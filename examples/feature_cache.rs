//! PDA demo: the feature-query cache ablation (Table 3's mechanism)
//! without any model compute — pure feature-stage economics under
//! Zipf-hot traffic against the simulated remote store.
//!
//! ```bash
//! cargo run --release --example feature_cache
//! ```
//! (No artifacts needed — this exercises the CPU-side substrate only.)

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use flame::config::{CacheMode, PdaConfig, WorkloadConfig};
use flame::featurestore::{FeatureSchema, RemoteStore};
use flame::netsim::{Link, LinkConfig};
use flame::pda::QueryEngine;
use flame::workload::Generator;

fn main() -> Result<()> {
    let n_requests = 400;
    println!("feature-query ablation: {n_requests} requests, Zipf(1.0) items, M=32 candidates\n");
    println!(
        "{:<22} {:>12} {:>14} {:>14} {:>12}",
        "mode", "wall time", "mean/req", "remote bytes", "hit rate"
    );
    println!("{}", "-".repeat(80));

    for (label, mode) in [
        ("no cache (baseline)", CacheMode::Off),
        ("sync cache", CacheMode::Sync),
        ("async cache (SWR)", CacheMode::Async),
    ] {
        let link = Arc::new(Link::new(LinkConfig::default()));
        let store = Arc::new(RemoteStore::new(FeatureSchema::default(), Arc::clone(&link), 3));
        let engine = QueryEngine::new(
            &PdaConfig { cache_mode: mode, ..PdaConfig::default() },
            store,
        );
        let wl = WorkloadConfig {
            catalog_size: 100_000,
            zipf_theta: 1.0,
            n_users: 5_000,
            candidate_mix: vec![(32, 1.0)],
            arrival_rate: None,
            seed: 42,
        };
        let mut gen = Generator::new(&wl, 64);

        // small warmup so cached modes start realistic, as in Table 3's
        // bypass-traffic methodology
        for _ in 0..50 {
            let r = gen.next_request();
            engine.fetch(&r.candidates);
        }
        engine.drain_refreshes();
        let bytes_before = link.bytes_total();

        let t0 = Instant::now();
        for _ in 0..n_requests {
            let r = gen.next_request();
            engine.fetch(&r.candidates);
        }
        let wall = t0.elapsed();
        engine.drain_refreshes();

        let bytes = link.bytes_total() - bytes_before;
        println!(
            "{label:<22} {:>12} {:>14} {:>11} KB {:>11.1} %",
            format!("{:.1} ms", wall.as_secs_f64() * 1e3),
            format!("{:.3} ms", wall.as_secs_f64() * 1e3 / n_requests as f64),
            bytes / 1000,
            engine.cache().stats.hit_rate() * 100.0
        );
    }

    println!("\nasync (stale-while-revalidate) never blocks on the link;");
    println!("sync blocks only on true misses; the baseline pays one RTT per request.");
    Ok(())
}
