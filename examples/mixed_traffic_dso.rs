//! DSO demo: the same mixed candidate-count traffic served with the
//! implicit-shape baseline (pad everything to the max profile) and with
//! the explicit-shape orchestrator (descending batch splitting) —
//! Table 5's mechanism, shown request by request.
//!
//! ```bash
//! make artifacts && cargo run --release --example mixed_traffic_dso
//! ```

use std::sync::Arc;

use anyhow::{Context, Result};
use flame::config::{DsoConfig, DsoMode};
use flame::dso::Orchestrator;
use flame::manifest::Manifest;
use flame::runtime::Runtime;
use flame::util::rng::Rng;

fn main() -> Result<()> {
    let scenario = "bench";
    let manifest = Manifest::load("artifacts").context("run `make artifacts` first")?;
    let runtime = Runtime::new()?;
    let cfg = manifest.scenario(scenario)?.config.clone();

    eprintln!("[dso] compiling {scenario}/fused profile engines ...");
    let build = |mode: DsoMode| -> Result<Orchestrator> {
        let engines = runtime.load_profile_set(&manifest, scenario, "fused")?;
        Ok(Orchestrator::new(
            engines,
            &DsoConfig { mode, executors_per_profile: 1, queue_capacity: 256 },
        )?)
    };
    let explicit = build(DsoMode::Explicit)?;
    let implicit = build(DsoMode::ImplicitPad)?;
    println!("profiles: {:?} (max {})", explicit.profiles(), explicit.max_profile());

    // Non-uniform upstream candidate counts (deliberately off-profile
    // values too — retrievers don't know about engine profiles).
    let mut rng = Rng::new(7);
    let ms: Vec<usize> = (0..12)
        .map(|_| *rng.choose(&[16usize, 24, 32, 48, 64, 96, 128, 130]))
        .collect();

    println!("\n{:>5} | {:<28} | {:<18} | waste", "M", "explicit plan", "implicit plan");
    println!("{}", "-".repeat(72));
    let d = cfg.d_model;
    let hist = Arc::new(vec![0.1f32; cfg.seq_len * d]);
    for &m in &ms {
        let cands = vec![0.05f32; m * d];
        let pe = explicit.plan(m);
        let pi = implicit.plan(m);
        let oe = explicit.submit(Arc::clone(&hist), &cands, m)?;
        let oi = implicit.submit(Arc::clone(&hist), &cands, m)?;
        assert_eq!(oe.scores.len(), m * cfg.n_tasks);
        assert_eq!(oi.scores.len(), m * cfg.n_tasks);
        println!(
            "{m:>5} | {:<28} | {:<18} | {} vs {} padded rows",
            format!("{:?} (+{})", pe.chunks, pe.padding),
            format!("{:?} (+{})", pi.chunks, pi.padding),
            pe.padding,
            pi.padding,
        );
    }

    println!("\ncumulative padded-row waste:");
    println!(
        "  explicit : {:.1} % of executed rows",
        explicit.waste_fraction() * 100.0
    );
    println!(
        "  implicit : {:.1} % of executed rows",
        implicit.waste_fraction() * 100.0
    );
    println!("\n(the wasted rows are wasted FLOPs — Table 5's throughput gap)");
    Ok(())
}
