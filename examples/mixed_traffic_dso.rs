//! DSO demo: the same mixed candidate-count traffic served three ways —
//! implicit-shape padding, explicit-shape splitting, and explicit
//! splitting with the cross-request batch coalescer packing concurrent
//! requests' tail remainders into shared launches.
//!
//! Phase 1 runs on every checkout (artifact-free `SimEngine` backend);
//! phase 2 shows the per-request split plans on real engines and is
//! skipped unless artifacts + a PJRT runtime are available.
//!
//! ```bash
//! cargo run --release --example mixed_traffic_dso        # phase 1 only
//! make artifacts && cargo run --release --example mixed_traffic_dso
//! ```

use std::sync::{Arc, Barrier};
use std::time::Duration;

use anyhow::Result;
use flame::config::{DsoConfig, DsoMode};
use flame::dso::{ComputeBackend, Orchestrator, SimEngine};
use flame::manifest::Manifest;
use flame::runtime::Runtime;
use flame::util::rng::Rng;
use flame::workload::MDist;

const SEQ: usize = 32;
const D: usize = 16;
const TASKS: usize = 3;
const PROFILES: &[usize] = &[16, 32, 64, 128];

fn sim_orchestrator(coalesce: bool, mode: DsoMode) -> Result<Orchestrator> {
    let backends: Vec<Arc<dyn ComputeBackend>> = PROFILES
        .iter()
        .map(|&m| {
            Arc::new(SimEngine::new(m, SEQ, D, TASKS).with_delay(Duration::from_micros(150)))
                as Arc<dyn ComputeBackend>
        })
        .collect();
    Ok(Orchestrator::from_backends(
        backends,
        &DsoConfig {
            mode,
            executors_per_profile: 2,
            queue_capacity: 1024,
            coalesce,
            coalesce_wait_us: 500,
        },
        None,
    )?)
}

/// Drive `ms` through `orch` in waves of `wave` concurrent requests
/// (the coalescer only has something to pack when requests overlap).
fn drive(orch: &Arc<Orchestrator>, ms: &[usize], wave: usize) {
    for chunk in ms.chunks(wave) {
        let barrier = Arc::new(Barrier::new(chunk.len()));
        std::thread::scope(|s| {
            for (i, &m) in chunk.iter().enumerate() {
                let orch = Arc::clone(orch);
                let barrier = Arc::clone(&barrier);
                s.spawn(move || {
                    let hist = vec![0.1f32; SEQ * D];
                    let cands: Vec<f32> =
                        (0..m * D).map(|j| ((i * 131 + j) % 97) as f32 / 97.0 - 0.5).collect();
                    barrier.wait();
                    let out = orch.submit_slice(&hist, &cands, m).expect("submit");
                    assert_eq!(out.scores.len(), m * TASKS);
                });
            }
        });
    }
}

fn phase_sim() {
    println!("— phase 1: cross-request coalescing under a skewed upstream (sim backend) —\n");
    // bimodal upstream: mostly tiny requests, a heavy large tail, and
    // deliberately off-profile M values (retrievers don't know profiles)
    let mix = MDist::Bimodal.mix(PROFILES);
    println!("bimodal mix over profile support: {mix:?}");
    let total: f64 = mix.iter().map(|&(_, w)| w).sum();
    let mut rng = Rng::new(7);
    let ms: Vec<usize> = (0..96)
        .map(|_| {
            let x = rng.next_f64() * total;
            let mut acc = 0.0;
            for &(m, w) in &mix {
                acc += w;
                if x < acc {
                    return m;
                }
            }
            mix.last().unwrap().0
        })
        .collect();

    let mut report: Vec<(&str, f64, u64)> = Vec::new();
    for (label, mode, coalesce) in [
        ("implicit pad-to-max", DsoMode::ImplicitPad, false),
        ("DSO split", DsoMode::Explicit, false),
        ("DSO split+coalesce", DsoMode::Explicit, true),
    ] {
        let orch = Arc::new(sim_orchestrator(coalesce, mode).expect("orchestrator"));
        drive(&orch, &ms, 8);
        let stats = orch.coalesce_stats();
        report.push((label, orch.waste_fraction(), stats.coalesced_rows));
        if coalesce {
            println!(
                "\ncoalescer: {} packed batches, {} multi-request, {} rows shared a launch, \
                 occupancy mean {:.0} %",
                stats.batches,
                stats.multi_request_batches,
                stats.coalesced_rows,
                stats.occupancy_mean_pct
            );
        }
    }
    println!("\npadded-row waste (same 96-request stream, 8-way concurrency):");
    for (label, waste, _) in &report {
        println!("  {label:<22} {:.1} % of executed rows", waste * 100.0);
    }
    println!("\n(wasted rows are wasted FLOPs — the coalescer closes the remainder gap)");
}

fn phase_real() -> Result<()> {
    let scenario = "bench";
    let Ok(manifest) = Manifest::load("artifacts") else {
        println!("\n— phase 2 skipped: no artifacts (run `make artifacts`) —");
        return Ok(());
    };
    let runtime = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            println!("\n— phase 2 skipped: PJRT runtime unavailable ({e}) —");
            return Ok(());
        }
    };
    let cfg = manifest.scenario(scenario)?.config.clone();

    println!("\n— phase 2: per-request split plans on real engines —");
    eprintln!("[dso] compiling {scenario}/fused profile engines ...");
    let build = |mode: DsoMode| -> Result<Orchestrator> {
        let engines = runtime.load_profile_set(&manifest, scenario, "fused")?;
        Ok(Orchestrator::new(
            engines,
            &DsoConfig {
                mode,
                executors_per_profile: 1,
                queue_capacity: 256,
                ..DsoConfig::default()
            },
        )?)
    };
    let explicit = build(DsoMode::Explicit)?;
    let implicit = build(DsoMode::ImplicitPad)?;
    println!("profiles: {:?} (max {})", explicit.profiles(), explicit.max_profile());

    let mut rng = Rng::new(7);
    let ms: Vec<usize> = (0..12)
        .map(|_| *rng.choose(&[16usize, 24, 32, 48, 64, 96, 128, 130]))
        .collect();

    println!("\n{:>5} | {:<28} | {:<18} | waste", "M", "explicit plan", "implicit plan");
    println!("{}", "-".repeat(72));
    let d = cfg.d_model;
    let hist = Arc::new(vec![0.1f32; cfg.seq_len * d]);
    for &m in &ms {
        let cands = vec![0.05f32; m * d];
        let pe = explicit.plan(m);
        let pi = implicit.plan(m);
        let oe = explicit.submit(Arc::clone(&hist), &cands, m)?;
        let oi = implicit.submit(Arc::clone(&hist), &cands, m)?;
        assert_eq!(oe.scores.len(), m * cfg.n_tasks);
        assert_eq!(oi.scores.len(), m * cfg.n_tasks);
        println!(
            "{m:>5} | {:<28} | {:<18} | {} vs {} padded rows",
            format!("{:?} (+{})", pe.chunks, pe.padding),
            format!("{:?} (+{})", pi.chunks, pi.padding),
            pe.padding,
            pi.padding,
        );
    }

    println!("\ncumulative padded-row waste:");
    println!("  explicit : {:.1} % of executed rows", explicit.waste_fraction() * 100.0);
    println!("  implicit : {:.1} % of executed rows", implicit.waste_fraction() * 100.0);
    Ok(())
}

fn main() -> Result<()> {
    phase_sim();
    phase_real()
}
