//! Cluster-tier demo: three replicas behind the router, cache-affinity
//! placement, deadline admission, and failure ejection + re-admission —
//! all on simulated replicas, so it runs on a bare checkout:
//!
//! ```bash
//! cargo run --release --example cluster_serve
//! ```
//!
//! (For real replicas over artifacts, use `flame cluster --real` or
//! `flame bind --replicas 3`.)

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use flame::cluster::{
    ClusterConfig, ClusterRouter, ReplicaBackend, ResultCacheConfig, RoutePolicy, SimConfig,
    SimReplica,
};
use flame::config::WorkloadConfig;
use flame::workload::{driver, Generator};

fn main() -> Result<()> {
    // three simulated replicas, each with its own user-feature cache
    let sims: Vec<Arc<SimReplica>> =
        (0..3).map(|_| Arc::new(SimReplica::new(SimConfig::default()))).collect();
    let backends: Vec<Arc<dyn ReplicaBackend>> =
        sims.iter().map(|s| Arc::clone(s) as Arc<dyn ReplicaBackend>).collect();
    let router = Arc::new(ClusterRouter::new(
        backends,
        ClusterConfig { policy: RoutePolicy::CacheAffinity, ..ClusterConfig::default() },
    )?);

    // traffic: 400 returning users, non-uniform candidate counts
    let wl = WorkloadConfig {
        catalog_size: 50_000,
        zipf_theta: 0.99,
        n_users: 400,
        candidate_mix: vec![(128, 0.6), (256, 0.25), (512, 0.15)],
        arrival_rate: None,
        seed: 9,
    };
    let requests = Generator::new(&wl, 32).batch(3_000);

    println!("phase 1: cache-affinity routing, 3k requests from 400 users");
    let report = driver::closed_loop(requests.clone(), 12, Duration::from_secs(30), |r| {
        router.submit(r).is_ok()
    });
    let snap = router.snapshot();
    println!(
        "  completed {}/{}  aggregate cache hit rate {:.1} %",
        report.completed,
        report.submitted,
        snap.aggregate_cache_hit_rate * 100.0
    );
    for r in &snap.replicas {
        println!(
            "  replica {}: {} requests, hit rate {:.1} %, p99 {:.2} ms",
            r.id,
            r.requests,
            r.cache_hit_rate * 100.0,
            r.p99_ms
        );
    }

    // phase 2: replica 0 starts failing; the router ejects it after 3
    // consecutive errors and fails the affected users over to the ring's
    // next replicas — the others' caches stay warm (minimal disruption)
    println!("\nphase 2: replica 0 fails; consecutive-error ejection + failover");
    sims[0].fail_next(1_000);
    let report = driver::closed_loop(requests.clone(), 12, Duration::from_secs(30), |r| {
        router.submit(r).is_ok()
    });
    let snap = router.snapshot();
    println!(
        "  completed {}/{} (failover re-routes: {})",
        report.completed, report.submitted, snap.rerouted
    );
    for r in &snap.replicas {
        println!(
            "  replica {}: healthy={} errors={} ejections={}",
            r.id, r.healthy, r.errors, r.ejections
        );
    }

    // phase 3: cooldown passes, replica 0 recovers and is re-admitted
    sims[0].fail_next(0);
    std::thread::sleep(Duration::from_millis(600)); // > eject_cooldown_ms
    let before = router.replicas()[0].metrics.requests();
    driver::closed_loop(requests.clone(), 12, Duration::from_secs(30), |r| {
        router.submit(r).is_ok()
    });
    let after = router.replicas()[0].metrics.requests();
    println!(
        "\nphase 3: after cooldown, replica 0 served {} more requests (healthy={})",
        after - before,
        router.replicas()[0].healthy()
    );

    // phase 4: duplicate bursts against the router's result-cache tier —
    // a fresh router with the cache enabled, fed the same traffic with
    // 30% of requests re-issued (the upstream-retriever-retry pattern).
    // Duplicates are answered from the cache (or coalesced onto an
    // in-flight computation) without touching a replica.
    let sims2: Vec<Arc<SimReplica>> =
        (0..3).map(|_| Arc::new(SimReplica::new(SimConfig::default()))).collect();
    let backends2: Vec<Arc<dyn ReplicaBackend>> =
        sims2.iter().map(|s| Arc::clone(s) as Arc<dyn ReplicaBackend>).collect();
    let cached_router = Arc::new(ClusterRouter::new(
        backends2,
        ClusterConfig {
            policy: RoutePolicy::CacheAffinity,
            result_cache: ResultCacheConfig {
                capacity: 32_768,
                ttl_ms: 5_000,
                ..ResultCacheConfig::default()
            },
            ..ClusterConfig::default()
        },
    )?);
    let mut dup_requests = requests;
    driver::inject_duplicates(&mut dup_requests, 0.3, 9);
    let report = driver::closed_loop(dup_requests, 12, Duration::from_secs(30), |r| {
        cached_router.submit(r).is_ok()
    });
    let snap = cached_router.snapshot();
    let backend_serves: u64 = sims2.iter().map(|s| s.served_total()).sum();
    println!(
        "\nphase 4: 30% duplicate bursts through the result tier: \
         completed {}/{}, backend serves {} (hits {}, coalesced {}, misses {})",
        report.completed,
        report.submitted,
        backend_serves,
        snap.result_hits,
        snap.result_coalesced,
        snap.result_misses
    );
    Ok(())
}
