//! TCP front demo: start the serving stack behind the binary protocol,
//! drive it with an in-process client, print per-request results.
//!
//! ```bash
//! make artifacts && cargo run --release --example tcp_serve
//! ```

use std::sync::Arc;

use anyhow::{Context, Result};
use flame::config::{CacheMode, StackConfig};
use flame::manifest::Manifest;
use flame::runtime::Runtime;
use flame::server::pipeline::StackBuilder;
use flame::server::tcp::{TcpClient, TcpServer};
use flame::workload::{Generator, Request};
use flame::config::WorkloadConfig;

fn main() -> Result<()> {
    let manifest = Manifest::load("artifacts").context("run `make artifacts` first")?;
    let runtime = Runtime::new()?;

    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Async;
    eprintln!("[tcp_serve] compiling tiny/fused engines ...");
    let stack = Arc::new(StackBuilder::new("tiny", "fused", cfg).build(&runtime, &manifest)?);

    let server = TcpServer::start(Arc::clone(&stack), "127.0.0.1:0")?;
    println!("listening on {}", server.addr);

    // generate realistic requests
    let wl = WorkloadConfig {
        catalog_size: 10_000,
        zipf_theta: 1.0,
        n_users: 500,
        candidate_mix: WorkloadConfig::uniform_mix(stack.orchestrator.profiles()),
        arrival_rate: None,
        seed: 5,
    };
    let mut gen = Generator::new(&wl, stack.model_cfg.seq_len);
    let requests: Vec<Request> = gen.batch(10);

    let mut client = TcpClient::connect(&server.addr)?;
    println!("\n{:>4} {:>6} {:>10} {:>12}  top task-0 score", "id", "M", "status", "latency");
    for req in &requests {
        let resp = client.call(req)?;
        let status = match resp.status {
            0 => "ok",
            1 => "overload",
            _ => "error",
        };
        // best candidate by task-0 probability
        let best = resp
            .scores
            .chunks(resp.n_tasks.max(1))
            .enumerate()
            .max_by(|a, b| a.1[0].partial_cmp(&b.1[0]).unwrap())
            .map(|(i, row)| format!("cand {i} @ {:.4}", row[0]))
            .unwrap_or_default();
        println!(
            "{:>4} {:>6} {:>10} {:>9.2} ms  {best}",
            resp.request_id,
            resp.m,
            status,
            resp.overall_us as f64 / 1e3
        );
    }

    let snap = stack.metrics.snapshot();
    println!("\nserved {} requests, mean overall {:.2} ms, cache hit {:.0} %",
        snap.requests, snap.overall_mean_ms,
        stack.query.cache().stats.hit_rate() * 100.0);
    server.shutdown();
    Ok(())
}
