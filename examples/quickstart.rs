//! Quickstart: score one SUMI request end to end.
//!
//! With artifacts (`make artifacts`) this compiles and runs the tiny
//! scenario's fused PJRT engine; on a bare checkout it falls back to
//! the native CPU Fused Kernel Engine (`fke::cpu`) — same model
//! semantics, zero build-time dependencies.
//!
//! ```bash
//! cargo run --release --example quickstart          # CPU FKE fallback
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use flame::manifest::Manifest;
use flame::runtime::{EngineKey, Runtime};

fn main() -> Result<()> {
    // 1. Artifacts: HLO text + weights, produced once by `make artifacts`.
    //    Missing artifacts are not an error anymore — the native CPU
    //    engine serves the same request without them.
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            println!("artifacts unavailable ({e}) — running the native CPU FKE instead\n");
            return cpu_quickstart();
        }
    };

    // 2. Runtime: one PJRT CPU client per process.
    let runtime = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            println!("PJRT unavailable ({e}) — running the native CPU FKE instead\n");
            return cpu_quickstart();
        }
    };
    println!("platform: {}", runtime.platform());

    // 3. Engine: compile tiny/fused at the native candidate profile.
    let cfg = manifest.scenario("tiny")?.config.clone();
    let key = EngineKey::new("tiny", "fused", cfg.native_m);
    let engine = runtime.load_engine(&manifest, &key)?;
    println!(
        "engine {}: L={} D={} M={} ({:.2e} FLOPs/request)",
        key.label(),
        cfg.seq_len,
        cfg.d_model,
        cfg.native_m,
        engine.flops as f64
    );

    // 4. One request: pre-embedded history [L, D] + candidates [M, D].
    //    (In the full stack the PDA assembles these from item ids; see
    //    examples/serve_e2e.rs.)
    let hist: Vec<f32> = (0..engine.hist_len())
        .map(|i| ((i % 17) as f32 / 17.0) - 0.5)
        .collect();
    let cands: Vec<f32> = (0..engine.cands_len())
        .map(|i| ((i % 13) as f32 / 13.0) - 0.5)
        .collect();

    let scores = engine.run(&hist, &cands)?;

    // 5. Scores: [M, n_tasks] task probabilities per candidate.
    print_scores(&scores, cfg.n_tasks);
    println!(
        "\nmean compute latency: {:.3} ms",
        engine.stats.mean_compute_ms()
    );
    Ok(())
}

/// The artifact-free path: the same tiny scenario on the native CPU
/// Fused Kernel Engine — real FLOPs, mask-aware tile skipping, no
/// Python, no PJRT.
fn cpu_quickstart() -> Result<()> {
    use flame::config::Scenario;
    use flame::dso::ComputeBackend;
    use flame::fke::cpu::{CpuEngine, CpuEngineConfig, CpuModel};

    let cfg = Scenario::Tiny.config();
    let model = CpuModel::new(&cfg, CpuModel::seed_for(&cfg.name))?;
    let engine = CpuEngine::new(model, cfg.native_m, &CpuEngineConfig::default());
    println!(
        "engine {}: L={} D={} M={} (native CPU, fused variant)",
        engine.label(),
        cfg.seq_len,
        cfg.d_model,
        cfg.native_m
    );

    let hist: Vec<f32> = (0..engine.hist_len())
        .map(|i| ((i % 17) as f32 / 17.0) - 0.5)
        .collect();
    let cands: Vec<f32> = (0..cfg.native_m * cfg.d_model)
        .map(|i| ((i % 13) as f32 / 13.0) - 0.5)
        .collect();
    let scores = engine.run(&hist, &cands)?;
    print_scores(&scores, cfg.n_tasks);

    let ks = engine.kernel_stats();
    println!(
        "\nkernel stats: {:.2} MFLOP executed, attention tiles visited {} / skipped {} \
         ({:.0} % skipped by the mask-aware schedule)",
        ks.flops as f64 / 1e6,
        ks.tiles_visited,
        ks.tiles_skipped,
        ks.tile_skip_fraction() * 100.0
    );
    println!("try the full ladder: cargo bench --bench bench_fke");
    Ok(())
}

fn print_scores(scores: &[f32], n_tasks: usize) {
    println!("\nper-candidate task probabilities:");
    for (i, row) in scores.chunks(n_tasks).enumerate() {
        let fmt: Vec<String> = row.iter().map(|s| format!("{s:.4}")).collect();
        println!("  candidate {i}: [{}]", fmt.join(", "));
    }
}
