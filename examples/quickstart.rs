//! Quickstart: load the tiny scenario's fused engine and score one
//! SUMI request end to end.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::{Context, Result};
use flame::manifest::Manifest;
use flame::runtime::{EngineKey, Runtime};

fn main() -> Result<()> {
    // 1. Artifacts: HLO text + weights, produced once by `make artifacts`.
    let manifest = Manifest::load("artifacts")
        .context("artifacts/ missing — run `make artifacts` first")?;

    // 2. Runtime: one PJRT CPU client per process.
    let runtime = Runtime::new()?;
    println!("platform: {}", runtime.platform());

    // 3. Engine: compile tiny/fused at the native candidate profile.
    let cfg = manifest.scenario("tiny")?.config.clone();
    let key = EngineKey::new("tiny", "fused", cfg.native_m);
    let engine = runtime.load_engine(&manifest, &key)?;
    println!(
        "engine {}: L={} D={} M={} ({:.2e} FLOPs/request)",
        key.label(),
        cfg.seq_len,
        cfg.d_model,
        cfg.native_m,
        engine.flops as f64
    );

    // 4. One request: pre-embedded history [L, D] + candidates [M, D].
    //    (In the full stack the PDA assembles these from item ids; see
    //    examples/serve_e2e.rs.)
    let hist: Vec<f32> = (0..engine.hist_len())
        .map(|i| ((i % 17) as f32 / 17.0) - 0.5)
        .collect();
    let cands: Vec<f32> = (0..engine.cands_len())
        .map(|i| ((i % 13) as f32 / 13.0) - 0.5)
        .collect();

    let scores = engine.run(&hist, &cands)?;

    // 5. Scores: [M, n_tasks] task probabilities per candidate.
    println!("\nper-candidate task probabilities:");
    for (i, row) in scores.chunks(cfg.n_tasks).enumerate() {
        let fmt: Vec<String> = row.iter().map(|s| format!("{s:.4}")).collect();
        println!("  candidate {i}: [{}]", fmt.join(", "));
    }
    println!(
        "\nmean compute latency: {:.3} ms",
        engine.stats.mean_compute_ms()
    );
    Ok(())
}
