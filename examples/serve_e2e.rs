//! End-to-end serving driver — the repo's headline validation run
//! (recorded in EXPERIMENTS.md §End-to-end).
//!
//! Builds the whole FLAME stack (simulated remote feature store → PDA
//! cached query engine → embedding/assembly → DSO explicit-shape
//! orchestrator → PJRT engines) on a real lowered model, drives mixed
//! candidate-count traffic closed-loop (one request in flight per
//! worker), and reports the paper's metric set: throughput in user-item
//! pairs/s, overall/compute latency mean/p50/p99, feature-stage latency,
//! network utilization, cache hit rate, and DSO padding waste.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! cargo run --release --example serve_e2e -- --scenario bench --seconds 20
//! ```

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};
use flame::benchkit::Table;
use flame::config::{CacheMode, StackConfig, WorkloadConfig};
use flame::dso::ComputeBackend;
use flame::manifest::Manifest;
use flame::runtime::Runtime;
use flame::server::pipeline::StackBuilder;
use flame::workload::Generator;

fn main() -> Result<()> {
    // light argv parsing (example-local)
    let argv: Vec<String> = std::env::args().collect();
    let getf = |name: &str, default: &str| -> String {
        argv.iter()
            .position(|a| a == name)
            .and_then(|i| argv.get(i + 1).cloned())
            .unwrap_or_else(|| default.to_string())
    };
    let scenario = getf("--scenario", "bench");
    let variant = getf("--variant", "fused");
    let seconds: f64 = getf("--seconds", "15").parse()?;
    let workers: usize = getf("--workers", "2").parse()?;
    // decoupled two-stage mode: feature workers overlap compute submitters
    let pipelined = argv.iter().any(|a| a == "--pipeline");

    let manifest = Manifest::load("artifacts").context("run `make artifacts` first")?;
    let runtime = Runtime::new()?;

    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Async;
    cfg.server.pipeline_workers = workers;
    cfg.server.pipeline = pipelined;
    cfg.server.feature_workers = workers;
    cfg.dso.executors_per_profile = 1;

    eprintln!("[serve_e2e] compiling {scenario}/{variant} engines (all profiles) ...");
    let stack = Arc::new(
        StackBuilder::new(&scenario, &variant, cfg.clone()).build(&runtime, &manifest)?,
    );
    let profiles = stack.orchestrator.profiles().to_vec();
    eprintln!("[serve_e2e] profiles {profiles:?} ready");

    // Mixed traffic: uniform over this scenario's profiles (the Table 5
    // shape), Zipf-hot items (the Table 3 shape).
    let wl = WorkloadConfig {
        catalog_size: 200_000,
        zipf_theta: 1.0,
        n_users: 20_000,
        candidate_mix: WorkloadConfig::uniform_mix(&profiles),
        arrival_rate: None,
        seed: 2026,
    };
    let mut gen = Generator::new(&wl, stack.model_cfg.seq_len);
    let requests = gen.batch(50_000);

    // Warmup: populate caches + engine first-run costs.
    eprintln!("[serve_e2e] warmup ...");
    stack.drive_closed_loop(&requests[..64], workers, Duration::from_secs(60));
    stack.query.drain_refreshes();

    // Measured run.
    eprintln!(
        "[serve_e2e] measuring for {seconds:.0}s{} ...",
        if pipelined { " (decoupled pipeline)" } else { "" }
    );
    let before_pairs = stack.metrics.pairs();
    let before_bytes = stack.link.bytes_total();
    // first-touch arena growths happen during warmup; report the
    // measured window's delta
    let before_growths = stack.metrics.arena_growths();
    stack.metrics.overall.reset();
    stack.metrics.compute.reset();
    stack.metrics.feature.reset();
    stack.metrics.queueing.reset();
    stack.metrics.handoff.reset();
    let t0 = std::time::Instant::now();
    let report = if pipelined {
        let handle = stack.spawn_pipeline();
        let dur = Duration::from_secs_f64(seconds);
        let report = handle.drive_closed_loop(&requests[64..], 2 * workers, dur);
        handle.shutdown();
        report
    } else {
        stack.drive_closed_loop(&requests[64..], workers, Duration::from_secs_f64(seconds))
    };
    let elapsed = t0.elapsed().as_secs_f64();

    let pairs = stack.metrics.pairs() - before_pairs;
    let mb = (stack.link.bytes_total() - before_bytes) as f64 / 1e6;
    let snap = stack.metrics.snapshot_over(elapsed);

    println!("\n=== serve_e2e report ({scenario}/{variant}, {workers} workers, closed loop) ===");
    println!("requests served : {} ({} failed)", report.completed, report.rejected);
    println!("throughput      : {:.1} k user-item pairs/s ({} pairs / {elapsed:.1}s)", pairs as f64 / elapsed / 1e3, pairs);
    println!("overall latency : mean {:.2} ms   p50 {:.2} ms   p99 {:.2} ms", snap.overall_mean_ms, snap.overall_p50_ms, snap.overall_p99_ms);
    println!("compute latency : mean {:.2} ms   p50 {:.2} ms   p99 {:.2} ms", snap.compute_mean_ms, snap.compute_p50_ms, snap.compute_p99_ms);
    println!("feature stage   : mean {:.2} ms", snap.feature_mean_ms);
    if pipelined {
        println!(
            "stage handoff   : mean {:.2} ms   p99 {:.2} ms (arena growths {})",
            snap.handoff_mean_ms,
            snap.handoff_p99_ms,
            snap.arena_growths - before_growths
        );
    }
    // Where a request's time goes, stage by stage (queue and handoff
    // are 0 outside the decoupled pipeline). The rows don't sum to the
    // e2e percentiles — a p99 request is rarely p99 in every stage.
    let mut stages = Table::new("per-stage latency", &["stage", "mean ms", "p50 ms", "p99 ms"]);
    for (name, mean, p50, p99) in [
        ("queue", snap.queueing_mean_ms, snap.queueing_p50_ms, snap.queueing_p99_ms),
        ("feature", snap.feature_mean_ms, snap.feature_p50_ms, snap.feature_p99_ms),
        ("handoff", snap.handoff_mean_ms, snap.handoff_p50_ms, snap.handoff_p99_ms),
        ("compute", snap.compute_mean_ms, snap.compute_p50_ms, snap.compute_p99_ms),
        ("e2e", snap.overall_mean_ms, snap.overall_p50_ms, snap.overall_p99_ms),
    ] {
        stages.row(&[
            name.to_string(),
            format!("{mean:.3}"),
            format!("{p50:.3}"),
            format!("{p99:.3}"),
        ]);
    }
    stages.print();
    println!("network         : {:.2} MB/s", mb / elapsed);
    println!("cache hit rate  : {:.1} % (fresh {:.1} %)", stack.query.cache().stats.hit_rate() * 100.0, stack.query.cache().stats.fresh_hit_rate() * 100.0);
    println!("dso waste       : {:.1} % padded rows", stack.orchestrator.waste_fraction() * 100.0);
    for &m in &profiles {
        if let Some(e) = stack.orchestrator.backend(m).and_then(|b| b.as_engine()) {
            println!(
                "engine m{:<5}: {} execs, mean compute {:.2} ms, upload {:.3} ms",
                m,
                e.stats.executions.load(std::sync::atomic::Ordering::Relaxed),
                e.stats.mean_compute_ms(),
                e.stats.upload_us.load(std::sync::atomic::Ordering::Relaxed) as f64
                    / e.stats.executions.load(std::sync::atomic::Ordering::Relaxed).max(1) as f64
                    / 1e3,
            );
        }
    }
    Ok(())
}
