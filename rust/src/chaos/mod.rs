//! Crate-wide fault-injection plane and degradation-ladder vocabulary.
//!
//! A [`FaultPlan`] is a seeded, deterministic description of a fault
//! storm: feature-store delay/error/timeout probabilities, a replica
//! brownout (latency multiplier) or hard crash window, compute-backend
//! stalls, and targeted worker-thread panics. Layers consult the plan
//! through injection points ([`ChaosSlot`] fields armed at
//! construction); an unarmed slot costs one `OnceLock::get` returning
//! `None` — the same zero-overhead idiom as the tracing hook.
//!
//! Determinism: every probabilistic site draws from
//! `splitmix64(seed ^ site_salt ^ sequence)` where `sequence` is a
//! per-site atomic counter. Given the same plan spec, seed, and number
//! of visits to each site, the *set* of injected faults is identical
//! across runs — a storm is reproduced from `(spec, seed)` alone (see
//! EXPERIMENTS.md, "Chaos runbook"). Injected events are counted on
//! the plan itself ([`FaultPlan::injected`]) so tests can assert the
//! recorder's degradation counters against what was actually injected.
//!
//! ## Spec grammar
//!
//! A spec is a comma-separated list of clauses; a clause is
//! `name:key=value` and bare `key=value` tokens extend the preceding
//! clause:
//!
//! ```text
//! store_timeout:p=0.05,brownout:replica=2,x=8,panic:worker=feature,n=3
//! ```
//!
//! | clause          | params (defaults)           | effect at the site |
//! |-----------------|-----------------------------|--------------------|
//! | `store_delay`   | `p` (1.0), `us` (2000)      | adds `us` of latency to a remote feature batch |
//! | `store_error`   | `p` (1.0)                   | remote feature batch fails (degrades to stale/default) |
//! | `store_timeout` | `p` (1.0)                   | remote feature batch times out (3x penalty, then stale/default) |
//! | `brownout`      | `replica` (0), `x` (4)      | multiplies the replica's service time by `x` |
//! | `crash`         | `replica` (0), `after` (0), `down` (u64::MAX) | the replica hard-fails attempts `after..after+down` |
//! | `stall`         | `p` (1.0), `us` (2000)      | a compute launch sleeps `us` before running |
//! | `panic`         | `worker` (feature), `n` (1), `count` (1) | the worker's `n`-th..`n+count`-th polls panic |
//!
//! `worker` targets: `feature` (pipeline feature stage), `compute`
//! (pipeline compute stage), `executor` (DSO executor). `n` is 1-based.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::error::{Error, Result};

/// Degradation-ladder rung stamped on every response (§ ladder docs in
/// `lib.rs`). Ordered best-first: later variants are worse; merging two
/// qualities keeps the maximum (worst) rung.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ServeQuality {
    /// Fresh features, full candidate set, computed for this request.
    Full = 0,
    /// At least one feature row was served stale or zero-defaulted
    /// because the remote store erred/timed out.
    StaleFeatures = 1,
    /// The candidate set was truncated to the top-K that fit the
    /// remaining deadline budget.
    TruncatedCandidates = 2,
    /// Served from the cluster result cache (hit or coalesced ride)
    /// instead of being computed.
    CachedResult = 3,
    /// Rejected by admission control / shed under overload; no scores.
    Shed = 4,
}

/// Number of ladder rungs (size of the recorder's quality histogram).
pub const QUALITY_RUNGS: usize = 5;

impl ServeQuality {
    /// Stable index into the recorder's quality histogram.
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Option<ServeQuality> {
        match i {
            0 => Some(ServeQuality::Full),
            1 => Some(ServeQuality::StaleFeatures),
            2 => Some(ServeQuality::TruncatedCandidates),
            3 => Some(ServeQuality::CachedResult),
            4 => Some(ServeQuality::Shed),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ServeQuality::Full => "full",
            ServeQuality::StaleFeatures => "stale_features",
            ServeQuality::TruncatedCandidates => "truncated_candidates",
            ServeQuality::CachedResult => "cached_result",
            ServeQuality::Shed => "shed",
        }
    }

    /// The worse (higher) of two rungs — a response's quality is the
    /// worst degradation it suffered anywhere on its path.
    pub fn worst(self, other: ServeQuality) -> ServeQuality {
        self.max(other)
    }
}

/// Supervised worker sites that targeted panics can name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PanicSite {
    /// Pipeline feature-stage worker (`server::stages`).
    Feature,
    /// Pipeline compute-stage submitter (`server::stages`).
    Compute,
    /// DSO executor thread (`dso::orchestrator`).
    Executor,
}

impl PanicSite {
    fn idx(self) -> usize {
        match self {
            PanicSite::Feature => 0,
            PanicSite::Compute => 1,
            PanicSite::Executor => 2,
        }
    }

    fn parse(s: &str) -> Result<Self> {
        match s {
            "feature" => Ok(PanicSite::Feature),
            "compute" => Ok(PanicSite::Compute),
            "executor" => Ok(PanicSite::Executor),
            o => Err(Error::Config(format!("unknown panic worker '{o}'"))),
        }
    }
}

/// Outcome of one feature-store fault roll (one roll per remote batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreFault {
    None,
    /// Add this many microseconds of latency, then proceed normally.
    Delay(u64),
    /// The batch fails outright.
    Error,
    /// The batch times out (callers pay the timeout penalty).
    Timeout,
}

#[derive(Clone, Copy, Debug)]
struct PanicSpec {
    site: PanicSite,
    /// 1-based poll index at which this spec starts firing.
    n: u64,
    /// Consecutive polls that fire.
    count: u64,
}

#[derive(Clone, Copy, Debug)]
struct CrashSpec {
    replica: usize,
    /// Serve attempts at the replica before the crash window opens.
    after: u64,
    /// Length of the crash window in serve attempts (u64::MAX = forever).
    down: u64,
}

/// Counts of faults the plan actually injected, for asserting recorder
/// counters against ground truth.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Injected {
    pub store_delays: u64,
    pub store_errors: u64,
    pub store_timeouts: u64,
    pub brownout_hits: u64,
    pub crash_faults: u64,
    pub compute_stalls: u64,
    pub worker_panics: u64,
}

/// A seeded, deterministic fault storm. Construct with
/// [`FaultPlan::parse`]; share via `Arc` and arm [`ChaosSlot`]s with it.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    store_delay: Option<(f64, u64)>,
    store_error_p: f64,
    store_timeout_p: f64,
    brownout: Option<(usize, u32)>,
    crash: Option<CrashSpec>,
    stall: Option<(f64, u64)>,
    panics: Vec<PanicSpec>,

    store_seq: AtomicU64,
    stall_seq: AtomicU64,
    crash_seq: AtomicU64,
    panic_seq: [AtomicU64; 3],

    inj_store_delays: AtomicU64,
    inj_store_errors: AtomicU64,
    inj_store_timeouts: AtomicU64,
    inj_brownouts: AtomicU64,
    inj_crashes: AtomicU64,
    inj_stalls: AtomicU64,
    inj_panics: AtomicU64,
}

/// splitmix64 finalizer — the crate's standard cheap deterministic hash.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Map a hash to [0, 1).
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultPlan {
    /// The empty plan: no clause ever fires. Useful as a spec default.
    pub fn none(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            store_delay: None,
            store_error_p: 0.0,
            store_timeout_p: 0.0,
            brownout: None,
            crash: None,
            stall: None,
            panics: Vec::new(),
            store_seq: AtomicU64::new(0),
            stall_seq: AtomicU64::new(0),
            crash_seq: AtomicU64::new(0),
            panic_seq: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
            inj_store_delays: AtomicU64::new(0),
            inj_store_errors: AtomicU64::new(0),
            inj_store_timeouts: AtomicU64::new(0),
            inj_brownouts: AtomicU64::new(0),
            inj_crashes: AtomicU64::new(0),
            inj_stalls: AtomicU64::new(0),
            inj_panics: AtomicU64::new(0),
        }
    }

    /// Parse a fault spec (see module docs for the grammar).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut plan = FaultPlan::none(seed);
        let mut clauses: Vec<(String, Vec<(String, String)>)> = Vec::new();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            if let Some((name, first)) = tok.split_once(':') {
                clauses.push((name.to_string(), vec![kv(first)?]));
            } else if tok.contains('=') {
                match clauses.last_mut() {
                    Some((_, params)) => params.push(kv(tok)?),
                    None => {
                        return Err(Error::Config(format!(
                            "chaos spec param '{tok}' precedes any clause"
                        )))
                    }
                }
            } else {
                clauses.push((tok.to_string(), Vec::new()));
            }
        }
        for (name, params) in clauses {
            let get_f = |k: &str, d: f64| -> Result<f64> { param_f64(&params, k, d) };
            let get_u = |k: &str, d: u64| -> Result<u64> { param_u64(&params, k, d) };
            match name.as_str() {
                "store_delay" => {
                    plan.store_delay = Some((get_f("p", 1.0)?, get_u("us", 2_000)?));
                }
                "store_error" => plan.store_error_p = get_f("p", 1.0)?,
                "store_timeout" => plan.store_timeout_p = get_f("p", 1.0)?,
                "brownout" => {
                    plan.brownout =
                        Some((get_u("replica", 0)? as usize, get_u("x", 4)? as u32));
                }
                "crash" => {
                    plan.crash = Some(CrashSpec {
                        replica: get_u("replica", 0)? as usize,
                        after: get_u("after", 0)?,
                        down: get_u("down", u64::MAX)?,
                    });
                }
                "stall" | "compute_stall" => {
                    plan.stall = Some((get_f("p", 1.0)?, get_u("us", 2_000)?));
                }
                "panic" => {
                    let site = match params.iter().find(|(k, _)| k == "worker") {
                        Some((_, v)) => PanicSite::parse(v)?,
                        None => PanicSite::Feature,
                    };
                    plan.panics.push(PanicSpec {
                        site,
                        n: get_u("n", 1)?.max(1),
                        count: get_u("count", 1)?.max(1),
                    });
                }
                o => return Err(Error::Config(format!("unknown chaos clause '{o}'"))),
            }
        }
        Ok(plan)
    }

    fn roll(&self, salt: u64, seq: u64, p: f64) -> bool {
        p > 0.0 && unit(mix(self.seed ^ salt.wrapping_mul(0xA24B_AED4_963E_E407) ^ seq)) < p
    }

    /// One fault roll for a remote feature-store batch. Rolls timeout,
    /// then error, then delay — at most one fault per batch.
    pub fn store_fault(&self) -> StoreFault {
        let seq = self.store_seq.fetch_add(1, Ordering::Relaxed);
        if self.roll(0x51, seq, self.store_timeout_p) {
            self.inj_store_timeouts.fetch_add(1, Ordering::Relaxed);
            return StoreFault::Timeout;
        }
        if self.roll(0x52, seq, self.store_error_p) {
            self.inj_store_errors.fetch_add(1, Ordering::Relaxed);
            return StoreFault::Error;
        }
        if let Some((p, us)) = self.store_delay {
            if self.roll(0x53, seq, p) {
                self.inj_store_delays.fetch_add(1, Ordering::Relaxed);
                return StoreFault::Delay(us);
            }
        }
        StoreFault::None
    }

    /// Latency multiplier for a browned-out replica (`None` = healthy).
    /// Counts a hit each time a service is actually slowed.
    pub fn brownout_x(&self, replica: usize) -> Option<u32> {
        match self.brownout {
            Some((r, x)) if r == replica && x > 1 => {
                self.inj_brownouts.fetch_add(1, Ordering::Relaxed);
                Some(x)
            }
            _ => None,
        }
    }

    /// Whether the replica's spec is a brownout target at all (no count).
    pub fn is_browned_out(&self, replica: usize) -> bool {
        matches!(self.brownout, Some((r, x)) if r == replica && x > 1)
    }

    /// Does this serve attempt at `replica` fall in the crash window?
    pub fn crashed(&self, replica: usize) -> bool {
        let Some(c) = self.crash else { return false };
        if c.replica != replica {
            return false;
        }
        let seq = self.crash_seq.fetch_add(1, Ordering::Relaxed);
        let hit = seq >= c.after && (c.down == u64::MAX || seq < c.after.saturating_add(c.down));
        if hit {
            self.inj_crashes.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Microseconds a compute launch should stall (`None` = run now).
    pub fn compute_stall_us(&self) -> Option<u64> {
        let (p, us) = self.stall?;
        let seq = self.stall_seq.fetch_add(1, Ordering::Relaxed);
        if self.roll(0x54, seq, p) {
            self.inj_stalls.fetch_add(1, Ordering::Relaxed);
            Some(us)
        } else {
            None
        }
    }

    /// Poll a supervised worker site: `true` means the caller should
    /// panic now (the supervisor will catch it). Each call advances the
    /// site's 1-based poll counter.
    pub fn panic_due(&self, site: PanicSite) -> bool {
        if self.panics.is_empty() {
            return false;
        }
        let seq = self.panic_seq[site.idx()].fetch_add(1, Ordering::Relaxed) + 1;
        let due = self
            .panics
            .iter()
            .any(|s| s.site == site && seq >= s.n && seq < s.n.saturating_add(s.count));
        if due {
            self.inj_panics.fetch_add(1, Ordering::Relaxed);
        }
        due
    }

    /// Total panics the plan will inject at `site` given enough polls.
    pub fn planned_panics(&self, site: PanicSite) -> u64 {
        self.panics.iter().filter(|s| s.site == site).map(|s| s.count).sum()
    }

    /// Snapshot of everything injected so far.
    pub fn injected(&self) -> Injected {
        Injected {
            store_delays: self.inj_store_delays.load(Ordering::Relaxed),
            store_errors: self.inj_store_errors.load(Ordering::Relaxed),
            store_timeouts: self.inj_store_timeouts.load(Ordering::Relaxed),
            brownout_hits: self.inj_brownouts.load(Ordering::Relaxed),
            crash_faults: self.inj_crashes.load(Ordering::Relaxed),
            compute_stalls: self.inj_stalls.load(Ordering::Relaxed),
            worker_panics: self.inj_panics.load(Ordering::Relaxed),
        }
    }
}

/// An injection point: a write-once slot a component checks on its hot
/// path. Unarmed, `get()` is a single `OnceLock::get` returning `None`.
#[derive(Debug, Default)]
pub struct ChaosSlot(OnceLock<Arc<FaultPlan>>);

impl ChaosSlot {
    pub const fn new() -> ChaosSlot {
        ChaosSlot(OnceLock::new())
    }

    /// Arm the slot. A second arm is a no-op (write-once by design: a
    /// storm's plan never changes mid-run).
    pub fn arm(&self, plan: Arc<FaultPlan>) {
        let _ = self.0.set(plan);
    }

    #[inline]
    pub fn get(&self) -> Option<&FaultPlan> {
        self.0.get().map(|a| &**a)
    }

    pub fn armed(&self) -> bool {
        self.0.get().is_some()
    }

    /// The armed plan, by `Arc`, for handing to sub-components.
    pub fn plan(&self) -> Option<Arc<FaultPlan>> {
        self.0.get().cloned()
    }
}

fn kv(tok: &str) -> Result<(String, String)> {
    match tok.split_once('=') {
        Some((k, v)) if !k.is_empty() && !v.is_empty() => {
            Ok((k.trim().to_string(), v.trim().to_string()))
        }
        _ => Err(Error::Config(format!("chaos spec token '{tok}' is not key=value"))),
    }
}

fn param_f64(params: &[(String, String)], key: &str, default: f64) -> Result<f64> {
    match params.iter().find(|(k, _)| k == key) {
        None => Ok(default),
        Some((_, v)) => v
            .parse::<f64>()
            .map_err(|_| Error::Config(format!("chaos param {key}='{v}' is not a number"))),
    }
}

fn param_u64(params: &[(String, String)], key: &str, default: u64) -> Result<u64> {
    match params.iter().find(|(k, _)| k == key) {
        None => Ok(default),
        Some((_, v)) => v
            .parse::<u64>()
            .map_err(|_| Error::Config(format!("chaos param {key}='{v}' is not an integer"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quality_ladder_orders_and_merges() {
        use ServeQuality::*;
        assert!(Full < StaleFeatures);
        assert!(StaleFeatures < TruncatedCandidates);
        assert!(TruncatedCandidates < CachedResult);
        assert!(CachedResult < Shed);
        assert_eq!(Full.worst(CachedResult), CachedResult);
        assert_eq!(Shed.worst(Full), Shed);
        for i in 0..QUALITY_RUNGS {
            assert_eq!(ServeQuality::from_index(i).unwrap().index(), i);
        }
        assert!(ServeQuality::from_index(QUALITY_RUNGS).is_none());
    }

    #[test]
    fn spec_grammar_round_trips() {
        let p = FaultPlan::parse(
            "store_timeout:p=0.05,brownout:replica=2,x=8,crash:replica=1,after=10,down=20,\
             panic:worker=executor,n=3,count=2,store_delay:p=0.5,us=300,stall:p=0.1,us=400",
            7,
        )
        .unwrap();
        assert_eq!(p.store_timeout_p, 0.05);
        assert_eq!(p.brownout, Some((2, 8)));
        let c = p.crash.unwrap();
        assert_eq!((c.replica, c.after, c.down), (1, 10, 20));
        assert_eq!(p.panics.len(), 1);
        assert_eq!(p.panics[0].site, PanicSite::Executor);
        assert_eq!((p.panics[0].n, p.panics[0].count), (3, 2));
        assert_eq!(p.store_delay, Some((0.5, 300)));
        assert_eq!(p.stall, Some((0.1, 400)));
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(FaultPlan::parse("bogus_clause:p=1", 0).is_err());
        assert!(FaultPlan::parse("p=0.5", 0).is_err(), "param before any clause");
        assert!(FaultPlan::parse("store_timeout:p=abc", 0).is_err());
        assert!(FaultPlan::parse("panic:worker=gpu", 0).is_err());
        assert!(FaultPlan::parse("store_timeout:p", 0).is_err());
    }

    #[test]
    fn empty_spec_is_the_none_plan() {
        let p = FaultPlan::parse("", 9).unwrap();
        for _ in 0..100 {
            assert_eq!(p.store_fault(), StoreFault::None);
            assert!(!p.crashed(0));
            assert!(p.compute_stall_us().is_none());
            assert!(!p.panic_due(PanicSite::Feature));
        }
        assert_eq!(p.injected(), Injected::default());
    }

    #[test]
    fn store_faults_are_seed_deterministic() {
        let a = FaultPlan::parse("store_timeout:p=0.3", 42).unwrap();
        let b = FaultPlan::parse("store_timeout:p=0.3", 42).unwrap();
        let fa: Vec<StoreFault> = (0..200).map(|_| a.store_fault()).collect();
        let fb: Vec<StoreFault> = (0..200).map(|_| b.store_fault()).collect();
        assert_eq!(fa, fb, "same seed, same storm");
        let hits = fa.iter().filter(|f| **f == StoreFault::Timeout).count();
        assert!((20..=100).contains(&hits), "p=0.3 over 200 rolls hit {hits}");
        let c = FaultPlan::parse("store_timeout:p=0.3", 43).unwrap();
        let fc: Vec<StoreFault> = (0..200).map(|_| c.store_fault()).collect();
        assert_ne!(fa, fc, "different seed, different storm");
        assert_eq!(a.injected().store_timeouts, hits as u64);
    }

    #[test]
    fn crash_window_opens_and_closes() {
        let p = FaultPlan::parse("crash:replica=1,after=3,down=4", 0).unwrap();
        assert!(!p.crashed(0), "other replicas unaffected");
        let outcomes: Vec<bool> = (0..10).map(|_| p.crashed(1)).collect();
        assert_eq!(
            outcomes,
            vec![false, false, false, true, true, true, true, false, false, false]
        );
        assert_eq!(p.injected().crash_faults, 4);
    }

    #[test]
    fn brownout_targets_one_replica() {
        let p = FaultPlan::parse("brownout:replica=2,x=8", 0).unwrap();
        assert_eq!(p.brownout_x(2), Some(8));
        assert_eq!(p.brownout_x(0), None);
        assert!(p.is_browned_out(2));
        assert!(!p.is_browned_out(1));
        assert_eq!(p.injected().brownout_hits, 1, "is_browned_out must not count");
    }

    #[test]
    fn panic_fires_on_nth_poll_only() {
        let p = FaultPlan::parse("panic:worker=compute,n=3,count=2", 0).unwrap();
        let fires: Vec<bool> = (0..6).map(|_| p.panic_due(PanicSite::Compute)).collect();
        assert_eq!(fires, vec![false, false, true, true, false, false]);
        assert!(!p.panic_due(PanicSite::Feature), "other sites unaffected");
        assert_eq!(p.injected().worker_panics, 2);
        assert_eq!(p.planned_panics(PanicSite::Compute), 2);
        assert_eq!(p.planned_panics(PanicSite::Executor), 0);
    }

    #[test]
    fn chaos_slot_arms_once() {
        let slot = ChaosSlot::new();
        assert!(slot.get().is_none());
        assert!(!slot.armed());
        slot.arm(Arc::new(FaultPlan::parse("store_error:p=1", 1).unwrap()));
        slot.arm(Arc::new(FaultPlan::none(2))); // no-op
        assert!(slot.armed());
        assert_eq!(slot.get().unwrap().store_fault(), StoreFault::Error);
        assert!(slot.plan().is_some());
    }

    #[test]
    fn store_delay_rolls_independently() {
        let p = FaultPlan::parse("store_delay:p=1,us=123", 5).unwrap();
        assert_eq!(p.store_fault(), StoreFault::Delay(123));
        assert_eq!(p.injected().store_delays, 1);
    }
}
