//! Crate-wide error type.
//!
//! The library surfaces one `Error` enum so callers (CLI, benches, server)
//! can match on failure classes; binaries convert to `anyhow` at the edge.

use std::fmt;

/// All the ways the FLAME stack can fail.
#[derive(Debug)]
pub enum Error {
    /// I/O error with context path.
    Io(String, std::io::Error),
    /// Artifact manifest missing / malformed / inconsistent.
    Manifest(String),
    /// JSON parse error (hand-rolled parser in `util::json`).
    Json(String),
    /// PJRT / XLA runtime error.
    Xla(xla::Error),
    /// Request rejected by admission control (queue full / shedding).
    Overloaded(String),
    /// Configuration error (bad flag, unknown scenario, ...).
    Config(String),
    /// A requested engine/profile is not in the loaded set.
    UnknownEngine(String),
    /// Wire-protocol violation on the TCP front.
    Protocol(String),
    /// Internal invariant broken (worker died, channel closed, ...).
    Internal(String),
    /// The stack is shutting down: blocked producers and queued work are
    /// woken and handed this instead of hanging on a closed queue.
    Shutdown(String),
    /// A supervised worker panicked while holding this request; the
    /// supervisor failed the request and restarted the worker.
    WorkerPanic(String),
    /// The request was cooperatively cancelled (deadline expiry, client
    /// disconnect, lost hedge race, or shutdown) and dropped at the
    /// named stage boundary before burning further compute.
    Cancelled(crate::cancel::CancelCause, crate::cancel::CancelStage),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(path, e) => write!(f, "io error at {path}: {e}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Overloaded(m) => write!(f, "overloaded: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::UnknownEngine(m) => write!(f, "unknown engine: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Internal(m) => write!(f, "internal error: {m}"),
            Error::Shutdown(m) => write!(f, "shutting down: {m}"),
            Error::WorkerPanic(m) => write!(f, "worker panicked: {m}"),
            Error::Cancelled(cause, stage) => {
                write!(f, "cancelled ({}) at {}", cause.as_str(), stage.as_str())
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(_, e) => Some(e),
            Error::Xla(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Attach a path to an io::Error.
pub fn io_err(path: impl Into<String>) -> impl FnOnce(std::io::Error) -> Error {
    let p = path.into();
    move |e| Error::Io(p, e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Manifest("missing field".into());
        assert!(e.to_string().contains("missing field"));
        let e = io_err("/some/path")(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let s = e.to_string();
        assert!(s.contains("/some/path") && s.contains("gone"));
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
