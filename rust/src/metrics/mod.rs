//! Serving metrics: latency histograms (p50/p99 — the paper's reported
//! quantiles), throughput counters in user-item pairs/s (the paper's
//! throughput unit), and byte counters for network utilization (Table 3's
//! fourth column).

pub mod histogram;
pub mod recorder;

pub use histogram::{HistSnapshot, Histogram};
pub use recorder::{MetricsSnapshot, Recorder, TenantCounts};
