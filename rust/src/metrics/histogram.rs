//! Log-bucketed latency histogram (HdrHistogram-style, base-2 with
//! sub-bucket linear resolution). Records microsecond values; quantile
//! error is bounded by the sub-bucket width (<1.6% with 64 sub-buckets).
//!
//! Lock-free recording (atomic bucket counters) so the request-path hot
//! loop never serializes on a metrics mutex — the paper keeps its
//! telemetry off the critical path for the same reason.

use std::sync::atomic::{AtomicU64, Ordering};

const SUB_BITS: u32 = 6; // 64 linear sub-buckets per power of two
const SUB: usize = 1 << SUB_BITS;
const ORDERS: usize = 40; // covers 1 µs .. ~12 days
const BUCKETS: usize = ORDERS * SUB;

/// Concurrent log-bucket histogram over u64 values (microseconds).
pub struct Histogram {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        let mut counts = Vec::with_capacity(BUCKETS);
        counts.resize_with(BUCKETS, || AtomicU64::new(0));
        Histogram { counts, total: AtomicU64::new(0), sum: AtomicU64::new(0), max: AtomicU64::new(0) }
    }

    #[inline]
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let order = 63 - v.leading_zeros() as usize; // floor(log2 v)
        let shift = order - SUB_BITS as usize;
        let sub = ((v >> shift) as usize) & (SUB - 1);
        ((order - SUB_BITS as usize + 1) * SUB + sub).min(BUCKETS - 1)
    }

    /// Lower bound of a bucket (its representative value).
    fn bucket_value(i: usize) -> u64 {
        if i < SUB {
            return i as u64;
        }
        let order = i / SUB - 1 + SUB_BITS as usize;
        let sub = i % SUB;
        (1u64 << order) + ((sub as u64) << (order - SUB_BITS as usize))
    }

    /// Upper edge of a bucket (inclusive): the largest value that maps
    /// into it. Exact buckets (< 64) have width 1, so lower == upper;
    /// the final catch-all bucket is unbounded above.
    fn bucket_upper(i: usize) -> u64 {
        if i < SUB {
            return i as u64;
        }
        if i + 1 >= BUCKETS {
            return u64::MAX;
        }
        Self::bucket_value(i + 1) - 1
    }

    /// Record one value (thread-safe, wait-free).
    #[inline]
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn record(&self, v: u64) {
        self.counts[Self::index(v)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Quantile in [0, 1]; returns the bucket *upper* edge, capped at
    /// the observed max. The upper edge can overstate by at most one
    /// sub-bucket (<1.6%) but never understates — the conservative
    /// direction for SLA accounting (a reported p99 under the deadline
    /// guarantees the true p99 was too).
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                // Cap at max so q=1.0 is exact; the inner `.max()` guards
                // against a concurrent record whose bucket increment is
                // visible before its max update.
                return Self::bucket_upper(i).min(self.max().max(Self::bucket_value(i)));
            }
        }
        self.max()
    }

    /// One consistent load of every bucket counter; all derived
    /// statistics (count / mean / quantiles) on the returned
    /// [`HistSnapshot`] come from that single pass, so a reader racing
    /// with `record()` can never mix state from different instants.
    pub fn snapshot_counts(&self) -> HistSnapshot {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let count = counts.iter().sum();
        HistSnapshot {
            counts,
            count,
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Merge another histogram into this one (for per-thread shards).
    pub fn merge(&self, other: &Histogram) {
        for (a, b) in self.counts.iter().zip(other.counts.iter()) {
            let v = b.load(Ordering::Relaxed);
            if v > 0 {
                a.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.total.fetch_add(other.total.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Reset all counters (between bench phases).
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.total.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

/// Point-in-time view of one histogram taken by
/// [`Histogram::snapshot_counts`]. Unlike reading `mean()`/`p99()` off
/// the live histogram (each call re-reads the atomics and can observe
/// different instants), every statistic here derives from one bucket
/// load.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    max: u64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Mean of the counted values, clamped into the bucket-derived
    /// bounds of the snapshot. The raw `sum` counter is loaded in a
    /// separate instant from the bucket counts; under concurrent
    /// recording it may include (or miss) values the bucket pass did
    /// not, so the quotient is clamped into [Σcᵢ·lowerᵢ/n, Σcᵢ·upperᵢ/n]
    /// — the range the true mean of the counted values must lie in.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let n = self.count as f64;
        let (mut lo, mut hi) = (0.0f64, 0.0f64);
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            lo += c as f64 * Histogram::bucket_value(i) as f64;
            hi += c as f64
                * Histogram::bucket_upper(i).min(self.max.max(Histogram::bucket_value(i))) as f64;
        }
        (self.sum as f64 / n).clamp(lo / n, hi / n)
    }

    /// Quantile over the snapshotted counts (bucket upper edge, capped
    /// at the snapshotted max — same convention as
    /// [`Histogram::quantile`]).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Histogram::bucket_upper(i).min(self.max.max(Histogram::bucket_value(i)));
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p99(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn exact_below_64() {
        let h = Histogram::new();
        for v in 0..64u64 {
            h.record(v);
        }
        assert_eq!(h.quantile(0.5), 31);
        assert_eq!(h.max(), 63);
    }

    #[test]
    fn quantile_error_bounded() {
        let h = Histogram::new();
        // uniform 1..100_000 µs
        for v in 1..=100_000u64 {
            h.record(v);
        }
        let cases = [(0.5, 50_000.0), (0.9, 90_000.0), (0.99, 99_000.0), (1.0, 100_000.0)];
        for &(q, expect) in &cases {
            let got = h.quantile(q) as f64;
            let rel = (got - expect).abs() / expect;
            assert!(rel < 0.02, "q={q} got={got} expect={expect} rel={rel}");
            // the upper-edge convention never understates the true quantile
            assert!(got >= expect, "q={q} got={got} understates true quantile {expect}");
        }
    }

    #[test]
    fn quantile_never_understates_constant_series() {
        // a single repeated value: any quantile must report >= the value
        // (the old lower-bound convention reported the bucket floor, up
        // to one sub-bucket below it)
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(5_000);
        }
        for q in [0.5, 0.9, 0.99, 1.0] {
            let got = h.quantile(q);
            assert!(got >= 5_000, "q={q} got={got}");
            assert!(got <= 5_055, "q={q} got={got} beyond bucket upper edge");
        }
    }

    #[test]
    fn p99_dominated_by_tail() {
        let h = Histogram::new();
        for _ in 0..99 {
            h.record(1_000);
        }
        h.record(1_000_000);
        assert!(h.p99() >= 900_000 || h.quantile(1.0) >= 900_000);
        // p50 lands in 1_000's bucket: [1000, 1008) at this order
        assert!(h.p50() >= 1_000 && h.p50() < 1_008, "p50={}", h.p50());
    }

    #[test]
    fn mean_and_count() {
        let h = Histogram::new();
        h.record(10);
        h.record(20);
        h.record(30);
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn merge_combines() {
        let a = Histogram::new();
        let b = Histogram::new();
        for i in 0..1000 {
            a.record(i);
            b.record(i + 1000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 2000);
        let med = a.p50() as f64;
        assert!((med - 1000.0).abs() / 1000.0 < 0.05, "median {med}");
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new());
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(t * 10_000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 80_000);
    }

    #[test]
    fn reset_clears() {
        let h = Histogram::new();
        h.record(123);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn snapshot_stats_match_series() {
        let h = Histogram::new();
        h.record(10);
        h.record(20);
        h.record(30);
        let s = h.snapshot_counts();
        assert_eq!(s.count(), 3);
        assert_eq!(s.max(), 30);
        assert!((s.mean() - 20.0).abs() < 1e-9);
        assert_eq!(s.p50(), 20); // exact buckets below 64
        assert_eq!(s.quantile(1.0), 30);
    }

    #[test]
    fn snapshot_mean_stays_in_bucket_bounds_under_concurrent_records() {
        // Writers hammer two fixed values whose buckets are
        // [1024, 1040) and [2048, 2080); any honest mean of any mix of
        // them lies in [1024, 2079]. A snapshot whose count and sum
        // were read at different instants could report a mean outside
        // that range — the clamp in HistSnapshot::mean forbids it.
        let h = std::sync::Arc::new(Histogram::new());
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writers: Vec<_> = (0..4)
            .map(|t| {
                let h = std::sync::Arc::clone(&h);
                let stop = std::sync::Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut i = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        h.record(if (i + t) % 2 == 0 { 1_024 } else { 2_048 });
                        i += 1;
                    }
                })
            })
            .collect();
        for _ in 0..2_000 {
            let s = h.snapshot_counts();
            if s.count() == 0 {
                continue;
            }
            let m = s.mean();
            assert!(
                (1_024.0..=2_079.0).contains(&m),
                "snapshot mean {m} escaped recorded value bounds (count={})",
                s.count()
            );
        }
        stop.store(true, Ordering::Relaxed);
        for w in writers {
            w.join().unwrap();
        }
    }

    #[test]
    fn index_monotone_nondecreasing_value() {
        // bucket_value(index(v)) <= v and within one sub-bucket of v
        for v in [0u64, 1, 63, 64, 65, 127, 128, 1_000, 123_456, 10_000_000] {
            let bv = Histogram::bucket_value(Histogram::index(v));
            assert!(bv <= v, "v={v} bv={bv}");
            if v >= 64 {
                let rel = (v - bv) as f64 / v as f64;
                assert!(rel < 1.0 / 32.0, "v={v} bv={bv} rel={rel}");
            }
        }
    }
}
