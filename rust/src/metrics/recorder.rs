//! The serving-metrics recorder: the paper's measurement set in one
//! struct — overall latency, pure model-compute latency, throughput in
//! user-item pairs/s, cache statistics, and network bytes (Table 3/4/5
//! columns come straight out of `snapshot()`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::cancel::{CancelCause, CancelStage, N_CAUSES, N_STAGES};
use crate::chaos::{ServeQuality, QUALITY_RUNGS};
use crate::obs::{StageKind, TraceContext, Tracer};
use crate::workload::{TenantId, MAX_TENANTS};

use super::Histogram;

/// Shared recorder; one per serving stack, updated by all workers.
pub struct Recorder {
    /// End-to-end request latency (µs) — "Overall Latency".
    pub overall: Histogram,
    /// Pure model computation latency (µs) — "Compute Latency".
    pub compute: Histogram,
    /// Feature-query stage latency (µs) — PDA ablations.
    pub feature: Histogram,
    /// Queueing delay before an executor picks the job up (µs).
    pub queueing: Histogram,
    /// Decoupled pipeline: stage-wait between a staged input entering
    /// the handoff queue and a compute submitter picking it up (µs).
    pub handoff: Histogram,
    /// Staging-arena growths observed (steady state must stay at 0 — a
    /// growth is a hidden pageable reallocation on the hot path).
    arena_growths: AtomicU64,
    /// Feature-miss coalescer: ids that rode another request's in-flight
    /// fetch instead of paying their own round-trip.
    fetch_coalesced: AtomicU64,
    /// Feature-miss coalescer: shared multiget batches executed.
    fetch_batches: AtomicU64,
    requests: AtomicU64,
    user_item_pairs: AtomicU64,
    network_bytes: AtomicU64,
    dropped: AtomicU64,
    /// Result-cache tier: requests answered from the cluster router's
    /// response cache without touching a replica.
    result_hits: AtomicU64,
    /// Result-cache tier: requests that had to compute.
    result_misses: AtomicU64,
    /// Result-cache tier: requests that rode another request's
    /// in-flight computation (single-flight coalescing).
    result_coalesced: AtomicU64,
    /// DSO batch coalescer: fill percentage of each packed remainder
    /// batch at launch (occupancy histogram; 100 = no padding).
    pub coalesce_occupancy: Histogram,
    /// DSO batch coalescer: real rows that shared a multi-request launch.
    coalesced_rows: AtomicU64,
    /// DSO batch coalescer: packed remainder batches launched.
    coalesce_batches: AtomicU64,
    /// Native CPU FKE: analytic FLOPs executed by kernel launches.
    fke_flops: AtomicU64,
    /// Native CPU FKE: attention tiles the mask schedule visited.
    fke_tiles_visited: AtomicU64,
    /// Native CPU FKE: attention tiles skipped as fully masked.
    fke_tiles_skipped: AtomicU64,
    /// SLA-miss attribution: misses whose deadline budget was dominated
    /// by each stage (mirrored from the tracer's exemplar verdicts).
    sla_miss_queue: AtomicU64,
    sla_miss_feature: AtomicU64,
    sla_miss_handoff: AtomicU64,
    sla_miss_compute: AtomicU64,
    sla_miss_other: AtomicU64,
    /// Degradation ladder: responses served at each [`ServeQuality`]
    /// rung (index = `ServeQuality::index()`). Under a healthy stack the
    /// whole histogram sits in `Full`; a fault storm shifts mass down
    /// the ladder instead of producing errors.
    quality: [AtomicU64; QUALITY_RUNGS],
    /// Cluster degradation: budget-aware re-dispatches after a replica
    /// failure (retry-with-backoff, not the hedge).
    retries: AtomicU64,
    /// Cluster degradation: hedged re-dispatches fired against a slow
    /// (browned-out) primary.
    hedges: AtomicU64,
    /// Hedges whose secondary answered first (the hedge paid off).
    hedge_wins: AtomicU64,
    /// Supervised recovery: worker panics caught by a supervisor that
    /// failed the in-flight request and respawned/continued the worker.
    worker_restarts: AtomicU64,
    /// Cooperative cancellation: drops per `{cause, stage}` pair
    /// (indices = `CancelCause::index` x `CancelStage::index`). Each
    /// fired token is recorded exactly once, at the drop site that
    /// resolved the request's reply — the matrix total therefore equals
    /// the number of requests that resolved `Error::Cancelled` (plus
    /// hedge losers, whose *dispatch* was the unit dropped).
    cancelled: [[AtomicU64; N_STAGES]; N_CAUSES],
    /// Cooperative cancellation: user-item pairs that were *not*
    /// computed thanks to the drops above (saved-work estimate).
    cancelled_saved_pairs: AtomicU64,
    /// Per-tenant views (flat arrays indexed by `TenantId::index`):
    /// completions, SLA misses, front-door sheds, quality ladder, and
    /// an end-to-end latency histogram per tenant. Single-tenant
    /// traffic lands entirely in slot 0.
    tenant_requests: [AtomicU64; MAX_TENANTS],
    tenant_sla_miss: [AtomicU64; MAX_TENANTS],
    tenant_shed: [AtomicU64; MAX_TENANTS],
    tenant_quality: [[AtomicU64; QUALITY_RUNGS]; MAX_TENANTS],
    tenant_overall: [Histogram; MAX_TENANTS],
    /// Optional request-scoped tracer (set once at startup; absent on
    /// the default path so tracing costs nothing when off). The u32 is
    /// the pid this recorder's traces carry (replica id; 0 standalone).
    tracer: OnceLock<(Arc<Tracer>, u32)>,
    started: Instant,
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

impl Recorder {
    pub fn new() -> Self {
        Recorder {
            overall: Histogram::new(),
            compute: Histogram::new(),
            feature: Histogram::new(),
            queueing: Histogram::new(),
            handoff: Histogram::new(),
            arena_growths: AtomicU64::new(0),
            fetch_coalesced: AtomicU64::new(0),
            fetch_batches: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            user_item_pairs: AtomicU64::new(0),
            network_bytes: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            result_hits: AtomicU64::new(0),
            result_misses: AtomicU64::new(0),
            result_coalesced: AtomicU64::new(0),
            coalesce_occupancy: Histogram::new(),
            coalesced_rows: AtomicU64::new(0),
            coalesce_batches: AtomicU64::new(0),
            fke_flops: AtomicU64::new(0),
            fke_tiles_visited: AtomicU64::new(0),
            fke_tiles_skipped: AtomicU64::new(0),
            sla_miss_queue: AtomicU64::new(0),
            sla_miss_feature: AtomicU64::new(0),
            sla_miss_handoff: AtomicU64::new(0),
            sla_miss_compute: AtomicU64::new(0),
            sla_miss_other: AtomicU64::new(0),
            quality: std::array::from_fn(|_| AtomicU64::new(0)),
            retries: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            hedge_wins: AtomicU64::new(0),
            worker_restarts: AtomicU64::new(0),
            cancelled: std::array::from_fn(|_| std::array::from_fn(|_| AtomicU64::new(0))),
            cancelled_saved_pairs: AtomicU64::new(0),
            tenant_requests: std::array::from_fn(|_| AtomicU64::new(0)),
            tenant_sla_miss: std::array::from_fn(|_| AtomicU64::new(0)),
            tenant_shed: std::array::from_fn(|_| AtomicU64::new(0)),
            tenant_quality: std::array::from_fn(|_| std::array::from_fn(|_| AtomicU64::new(0))),
            tenant_overall: std::array::from_fn(|_| Histogram::new()),
            tracer: OnceLock::new(),
            started: Instant::now(),
        }
    }

    // ---- request-scoped tracing (off unless a tracer is attached) ----

    /// Attach a tracer (first call wins). `pid` labels every trace this
    /// recorder finishes — the replica id in a cluster, 0 standalone.
    pub fn set_tracer(&self, tracer: Arc<Tracer>, pid: u32) {
        let _ = self.tracer.set((tracer, pid));
    }

    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.get().map(|(t, _)| t)
    }

    /// Chrome-trace pid this recorder's traces carry (0 when no tracer
    /// is attached or for a standalone stack).
    pub fn tracer_pid(&self) -> u32 {
        self.tracer.get().map(|(_, p)| *p).unwrap_or(0)
    }

    /// Begin a trace for one admitted request. Returns `None` when
    /// tracing is off (no tracer, or `trace_sample_n = 0`) — the hot
    /// path then carries no context and allocates nothing.
    #[inline]
    pub fn trace_begin(&self, request_id: u64, budget_us: u64) -> Option<TraceContext> {
        let (t, _) = self.tracer.get()?;
        t.begin(request_id, budget_us)
    }

    /// Finish a trace. On an SLA miss the tracer's attribution verdict
    /// (the stage that consumed the largest share of the deadline
    /// budget) is mirrored into the per-stage miss counters.
    pub fn trace_finish(&self, ctx: TraceContext, sla_missed: bool) {
        if let Some((t, pid)) = self.tracer.get() {
            let verdict = t.finish(ctx, *pid, sla_missed);
            if sla_missed {
                self.record_sla_attribution(verdict.unwrap_or(StageKind::Other));
            }
        }
    }

    /// One SLA miss attributed to `stage` (the dominant share of the
    /// deadline budget). Fetch folds into the feature stage and Launch
    /// into compute: that is where their wait is spent from the
    /// request's point of view.
    pub fn record_sla_attribution(&self, stage: StageKind) {
        let c = match stage {
            StageKind::Queue => &self.sla_miss_queue,
            StageKind::Feature | StageKind::Fetch => &self.sla_miss_feature,
            StageKind::Handoff => &self.sla_miss_handoff,
            StageKind::Compute | StageKind::Launch => &self.sla_miss_compute,
            StageKind::Cache | StageKind::Other => &self.sla_miss_other,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// SLA-miss attribution counters as
    /// (queue, feature, handoff, compute, other).
    pub fn sla_miss_attribution(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.sla_miss_queue.load(Ordering::Relaxed),
            self.sla_miss_feature.load(Ordering::Relaxed),
            self.sla_miss_handoff.load(Ordering::Relaxed),
            self.sla_miss_compute.load(Ordering::Relaxed),
            self.sla_miss_other.load(Ordering::Relaxed),
        )
    }

    /// Record a completed request: end-to-end micros + its candidate count
    /// (the paper counts throughput as user-item *pairs* per second).
    pub fn record_request(&self, overall_us: u64, m: usize) {
        self.overall.record(overall_us);
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.user_item_pairs.fetch_add(m as u64, Ordering::Relaxed);
    }

    pub fn record_compute(&self, us: u64) {
        self.compute.record(us);
    }

    pub fn record_feature(&self, us: u64) {
        self.feature.record(us);
    }

    pub fn record_queueing(&self, us: u64) {
        self.queueing.record(us);
    }

    /// Handoff stage-wait of one pipelined request, µs.
    pub fn record_handoff(&self, us: u64) {
        self.handoff.record(us);
    }

    /// `n` staging-arena growths observed while assembling one request.
    pub fn record_arena_growth(&self, n: u64) {
        self.arena_growths.fetch_add(n, Ordering::Relaxed);
    }

    /// One feature id rode another request's in-flight fetch.
    pub fn record_fetch_coalesced(&self) {
        self.fetch_coalesced.fetch_add(1, Ordering::Relaxed);
    }

    /// One shared feature multiget executed by the miss coalescer.
    pub fn record_fetch_batch(&self) {
        self.fetch_batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn arena_growths(&self) -> u64 {
        self.arena_growths.load(Ordering::Relaxed)
    }

    pub fn fetch_coalesced(&self) -> u64 {
        self.fetch_coalesced.load(Ordering::Relaxed)
    }

    pub fn fetch_batches(&self) -> u64 {
        self.fetch_batches.load(Ordering::Relaxed)
    }

    /// Bytes pulled over the (simulated) network — Table 3's
    /// "Network Utilization" numerator.
    pub fn record_network_bytes(&self, bytes: u64) {
        self.network_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_dropped(&self) {
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_result_hit(&self) {
        self.result_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_result_miss(&self) {
        self.result_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_result_coalesced(&self) {
        self.result_coalesced.fetch_add(1, Ordering::Relaxed);
    }

    // ---- degradation ladder / supervised recovery ----

    /// One response served (or shed) at `quality` on the degradation
    /// ladder. Recorded exactly once per finished request.
    pub fn record_quality(&self, quality: ServeQuality) {
        self.quality[quality.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// One budget-aware re-dispatch after a replica failure.
    pub fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// One hedged re-dispatch fired against a slow primary.
    pub fn record_hedge(&self) {
        self.hedges.fetch_add(1, Ordering::Relaxed);
    }

    /// A hedge's secondary answered first.
    pub fn record_hedge_win(&self) {
        self.hedge_wins.fetch_add(1, Ordering::Relaxed);
    }

    /// One supervised worker panic: request failed with a typed error,
    /// worker respawned/continued.
    pub fn record_worker_restart(&self) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
    }

    // ---- cooperative cancellation ----

    /// One cancelled unit of work dropped at `stage` because of
    /// `cause`, saving `saved_pairs` user-item pairs of compute.
    /// Call sites record each fired token exactly once — at the drop
    /// site that resolves the request's reply (or, for a hedge loser,
    /// where the winning arm abandons the losing dispatch) — so the
    /// matrix total matches token fires one-for-one.
    // lint: no_alloc — cancellation fast path at stage boundaries
    pub fn record_cancelled(&self, cause: CancelCause, stage: CancelStage, saved_pairs: u64) {
        self.cancelled[cause.index()][stage.index()].fetch_add(1, Ordering::Relaxed);
        self.cancelled_saved_pairs.fetch_add(saved_pairs, Ordering::Relaxed);
    }

    /// The full `{cause, stage}` cancellation matrix.
    pub fn cancelled_matrix(&self) -> [[u64; N_STAGES]; N_CAUSES] {
        std::array::from_fn(|c| {
            std::array::from_fn(|s| self.cancelled[c][s].load(Ordering::Relaxed))
        })
    }

    /// Total cancelled drops across all causes and stages.
    pub fn cancelled_total(&self) -> u64 {
        self.cancelled_matrix().iter().flatten().sum()
    }

    /// Cancelled drops for one cause, summed over stages.
    pub fn cancelled_by_cause(&self, cause: CancelCause) -> u64 {
        self.cancelled[cause.index()].iter().map(|s| s.load(Ordering::Relaxed)).sum()
    }

    /// User-item pairs whose compute was saved by cancellation.
    pub fn cancelled_saved_pairs(&self) -> u64 {
        self.cancelled_saved_pairs.load(Ordering::Relaxed)
    }

    // ---- per-tenant views ----

    /// One completed request for `tenant`: end-to-end micros plus
    /// whether it blew its (per-tenant) deadline budget.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn record_tenant_request(&self, tenant: TenantId, overall_us: u64, sla_missed: bool) {
        let i = tenant.index();
        self.tenant_requests[i].fetch_add(1, Ordering::Relaxed);
        self.tenant_overall[i].record(overall_us);
        if sla_missed {
            self.tenant_sla_miss[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One front-door shed (admission or controller) for `tenant`.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn record_tenant_shed(&self, tenant: TenantId) {
        self.tenant_shed[tenant.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// One response at `quality` on `tenant`'s degradation ladder.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn record_tenant_quality(&self, tenant: TenantId, quality: ServeQuality) {
        self.tenant_quality[tenant.index()][quality.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time per-tenant views, indexed by `TenantId::index`.
    /// Tenants that saw no traffic report all-zero rows (callers skip
    /// them when printing).
    pub fn tenant_counts(&self) -> [TenantCounts; MAX_TENANTS] {
        std::array::from_fn(|i| {
            let lat = self.tenant_overall[i].snapshot_counts();
            TenantCounts {
                requests: self.tenant_requests[i].load(Ordering::Relaxed),
                sla_miss: self.tenant_sla_miss[i].load(Ordering::Relaxed),
                shed: self.tenant_shed[i].load(Ordering::Relaxed),
                quality: std::array::from_fn(|q| {
                    self.tenant_quality[i][q].load(Ordering::Relaxed)
                }),
                overall_p50_us: lat.p50(),
                overall_p99_us: lat.p99(),
                overall_mean_us: lat.mean(),
            }
        })
    }

    /// Quality histogram, indexed by [`ServeQuality::index`].
    pub fn quality_counts(&self) -> [u64; QUALITY_RUNGS] {
        std::array::from_fn(|i| self.quality[i].load(Ordering::Relaxed))
    }

    /// Responses recorded below [`ServeQuality::Full`] (any degraded
    /// rung, including sheds).
    pub fn degraded_total(&self) -> u64 {
        self.quality_counts().iter().skip(1).sum()
    }

    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    pub fn hedges(&self) -> u64 {
        self.hedges.load(Ordering::Relaxed)
    }

    pub fn hedge_wins(&self) -> u64 {
        self.hedge_wins.load(Ordering::Relaxed)
    }

    pub fn worker_restarts(&self) -> u64 {
        self.worker_restarts.load(Ordering::Relaxed)
    }

    /// One DSO packed batch launched. The coalescer derives both values
    /// once and passes them through (`occupancy_pct` = real rows as a
    /// percentage of the profile; `shared_rows` = real rows iff the
    /// batch carried ≥ 2 requests, else 0), so this mirror can never
    /// drift from `Orchestrator::coalesce_stats`.
    pub fn record_coalesce_batch(&self, occupancy_pct: u64, shared_rows: u64) {
        self.coalesce_batches.fetch_add(1, Ordering::Relaxed);
        self.coalesce_occupancy.record(occupancy_pct);
        self.coalesced_rows.fetch_add(shared_rows, Ordering::Relaxed);
    }

    /// One native CPU FKE launch: analytic FLOPs executed plus the
    /// mask-aware attention-tile schedule's visit/skip counts (the
    /// engine derives all three once and passes them through, so this
    /// mirror can never drift from `CpuEngine::kernel_stats`).
    pub fn record_fke_launch(&self, flops: u64, tiles_visited: u64, tiles_skipped: u64) {
        self.fke_flops.fetch_add(flops, Ordering::Relaxed);
        self.fke_tiles_visited.fetch_add(tiles_visited, Ordering::Relaxed);
        self.fke_tiles_skipped.fetch_add(tiles_skipped, Ordering::Relaxed);
    }

    pub fn fke_flops(&self) -> u64 {
        self.fke_flops.load(Ordering::Relaxed)
    }

    pub fn fke_tiles_visited(&self) -> u64 {
        self.fke_tiles_visited.load(Ordering::Relaxed)
    }

    pub fn fke_tiles_skipped(&self) -> u64 {
        self.fke_tiles_skipped.load(Ordering::Relaxed)
    }

    pub fn coalesced_rows(&self) -> u64 {
        self.coalesced_rows.load(Ordering::Relaxed)
    }

    pub fn coalesce_batches(&self) -> u64 {
        self.coalesce_batches.load(Ordering::Relaxed)
    }

    pub fn result_hits(&self) -> u64 {
        self.result_hits.load(Ordering::Relaxed)
    }

    pub fn result_misses(&self) -> u64 {
        self.result_misses.load(Ordering::Relaxed)
    }

    pub fn result_coalesced(&self) -> u64 {
        self.result_coalesced.load(Ordering::Relaxed)
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn pairs(&self) -> u64 {
        self.user_item_pairs.load(Ordering::Relaxed)
    }

    pub fn network_bytes(&self) -> u64 {
        self.network_bytes.load(Ordering::Relaxed)
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Reset all series (between warmup and measurement).
    pub fn reset(&mut self) {
        self.overall.reset();
        self.compute.reset();
        self.feature.reset();
        self.queueing.reset();
        self.handoff.reset();
        self.arena_growths.store(0, Ordering::Relaxed);
        self.fetch_coalesced.store(0, Ordering::Relaxed);
        self.fetch_batches.store(0, Ordering::Relaxed);
        self.requests.store(0, Ordering::Relaxed);
        self.user_item_pairs.store(0, Ordering::Relaxed);
        self.network_bytes.store(0, Ordering::Relaxed);
        self.dropped.store(0, Ordering::Relaxed);
        self.result_hits.store(0, Ordering::Relaxed);
        self.result_misses.store(0, Ordering::Relaxed);
        self.result_coalesced.store(0, Ordering::Relaxed);
        self.coalesce_occupancy.reset();
        self.coalesced_rows.store(0, Ordering::Relaxed);
        self.coalesce_batches.store(0, Ordering::Relaxed);
        self.fke_flops.store(0, Ordering::Relaxed);
        self.fke_tiles_visited.store(0, Ordering::Relaxed);
        self.fke_tiles_skipped.store(0, Ordering::Relaxed);
        self.sla_miss_queue.store(0, Ordering::Relaxed);
        self.sla_miss_feature.store(0, Ordering::Relaxed);
        self.sla_miss_handoff.store(0, Ordering::Relaxed);
        self.sla_miss_compute.store(0, Ordering::Relaxed);
        self.sla_miss_other.store(0, Ordering::Relaxed);
        for q in &self.quality {
            q.store(0, Ordering::Relaxed);
        }
        self.retries.store(0, Ordering::Relaxed);
        self.hedges.store(0, Ordering::Relaxed);
        self.hedge_wins.store(0, Ordering::Relaxed);
        self.worker_restarts.store(0, Ordering::Relaxed);
        for row in &self.cancelled {
            for s in row {
                s.store(0, Ordering::Relaxed);
            }
        }
        self.cancelled_saved_pairs.store(0, Ordering::Relaxed);
        for i in 0..MAX_TENANTS {
            self.tenant_requests[i].store(0, Ordering::Relaxed);
            self.tenant_sla_miss[i].store(0, Ordering::Relaxed);
            self.tenant_shed[i].store(0, Ordering::Relaxed);
            for q in &self.tenant_quality[i] {
                q.store(0, Ordering::Relaxed);
            }
            self.tenant_overall[i].reset();
        }
        self.started = Instant::now();
    }

    /// Snapshot over an explicit wall-clock window (seconds). Each
    /// histogram is read through one [`Histogram::snapshot_counts`]
    /// pass, so the mean/p50/p99 triple of a series is internally
    /// consistent even while workers keep recording.
    pub fn snapshot_over(&self, elapsed_s: f64) -> MetricsSnapshot {
        let overall = self.overall.snapshot_counts();
        let compute = self.compute.snapshot_counts();
        let feature = self.feature.snapshot_counts();
        let queueing = self.queueing.snapshot_counts();
        let handoff = self.handoff.snapshot_counts();
        let occupancy = self.coalesce_occupancy.snapshot_counts();
        let (sla_q, sla_f, sla_h, sla_c, sla_o) = self.sla_miss_attribution();
        MetricsSnapshot {
            requests: self.requests(),
            pairs: self.pairs(),
            elapsed_s,
            throughput_pairs_per_s: self.pairs() as f64 / elapsed_s.max(1e-9),
            overall_mean_ms: overall.mean() / 1e3,
            overall_p50_ms: overall.p50() as f64 / 1e3,
            overall_p99_ms: overall.p99() as f64 / 1e3,
            compute_mean_ms: compute.mean() / 1e3,
            compute_p50_ms: compute.p50() as f64 / 1e3,
            compute_p99_ms: compute.p99() as f64 / 1e3,
            feature_mean_ms: feature.mean() / 1e3,
            feature_p50_ms: feature.p50() as f64 / 1e3,
            feature_p99_ms: feature.p99() as f64 / 1e3,
            queueing_mean_ms: queueing.mean() / 1e3,
            queueing_p50_ms: queueing.p50() as f64 / 1e3,
            queueing_p99_ms: queueing.p99() as f64 / 1e3,
            handoff_mean_ms: handoff.mean() / 1e3,
            handoff_p50_ms: handoff.p50() as f64 / 1e3,
            handoff_p99_ms: handoff.p99() as f64 / 1e3,
            arena_growths: self.arena_growths(),
            fetch_coalesced: self.fetch_coalesced(),
            fetch_batches: self.fetch_batches(),
            network_mb_per_s: self.network_bytes() as f64 / 1e6 / elapsed_s.max(1e-9),
            dropped: self.dropped(),
            result_hits: self.result_hits(),
            result_misses: self.result_misses(),
            result_coalesced: self.result_coalesced(),
            coalesced_rows: self.coalesced_rows(),
            coalesce_batches: self.coalesce_batches(),
            coalesce_occupancy_mean_pct: occupancy.mean(),
            coalesce_occupancy_p50_pct: occupancy.p50(),
            fke_flops: self.fke_flops(),
            fke_tiles_visited: self.fke_tiles_visited(),
            fke_tiles_skipped: self.fke_tiles_skipped(),
            sla_miss_queue: sla_q,
            sla_miss_feature: sla_f,
            sla_miss_handoff: sla_h,
            sla_miss_compute: sla_c,
            sla_miss_other: sla_o,
            quality: self.quality_counts(),
            retries: self.retries(),
            hedges: self.hedges(),
            hedge_wins: self.hedge_wins(),
            worker_restarts: self.worker_restarts(),
            cancelled_total: self.cancelled_total(),
            cancelled_saved_pairs: self.cancelled_saved_pairs(),
        }
    }

    /// Snapshot since construction / last reset.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.snapshot_over(self.started.elapsed().as_secs_f64())
    }
}

/// Point-in-time metrics view; all the paper's table columns.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub pairs: u64,
    pub elapsed_s: f64,
    pub throughput_pairs_per_s: f64,
    pub overall_mean_ms: f64,
    pub overall_p50_ms: f64,
    pub overall_p99_ms: f64,
    pub compute_mean_ms: f64,
    pub compute_p50_ms: f64,
    pub compute_p99_ms: f64,
    pub feature_mean_ms: f64,
    pub feature_p50_ms: f64,
    pub feature_p99_ms: f64,
    pub queueing_mean_ms: f64,
    pub queueing_p50_ms: f64,
    pub queueing_p99_ms: f64,
    /// Decoupled pipeline: stage-wait between feature handoff and
    /// compute pickup (0 in synchronous mode).
    pub handoff_mean_ms: f64,
    pub handoff_p50_ms: f64,
    pub handoff_p99_ms: f64,
    /// Staging-arena growths (steady state must report 0).
    pub arena_growths: u64,
    /// Feature-miss coalescer (0 unless `PdaConfig::fetch_coalesce`).
    pub fetch_coalesced: u64,
    pub fetch_batches: u64,
    pub network_mb_per_s: f64,
    pub dropped: u64,
    /// Cluster result-cache tier (0 outside a router context).
    pub result_hits: u64,
    pub result_misses: u64,
    pub result_coalesced: u64,
    /// DSO batch coalescer (0 unless `DsoConfig::coalesce` is on).
    pub coalesced_rows: u64,
    pub coalesce_batches: u64,
    pub coalesce_occupancy_mean_pct: f64,
    pub coalesce_occupancy_p50_pct: u64,
    /// Native CPU FKE kernel counters (0 on sim/PJRT backends).
    pub fke_flops: u64,
    pub fke_tiles_visited: u64,
    pub fke_tiles_skipped: u64,
    /// SLA-miss attribution: misses whose deadline budget was dominated
    /// by each stage (0 unless tracing is on and deadlines were missed).
    pub sla_miss_queue: u64,
    pub sla_miss_feature: u64,
    pub sla_miss_handoff: u64,
    pub sla_miss_compute: u64,
    pub sla_miss_other: u64,
    /// Degradation-ladder histogram, indexed by
    /// [`ServeQuality::index`] (Full → StaleFeatures →
    /// TruncatedCandidates → CachedResult → Shed). All mass in index 0
    /// on a healthy stack.
    pub quality: [u64; QUALITY_RUNGS],
    /// Cluster degradation: budget-aware retries after replica failures.
    pub retries: u64,
    /// Cluster degradation: hedged re-dispatches (and wins).
    pub hedges: u64,
    pub hedge_wins: u64,
    /// Supervised recovery: caught worker panics (request failed typed,
    /// worker kept alive).
    pub worker_restarts: u64,
    /// Cooperative cancellation: total drops across all `{cause,
    /// stage}` pairs (0 unless tokens fired), plus the user-item pairs
    /// of compute those drops saved.
    pub cancelled_total: u64,
    pub cancelled_saved_pairs: u64,
}

/// Point-in-time view of one tenant's traffic (see
/// [`Recorder::tenant_counts`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TenantCounts {
    pub requests: u64,
    pub sla_miss: u64,
    pub shed: u64,
    /// Quality-ladder histogram, indexed by `ServeQuality::index`.
    pub quality: [u64; QUALITY_RUNGS],
    pub overall_p50_us: u64,
    pub overall_p99_us: u64,
    pub overall_mean_us: f64,
}

impl TenantCounts {
    /// Completions + sheds: everything the tenant pushed at the router.
    pub fn submitted(&self) -> u64 {
        self.requests + self.shed
    }

    /// SLA-miss rate over completions (0 when idle).
    pub fn miss_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sla_miss as f64 / self.requests as f64
        }
    }

    /// Shed rate over everything submitted (0 when idle).
    pub fn shed_rate(&self) -> f64 {
        if self.submitted() == 0 {
            0.0
        } else {
            self.shed as f64 / self.submitted() as f64
        }
    }
}

impl MetricsSnapshot {
    /// Paper-style one-liner: "126.6 k | 13.2 ms | 46 ms | 34 MB/s".
    pub fn paper_row(&self) -> String {
        format!(
            "{:.1} k | {:.2} ms | {:.1} ms | {:.1} MB/s",
            self.throughput_pairs_per_s / 1e3,
            self.overall_mean_ms,
            self.overall_p99_ms,
            self.network_mb_per_s
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_counts_pairs_not_requests() {
        let r = Recorder::new();
        r.record_request(1_000, 128);
        r.record_request(1_000, 512);
        let s = r.snapshot_over(1.0);
        assert_eq!(s.requests, 2);
        assert_eq!(s.pairs, 640);
        assert!((s.throughput_pairs_per_s - 640.0).abs() < 1e-9);
    }

    #[test]
    fn network_utilization_mb_per_s() {
        let r = Recorder::new();
        r.record_network_bytes(46_300_000);
        let s = r.snapshot_over(1.0);
        assert!((s.network_mb_per_s - 46.3).abs() < 1e-6);
    }

    #[test]
    fn latencies_in_ms() {
        let r = Recorder::new();
        r.record_request(22_600, 1);
        r.record_compute(5_690);
        let s = r.snapshot_over(1.0);
        assert!((s.overall_mean_ms - 22.6).abs() < 0.1);
        assert!((s.compute_mean_ms - 5.69).abs() < 0.1);
    }

    #[test]
    fn reset_zeroes_everything() {
        let mut r = Recorder::new();
        r.record_request(100, 10);
        r.record_network_bytes(1000);
        r.record_dropped();
        r.record_result_hit();
        r.record_result_miss();
        r.record_result_coalesced();
        r.record_coalesce_batch(75, 6);
        r.record_handoff(1_000);
        r.record_arena_growth(2);
        r.record_fetch_coalesced();
        r.record_fetch_batch();
        r.record_fke_launch(1_000_000, 10, 5);
        r.record_sla_attribution(StageKind::Compute);
        r.record_sla_attribution(StageKind::Queue);
        r.record_quality(ServeQuality::StaleFeatures);
        r.record_retry();
        r.record_hedge();
        r.record_hedge_win();
        r.record_worker_restart();
        r.record_cancelled(CancelCause::Expired, CancelStage::Intake, 16);
        r.reset();
        let s = r.snapshot_over(1.0);
        assert_eq!(s.requests, 0);
        assert_eq!(s.pairs, 0);
        assert_eq!(s.dropped, 0);
        assert_eq!(r.network_bytes(), 0);
        assert_eq!((s.result_hits, s.result_misses, s.result_coalesced), (0, 0, 0));
        assert_eq!((s.coalesced_rows, s.coalesce_batches), (0, 0));
        assert_eq!(s.coalesce_occupancy_mean_pct, 0.0);
        assert_eq!(s.handoff_mean_ms, 0.0);
        assert_eq!((s.arena_growths, s.fetch_coalesced, s.fetch_batches), (0, 0, 0));
        assert_eq!((s.fke_flops, s.fke_tiles_visited, s.fke_tiles_skipped), (0, 0, 0));
        assert_eq!(r.sla_miss_attribution(), (0, 0, 0, 0, 0));
        assert_eq!(s.quality, [0; QUALITY_RUNGS]);
        assert_eq!((s.retries, s.hedges, s.hedge_wins, s.worker_restarts), (0, 0, 0, 0));
        assert_eq!((s.cancelled_total, s.cancelled_saved_pairs), (0, 0));
        assert_eq!(r.cancelled_matrix(), [[0; N_STAGES]; N_CAUSES]);
    }

    #[test]
    fn cancel_matrix_counts_per_cause_and_stage() {
        let r = Recorder::new();
        r.record_cancelled(CancelCause::Expired, CancelStage::Intake, 16);
        r.record_cancelled(CancelCause::Expired, CancelStage::Handoff, 8);
        r.record_cancelled(CancelCause::ClientGone, CancelStage::Frontend, 0);
        r.record_cancelled(CancelCause::HedgeLoser, CancelStage::Hedge, 4);
        let m = r.cancelled_matrix();
        assert_eq!(m[CancelCause::Expired.index()][CancelStage::Intake.index()], 1);
        assert_eq!(m[CancelCause::Expired.index()][CancelStage::Handoff.index()], 1);
        assert_eq!(m[CancelCause::ClientGone.index()][CancelStage::Frontend.index()], 1);
        assert_eq!(r.cancelled_by_cause(CancelCause::Expired), 2);
        assert_eq!(r.cancelled_by_cause(CancelCause::Shutdown), 0);
        assert_eq!(r.cancelled_total(), 4);
        let s = r.snapshot_over(1.0);
        assert_eq!(s.cancelled_total, 4);
        assert_eq!(s.cancelled_saved_pairs, 28);
    }

    #[test]
    fn quality_histogram_surfaces_in_snapshot() {
        let r = Recorder::new();
        r.record_quality(ServeQuality::Full);
        r.record_quality(ServeQuality::Full);
        r.record_quality(ServeQuality::StaleFeatures);
        r.record_quality(ServeQuality::TruncatedCandidates);
        r.record_quality(ServeQuality::CachedResult);
        r.record_quality(ServeQuality::Shed);
        let s = r.snapshot_over(1.0);
        assert_eq!(s.quality, [2, 1, 1, 1, 1]);
        assert_eq!(r.degraded_total(), 4, "everything below Full is degraded");
    }

    #[test]
    fn recovery_counters_surface_in_snapshot() {
        let r = Recorder::new();
        r.record_retry();
        r.record_retry();
        r.record_hedge();
        r.record_hedge_win();
        r.record_worker_restart();
        let s = r.snapshot_over(1.0);
        assert_eq!((s.retries, s.hedges, s.hedge_wins, s.worker_restarts), (2, 1, 1, 1));
    }

    #[test]
    fn sla_attribution_counters_surface_in_snapshot() {
        let r = Recorder::new();
        r.record_sla_attribution(StageKind::Compute);
        r.record_sla_attribution(StageKind::Launch); // folds into compute
        r.record_sla_attribution(StageKind::Feature);
        r.record_sla_attribution(StageKind::Fetch); // folds into feature
        r.record_sla_attribution(StageKind::Queue);
        r.record_sla_attribution(StageKind::Handoff);
        r.record_sla_attribution(StageKind::Other);
        let s = r.snapshot_over(1.0);
        assert_eq!(s.sla_miss_compute, 2);
        assert_eq!(s.sla_miss_feature, 2);
        assert_eq!(s.sla_miss_queue, 1);
        assert_eq!(s.sla_miss_handoff, 1);
        assert_eq!(s.sla_miss_other, 1);
    }

    #[test]
    fn per_stage_quantiles_surface_in_snapshot() {
        let r = Recorder::new();
        r.record_feature(2_000);
        r.record_queueing(1_000);
        r.record_handoff(3_000);
        let s = r.snapshot_over(1.0);
        assert!(s.feature_p50_ms >= 2.0 && s.feature_p99_ms >= 2.0, "{s:?}");
        assert!(s.queueing_p50_ms >= 1.0, "{s:?}");
        assert!(s.handoff_p50_ms >= 3.0, "{s:?}");
    }

    #[test]
    fn fke_counters_surface_in_snapshot() {
        let r = Recorder::new();
        r.record_fke_launch(2_000_000, 12, 4);
        r.record_fke_launch(1_000_000, 6, 2);
        let s = r.snapshot_over(1.0);
        assert_eq!(s.fke_flops, 3_000_000);
        assert_eq!((s.fke_tiles_visited, s.fke_tiles_skipped), (18, 6));
    }

    #[test]
    fn pipeline_counters_surface_in_snapshot() {
        let r = Recorder::new();
        r.record_handoff(2_000);
        r.record_handoff(4_000);
        r.record_arena_growth(1);
        r.record_fetch_coalesced();
        r.record_fetch_coalesced();
        r.record_fetch_batch();
        let s = r.snapshot_over(1.0);
        assert!((s.handoff_mean_ms - 3.0).abs() < 0.2, "{s:?}");
        assert!(s.handoff_p99_ms >= 3.5, "{s:?}");
        assert_eq!(s.arena_growths, 1);
        assert_eq!((s.fetch_coalesced, s.fetch_batches), (2, 1));
    }

    #[test]
    fn coalesce_counters_surface_in_snapshot() {
        let r = Recorder::new();
        // full batch whose 8 rows came from 2 requests: coalesced rows
        r.record_coalesce_batch(100, 8);
        // half-full single-request batch: occupancy only
        r.record_coalesce_batch(50, 0);
        let s = r.snapshot_over(1.0);
        assert_eq!(s.coalesce_batches, 2);
        assert_eq!(s.coalesced_rows, 8, "single-segment batches are not coalesced rows");
        assert!((s.coalesce_occupancy_mean_pct - 75.0).abs() < 1.0, "{s:?}");
        assert!(s.coalesce_occupancy_p50_pct >= 45 && s.coalesce_occupancy_p50_pct <= 100);
    }

    #[test]
    fn result_tier_counters_surface_in_snapshot() {
        let r = Recorder::new();
        r.record_result_hit();
        r.record_result_hit();
        r.record_result_miss();
        r.record_result_coalesced();
        let s = r.snapshot_over(1.0);
        assert_eq!((s.result_hits, s.result_misses, s.result_coalesced), (2, 1, 1));
    }

    #[test]
    fn tenant_views_track_independently() {
        let r = Recorder::new();
        r.record_tenant_request(TenantId(0), 10_000, false);
        r.record_tenant_request(TenantId(0), 60_000, true);
        r.record_tenant_request(TenantId(1), 5_000, false);
        r.record_tenant_shed(TenantId(1));
        r.record_tenant_quality(TenantId(0), ServeQuality::Full);
        r.record_tenant_quality(TenantId(1), ServeQuality::Shed);
        let t = r.tenant_counts();
        assert_eq!((t[0].requests, t[0].sla_miss, t[0].shed), (2, 1, 0));
        assert_eq!((t[1].requests, t[1].sla_miss, t[1].shed), (1, 0, 1));
        assert!((t[0].miss_rate() - 0.5).abs() < 1e-9);
        assert!((t[1].shed_rate() - 0.5).abs() < 1e-9);
        assert_eq!(t[0].quality[ServeQuality::Full.index()], 1);
        assert_eq!(t[1].quality[ServeQuality::Shed.index()], 1);
        assert!(t[0].overall_p99_us >= 50_000, "{t:?}");
        assert!(t[1].overall_p50_us >= 4_000, "{t:?}");
        assert_eq!(t[2], TenantCounts::default(), "idle tenants stay zero");
        // out-of-range ids fold into the last slot instead of panicking
        r.record_tenant_shed(TenantId(250));
        assert_eq!(r.tenant_counts()[MAX_TENANTS - 1].shed, 1);
    }

    #[test]
    fn reset_zeroes_tenant_views() {
        let mut r = Recorder::new();
        r.record_tenant_request(TenantId(1), 10_000, true);
        r.record_tenant_shed(TenantId(1));
        r.record_tenant_quality(TenantId(1), ServeQuality::Shed);
        r.reset();
        assert_eq!(r.tenant_counts()[1], TenantCounts::default());
    }

    #[test]
    fn paper_row_formats() {
        let r = Recorder::new();
        r.record_request(13_200, 126_600);
        r.record_network_bytes(34_000_000);
        let row = r.snapshot_over(1.0).paper_row();
        assert!(row.contains("126.6 k"), "{row}");
        assert!(row.contains("MB/s"), "{row}");
    }
}
