//! The serving-metrics recorder: the paper's measurement set in one
//! struct — overall latency, pure model-compute latency, throughput in
//! user-item pairs/s, cache statistics, and network bytes (Table 3/4/5
//! columns come straight out of `snapshot()`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::Histogram;

/// Shared recorder; one per serving stack, updated by all workers.
pub struct Recorder {
    /// End-to-end request latency (µs) — "Overall Latency".
    pub overall: Histogram,
    /// Pure model computation latency (µs) — "Compute Latency".
    pub compute: Histogram,
    /// Feature-query stage latency (µs) — PDA ablations.
    pub feature: Histogram,
    /// Queueing delay before an executor picks the job up (µs).
    pub queueing: Histogram,
    /// Decoupled pipeline: stage-wait between a staged input entering
    /// the handoff queue and a compute submitter picking it up (µs).
    pub handoff: Histogram,
    /// Staging-arena growths observed (steady state must stay at 0 — a
    /// growth is a hidden pageable reallocation on the hot path).
    arena_growths: AtomicU64,
    /// Feature-miss coalescer: ids that rode another request's in-flight
    /// fetch instead of paying their own round-trip.
    fetch_coalesced: AtomicU64,
    /// Feature-miss coalescer: shared multiget batches executed.
    fetch_batches: AtomicU64,
    requests: AtomicU64,
    user_item_pairs: AtomicU64,
    network_bytes: AtomicU64,
    dropped: AtomicU64,
    /// Result-cache tier: requests answered from the cluster router's
    /// response cache without touching a replica.
    result_hits: AtomicU64,
    /// Result-cache tier: requests that had to compute.
    result_misses: AtomicU64,
    /// Result-cache tier: requests that rode another request's
    /// in-flight computation (single-flight coalescing).
    result_coalesced: AtomicU64,
    /// DSO batch coalescer: fill percentage of each packed remainder
    /// batch at launch (occupancy histogram; 100 = no padding).
    pub coalesce_occupancy: Histogram,
    /// DSO batch coalescer: real rows that shared a multi-request launch.
    coalesced_rows: AtomicU64,
    /// DSO batch coalescer: packed remainder batches launched.
    coalesce_batches: AtomicU64,
    /// Native CPU FKE: analytic FLOPs executed by kernel launches.
    fke_flops: AtomicU64,
    /// Native CPU FKE: attention tiles the mask schedule visited.
    fke_tiles_visited: AtomicU64,
    /// Native CPU FKE: attention tiles skipped as fully masked.
    fke_tiles_skipped: AtomicU64,
    started: Instant,
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

impl Recorder {
    pub fn new() -> Self {
        Recorder {
            overall: Histogram::new(),
            compute: Histogram::new(),
            feature: Histogram::new(),
            queueing: Histogram::new(),
            handoff: Histogram::new(),
            arena_growths: AtomicU64::new(0),
            fetch_coalesced: AtomicU64::new(0),
            fetch_batches: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            user_item_pairs: AtomicU64::new(0),
            network_bytes: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            result_hits: AtomicU64::new(0),
            result_misses: AtomicU64::new(0),
            result_coalesced: AtomicU64::new(0),
            coalesce_occupancy: Histogram::new(),
            coalesced_rows: AtomicU64::new(0),
            coalesce_batches: AtomicU64::new(0),
            fke_flops: AtomicU64::new(0),
            fke_tiles_visited: AtomicU64::new(0),
            fke_tiles_skipped: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    /// Record a completed request: end-to-end micros + its candidate count
    /// (the paper counts throughput as user-item *pairs* per second).
    pub fn record_request(&self, overall_us: u64, m: usize) {
        self.overall.record(overall_us);
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.user_item_pairs.fetch_add(m as u64, Ordering::Relaxed);
    }

    pub fn record_compute(&self, us: u64) {
        self.compute.record(us);
    }

    pub fn record_feature(&self, us: u64) {
        self.feature.record(us);
    }

    pub fn record_queueing(&self, us: u64) {
        self.queueing.record(us);
    }

    /// Handoff stage-wait of one pipelined request, µs.
    pub fn record_handoff(&self, us: u64) {
        self.handoff.record(us);
    }

    /// `n` staging-arena growths observed while assembling one request.
    pub fn record_arena_growth(&self, n: u64) {
        self.arena_growths.fetch_add(n, Ordering::Relaxed);
    }

    /// One feature id rode another request's in-flight fetch.
    pub fn record_fetch_coalesced(&self) {
        self.fetch_coalesced.fetch_add(1, Ordering::Relaxed);
    }

    /// One shared feature multiget executed by the miss coalescer.
    pub fn record_fetch_batch(&self) {
        self.fetch_batches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn arena_growths(&self) -> u64 {
        self.arena_growths.load(Ordering::Relaxed)
    }

    pub fn fetch_coalesced(&self) -> u64 {
        self.fetch_coalesced.load(Ordering::Relaxed)
    }

    pub fn fetch_batches(&self) -> u64 {
        self.fetch_batches.load(Ordering::Relaxed)
    }

    /// Bytes pulled over the (simulated) network — Table 3's
    /// "Network Utilization" numerator.
    pub fn record_network_bytes(&self, bytes: u64) {
        self.network_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn record_dropped(&self) {
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_result_hit(&self) {
        self.result_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_result_miss(&self) {
        self.result_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_result_coalesced(&self) {
        self.result_coalesced.fetch_add(1, Ordering::Relaxed);
    }

    /// One DSO packed batch launched. The coalescer derives both values
    /// once and passes them through (`occupancy_pct` = real rows as a
    /// percentage of the profile; `shared_rows` = real rows iff the
    /// batch carried ≥ 2 requests, else 0), so this mirror can never
    /// drift from `Orchestrator::coalesce_stats`.
    pub fn record_coalesce_batch(&self, occupancy_pct: u64, shared_rows: u64) {
        self.coalesce_batches.fetch_add(1, Ordering::Relaxed);
        self.coalesce_occupancy.record(occupancy_pct);
        self.coalesced_rows.fetch_add(shared_rows, Ordering::Relaxed);
    }

    /// One native CPU FKE launch: analytic FLOPs executed plus the
    /// mask-aware attention-tile schedule's visit/skip counts (the
    /// engine derives all three once and passes them through, so this
    /// mirror can never drift from `CpuEngine::kernel_stats`).
    pub fn record_fke_launch(&self, flops: u64, tiles_visited: u64, tiles_skipped: u64) {
        self.fke_flops.fetch_add(flops, Ordering::Relaxed);
        self.fke_tiles_visited.fetch_add(tiles_visited, Ordering::Relaxed);
        self.fke_tiles_skipped.fetch_add(tiles_skipped, Ordering::Relaxed);
    }

    pub fn fke_flops(&self) -> u64 {
        self.fke_flops.load(Ordering::Relaxed)
    }

    pub fn fke_tiles_visited(&self) -> u64 {
        self.fke_tiles_visited.load(Ordering::Relaxed)
    }

    pub fn fke_tiles_skipped(&self) -> u64 {
        self.fke_tiles_skipped.load(Ordering::Relaxed)
    }

    pub fn coalesced_rows(&self) -> u64 {
        self.coalesced_rows.load(Ordering::Relaxed)
    }

    pub fn coalesce_batches(&self) -> u64 {
        self.coalesce_batches.load(Ordering::Relaxed)
    }

    pub fn result_hits(&self) -> u64 {
        self.result_hits.load(Ordering::Relaxed)
    }

    pub fn result_misses(&self) -> u64 {
        self.result_misses.load(Ordering::Relaxed)
    }

    pub fn result_coalesced(&self) -> u64 {
        self.result_coalesced.load(Ordering::Relaxed)
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn pairs(&self) -> u64 {
        self.user_item_pairs.load(Ordering::Relaxed)
    }

    pub fn network_bytes(&self) -> u64 {
        self.network_bytes.load(Ordering::Relaxed)
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Reset all series (between warmup and measurement).
    pub fn reset(&mut self) {
        self.overall.reset();
        self.compute.reset();
        self.feature.reset();
        self.queueing.reset();
        self.handoff.reset();
        self.arena_growths.store(0, Ordering::Relaxed);
        self.fetch_coalesced.store(0, Ordering::Relaxed);
        self.fetch_batches.store(0, Ordering::Relaxed);
        self.requests.store(0, Ordering::Relaxed);
        self.user_item_pairs.store(0, Ordering::Relaxed);
        self.network_bytes.store(0, Ordering::Relaxed);
        self.dropped.store(0, Ordering::Relaxed);
        self.result_hits.store(0, Ordering::Relaxed);
        self.result_misses.store(0, Ordering::Relaxed);
        self.result_coalesced.store(0, Ordering::Relaxed);
        self.coalesce_occupancy.reset();
        self.coalesced_rows.store(0, Ordering::Relaxed);
        self.coalesce_batches.store(0, Ordering::Relaxed);
        self.fke_flops.store(0, Ordering::Relaxed);
        self.fke_tiles_visited.store(0, Ordering::Relaxed);
        self.fke_tiles_skipped.store(0, Ordering::Relaxed);
        self.started = Instant::now();
    }

    /// Snapshot over an explicit wall-clock window (seconds).
    pub fn snapshot_over(&self, elapsed_s: f64) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests(),
            pairs: self.pairs(),
            elapsed_s,
            throughput_pairs_per_s: self.pairs() as f64 / elapsed_s.max(1e-9),
            overall_mean_ms: self.overall.mean() / 1e3,
            overall_p50_ms: self.overall.p50() as f64 / 1e3,
            overall_p99_ms: self.overall.p99() as f64 / 1e3,
            compute_mean_ms: self.compute.mean() / 1e3,
            compute_p50_ms: self.compute.p50() as f64 / 1e3,
            compute_p99_ms: self.compute.p99() as f64 / 1e3,
            feature_mean_ms: self.feature.mean() / 1e3,
            queueing_mean_ms: self.queueing.mean() / 1e3,
            handoff_mean_ms: self.handoff.mean() / 1e3,
            handoff_p99_ms: self.handoff.p99() as f64 / 1e3,
            arena_growths: self.arena_growths(),
            fetch_coalesced: self.fetch_coalesced(),
            fetch_batches: self.fetch_batches(),
            network_mb_per_s: self.network_bytes() as f64 / 1e6 / elapsed_s.max(1e-9),
            dropped: self.dropped(),
            result_hits: self.result_hits(),
            result_misses: self.result_misses(),
            result_coalesced: self.result_coalesced(),
            coalesced_rows: self.coalesced_rows(),
            coalesce_batches: self.coalesce_batches(),
            coalesce_occupancy_mean_pct: self.coalesce_occupancy.mean(),
            coalesce_occupancy_p50_pct: self.coalesce_occupancy.p50(),
            fke_flops: self.fke_flops(),
            fke_tiles_visited: self.fke_tiles_visited(),
            fke_tiles_skipped: self.fke_tiles_skipped(),
        }
    }

    /// Snapshot since construction / last reset.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.snapshot_over(self.started.elapsed().as_secs_f64())
    }
}

/// Point-in-time metrics view; all the paper's table columns.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub pairs: u64,
    pub elapsed_s: f64,
    pub throughput_pairs_per_s: f64,
    pub overall_mean_ms: f64,
    pub overall_p50_ms: f64,
    pub overall_p99_ms: f64,
    pub compute_mean_ms: f64,
    pub compute_p50_ms: f64,
    pub compute_p99_ms: f64,
    pub feature_mean_ms: f64,
    pub queueing_mean_ms: f64,
    /// Decoupled pipeline: stage-wait between feature handoff and
    /// compute pickup (0 in synchronous mode).
    pub handoff_mean_ms: f64,
    pub handoff_p99_ms: f64,
    /// Staging-arena growths (steady state must report 0).
    pub arena_growths: u64,
    /// Feature-miss coalescer (0 unless `PdaConfig::fetch_coalesce`).
    pub fetch_coalesced: u64,
    pub fetch_batches: u64,
    pub network_mb_per_s: f64,
    pub dropped: u64,
    /// Cluster result-cache tier (0 outside a router context).
    pub result_hits: u64,
    pub result_misses: u64,
    pub result_coalesced: u64,
    /// DSO batch coalescer (0 unless `DsoConfig::coalesce` is on).
    pub coalesced_rows: u64,
    pub coalesce_batches: u64,
    pub coalesce_occupancy_mean_pct: f64,
    pub coalesce_occupancy_p50_pct: u64,
    /// Native CPU FKE kernel counters (0 on sim/PJRT backends).
    pub fke_flops: u64,
    pub fke_tiles_visited: u64,
    pub fke_tiles_skipped: u64,
}

impl MetricsSnapshot {
    /// Paper-style one-liner: "126.6 k | 13.2 ms | 46 ms | 34 MB/s".
    pub fn paper_row(&self) -> String {
        format!(
            "{:.1} k | {:.2} ms | {:.1} ms | {:.1} MB/s",
            self.throughput_pairs_per_s / 1e3,
            self.overall_mean_ms,
            self.overall_p99_ms,
            self.network_mb_per_s
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_counts_pairs_not_requests() {
        let r = Recorder::new();
        r.record_request(1_000, 128);
        r.record_request(1_000, 512);
        let s = r.snapshot_over(1.0);
        assert_eq!(s.requests, 2);
        assert_eq!(s.pairs, 640);
        assert!((s.throughput_pairs_per_s - 640.0).abs() < 1e-9);
    }

    #[test]
    fn network_utilization_mb_per_s() {
        let r = Recorder::new();
        r.record_network_bytes(46_300_000);
        let s = r.snapshot_over(1.0);
        assert!((s.network_mb_per_s - 46.3).abs() < 1e-6);
    }

    #[test]
    fn latencies_in_ms() {
        let r = Recorder::new();
        r.record_request(22_600, 1);
        r.record_compute(5_690);
        let s = r.snapshot_over(1.0);
        assert!((s.overall_mean_ms - 22.6).abs() < 0.1);
        assert!((s.compute_mean_ms - 5.69).abs() < 0.1);
    }

    #[test]
    fn reset_zeroes_everything() {
        let mut r = Recorder::new();
        r.record_request(100, 10);
        r.record_network_bytes(1000);
        r.record_dropped();
        r.record_result_hit();
        r.record_result_miss();
        r.record_result_coalesced();
        r.record_coalesce_batch(75, 6);
        r.record_handoff(1_000);
        r.record_arena_growth(2);
        r.record_fetch_coalesced();
        r.record_fetch_batch();
        r.record_fke_launch(1_000_000, 10, 5);
        r.reset();
        let s = r.snapshot_over(1.0);
        assert_eq!(s.requests, 0);
        assert_eq!(s.pairs, 0);
        assert_eq!(s.dropped, 0);
        assert_eq!(r.network_bytes(), 0);
        assert_eq!((s.result_hits, s.result_misses, s.result_coalesced), (0, 0, 0));
        assert_eq!((s.coalesced_rows, s.coalesce_batches), (0, 0));
        assert_eq!(s.coalesce_occupancy_mean_pct, 0.0);
        assert_eq!(s.handoff_mean_ms, 0.0);
        assert_eq!((s.arena_growths, s.fetch_coalesced, s.fetch_batches), (0, 0, 0));
        assert_eq!((s.fke_flops, s.fke_tiles_visited, s.fke_tiles_skipped), (0, 0, 0));
    }

    #[test]
    fn fke_counters_surface_in_snapshot() {
        let r = Recorder::new();
        r.record_fke_launch(2_000_000, 12, 4);
        r.record_fke_launch(1_000_000, 6, 2);
        let s = r.snapshot_over(1.0);
        assert_eq!(s.fke_flops, 3_000_000);
        assert_eq!((s.fke_tiles_visited, s.fke_tiles_skipped), (18, 6));
    }

    #[test]
    fn pipeline_counters_surface_in_snapshot() {
        let r = Recorder::new();
        r.record_handoff(2_000);
        r.record_handoff(4_000);
        r.record_arena_growth(1);
        r.record_fetch_coalesced();
        r.record_fetch_coalesced();
        r.record_fetch_batch();
        let s = r.snapshot_over(1.0);
        assert!((s.handoff_mean_ms - 3.0).abs() < 0.2, "{s:?}");
        assert!(s.handoff_p99_ms >= 3.5, "{s:?}");
        assert_eq!(s.arena_growths, 1);
        assert_eq!((s.fetch_coalesced, s.fetch_batches), (2, 1));
    }

    #[test]
    fn coalesce_counters_surface_in_snapshot() {
        let r = Recorder::new();
        // full batch whose 8 rows came from 2 requests: coalesced rows
        r.record_coalesce_batch(100, 8);
        // half-full single-request batch: occupancy only
        r.record_coalesce_batch(50, 0);
        let s = r.snapshot_over(1.0);
        assert_eq!(s.coalesce_batches, 2);
        assert_eq!(s.coalesced_rows, 8, "single-segment batches are not coalesced rows");
        assert!((s.coalesce_occupancy_mean_pct - 75.0).abs() < 1.0, "{s:?}");
        assert!(s.coalesce_occupancy_p50_pct >= 45 && s.coalesce_occupancy_p50_pct <= 100);
    }

    #[test]
    fn result_tier_counters_surface_in_snapshot() {
        let r = Recorder::new();
        r.record_result_hit();
        r.record_result_hit();
        r.record_result_miss();
        r.record_result_coalesced();
        let s = r.snapshot_over(1.0);
        assert_eq!((s.result_hits, s.result_misses, s.result_coalesced), (2, 1, 1));
    }

    #[test]
    fn paper_row_formats() {
        let r = Recorder::new();
        r.record_request(13_200, 126_600);
        r.record_network_bytes(34_000_000);
        let row = r.snapshot_over(1.0).paper_row();
        assert!(row.contains("126.6 k"), "{row}");
        assert!(row.contains("MB/s"), "{row}");
    }
}
