//! The five invariant checkers, all running over the [`Model`].
//!
//! | checker      | invariant it encodes                                     |
//! |--------------|----------------------------------------------------------|
//! | `lock-order` | documented mutex acquisition orders (see [`ORDER_RULES`]) |
//! | `condvar`    | every condvar wait sits in a `while`/`loop`               |
//! | `no-alloc`   | `// lint: no_alloc` fns never allocate, even via callees  |
//! | `panic`      | hot-path dirs panic only with a tagged justification      |
//! | `unsafe`     | every `unsafe` carries a `// SAFETY:` comment             |
//!
//! Soundness stance: the lock walker models guards the way this codebase
//! writes them — `let g = x.lock().unwrap…();` binds a guard to the
//! enclosing brace scope, `drop(g)` releases it, anything else is a
//! statement-scoped temporary — and resolves calls interprocedurally
//! only when unambiguous (`self.f(…)` in the same file, or a crate-wide
//! unique free-function name outside [`METHOD_DENY`]). Unresolvable
//! constructs are skipped, so the checker can miss exotic violations;
//! it is tuned to never cry wolf on idiomatic code, which is what lets
//! CI fail hard on any finding.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::Kind;
use super::source::{FnItem, LockClass, Model, SourceFile};

/// One reported violation.
#[derive(Clone, Debug)]
pub struct Finding {
    pub checker: &'static str,
    pub file: String,
    pub line: u32,
    pub function: String,
    pub detail: String,
}

impl Finding {
    /// Stable identity for baselining: deliberately excludes the line
    /// number so unrelated edits don't churn the baseline file.
    pub fn fingerprint(&self) -> String {
        format!("{}|{}|{}|{}", self.checker, self.file, self.function, self.detail)
    }

    pub fn render(&self) -> String {
        format!(
            "{}:{} [{}] fn {}: {}",
            self.file, self.line, self.checker, self.function, self.detail
        )
    }
}

/// One observed (or inferred) lock acquisition edge: `held` was live
/// while `acquired` was taken, in `function` (through `via` if the
/// acquisition happened inside a resolved callee).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct LockEdge {
    pub file: String,
    pub function: String,
    pub held: LockClass,
    pub acquired: LockClass,
    pub via: Option<String>,
}

impl LockEdge {
    pub fn render(&self) -> String {
        let via = self.via.as_deref().map(|v| format!(" (via {v})")).unwrap_or_default();
        format!(
            "{} fn {}: {} -> {}{}",
            self.file,
            self.function,
            self.held.label(),
            self.acquired.label(),
            via
        )
    }
}

pub struct Analysis {
    pub findings: Vec<Finding>,
    pub edges: Vec<LockEdge>,
}

/// Directories whose non-test code falls under the panic policy.
const PANIC_POLICY_DIRS: &[&str] = &["server/", "dso/", "pda/", "cluster/", "fke/"];

/// Per-request hot-path functions that MUST carry the `// lint:
/// no_alloc` annotation. The no-alloc checker only verifies functions
/// that opted in; for the overload-controller surface (consulted on
/// every cluster submit) a silently dropped tag would silently drop
/// coverage, so the registry turns a missing tag into a finding.
const NO_ALLOC_REQUIRED: &[(&str, &str)] = &[
    ("cluster/controller.rs", "note_submit"),
    ("cluster/controller.rs", "note_outcome"),
    ("cluster/controller.rs", "blend_permille"),
    ("cluster/controller.rs", "shed_permille"),
    ("cluster/controller.rs", "decision"),
    ("cluster/controller.rs", "maybe_tick"),
    ("cluster/controller.rs", "tick"),
    ("cluster/tenant.rs", "budget_us"),
    ("cluster/tenant.rs", "weight"),
    ("cluster/mod.rs", "queue_permille"),
    // cooperative-cancellation surface: token checks sit at every stage
    // boundary and the per-drop accounting runs on the purge path
    ("src/cancel.rs", "cancel"),
    ("src/cancel.rs", "poll"),
    ("src/cancel.rs", "cause"),
    ("src/cancel.rs", "is_cancelled"),
    ("metrics/recorder.rs", "record_cancelled"),
];

/// A documented lock-order invariant: within the file matching
/// `file_suffix`, the `held` class must never be live when the
/// `acquired` class is taken. Cross-linked from the module docs of the
/// files they protect.
struct OrderRule {
    file_suffix: &'static str,
    held: &'static str,
    acquired: &'static str,
    doc: &'static str,
}

const ORDER_RULES: &[OrderRule] = &[
    OrderRule {
        file_suffix: "dso/coalescer.rs",
        held: "slots",
        acquired: "signal",
        doc: "slot locks are never held while taking the flusher signal mutex \
              (dso::coalescer module docs, 'Locking')",
    },
    OrderRule {
        file_suffix: "dso/coalescer.rs",
        held: "slots",
        acquired: "slots",
        doc: "per-profile slot locks never nest",
    },
    OrderRule {
        file_suffix: "pda/fetch_coalescer.rs",
        held: "shards",
        acquired: "signal",
        doc: "shard locks are never held while taking the flusher signal mutex \
              (pda::fetch_coalescer module docs, 'Locking')",
    },
    OrderRule {
        file_suffix: "pda/fetch_coalescer.rs",
        held: "shards",
        acquired: "shards",
        doc: "per-shard slot locks never nest",
    },
    OrderRule {
        file_suffix: "cache/sharded.rs",
        held: "shards",
        acquired: "shards",
        doc: "cache shard locks never nest (cache::sharded per-call single-shard discipline)",
    },
];

/// Method names never resolved to crate functions by bare-name
/// uniqueness: they shadow ubiquitous std/container methods, and
/// resolving them would fabricate call edges. Everything the order
/// rules need flows through `self.f(…)` calls, which bypass this list.
const METHOD_DENY: &[&str] = &[
    "lock", "try_lock", "read", "write", "wait", "wait_timeout", "notify_all", "notify_one",
    "unwrap", "expect", "unwrap_or", "unwrap_or_else", "unwrap_or_default", "ok_or_else",
    "get", "get_mut", "get_or_insert_with", "insert", "remove", "push", "push_back", "pop",
    "pop_front", "take", "take_if", "drop", "clone", "len", "is_empty", "contains", "entry",
    "or_default", "iter", "into_iter", "next", "send", "recv", "join", "spawn", "drain",
    "extend", "map", "and_then", "min", "max", "load", "store", "save", "new", "default",
    "from", "into", "with_capacity", "to_string", "to_owned", "to_vec", "fill", "resize",
    "clear", "last", "first", "flush", "run", "open", "close", "set", "begin", "finish",
    "record", "stats", "flow", "tick", "now", "elapsed", "abs", "wrapping_mul", "parse",
    "into_inner", "values", "keys", "contains_key", "fetch_add", "fetch_sub", "swap",
    "collect", "filter", "find", "any", "all", "count", "sum", "zip", "rev", "enumerate",
    "position", "sort", "sort_by", "retain", "split", "trim", "lines", "chars", "bytes",
];

/// A resolved call site inside a walked function.
struct CallSite {
    callee: (usize, usize),
    name: String,
    line: u32,
    /// Lock classes live at the moment of the call.
    held: Vec<LockClass>,
}

/// Everything one guard-tracking pass over a function body produces.
#[derive(Default)]
struct FnWalk {
    /// (held, acquired, line) — direct intra-function nesting.
    intra_edges: Vec<(LockClass, LockClass, u32)>,
    /// Every class this function acquires anywhere (guard state aside).
    acquires: BTreeSet<LockClass>,
    calls: Vec<CallSite>,
    /// Banned allocation constructs found directly in the body.
    alloc_tokens: Vec<(String, u32)>,
    /// Body contains a tagged (`// lint: supervisor`) `catch_unwind`.
    supervised: bool,
    /// Body drops jobs (`record_dropped` accounting call).
    drops_job: bool,
    /// Body references a `reply` channel anywhere.
    mentions_reply: bool,
    /// Body resolves it (`reply.send(..)`).
    resolves_reply: bool,
}

struct Guard {
    name: Option<String>,
    class: LockClass,
    depth: usize,
    /// `drop(g)` inside a deeper block (typically a branch that then
    /// `return`s or reacquires) suspends the guard until that block
    /// closes, rather than releasing it outright — the fall-through
    /// path still holds the lock. Errs toward reporting.
    suspended_at: Option<usize>,
}

impl Guard {
    fn live(&self) -> bool {
        self.suspended_at.is_none()
    }
}

/// Run every checker. `src_only` findings (all but `unsafe`) skip test
/// code; the unsafe checker covers test code and `tests/` roots too.
pub fn check(model: &Model) -> Analysis {
    let mut findings: Vec<Finding> = Vec::new();
    let mut edge_set: BTreeSet<LockEdge> = BTreeSet::new();

    // ---- pass 1: per-fn walks (also emits condvar + panic findings)
    let mut walks: BTreeMap<(usize, usize), FnWalk> = BTreeMap::new();
    for (fi, file) in model.files.iter().enumerate() {
        if file.integration_test {
            continue;
        }
        for (ni, item) in file.fns.iter().enumerate() {
            if item.is_test {
                continue;
            }
            let w = walk_fn(model, fi, item, &mut findings);
            // a supervisor that drops jobs while holding a `reply`
            // channel must also resolve it — a cleanup path that counts
            // the drop but never sends leaves the submitter blocked on
            // a receiver nobody will ever wake
            if w.supervised && w.drops_job && w.mentions_reply && !w.resolves_reply {
                findings.push(Finding {
                    checker: "supervisor",
                    file: file.path.clone(),
                    line: item.line,
                    function: item.name.clone(),
                    detail: "supervised worker drops jobs (`record_dropped`) and \
                             handles a `reply` channel but never resolves it — \
                             send a typed error (`reply.send(Err(..))`) before \
                             dropping the job"
                        .to_string(),
                });
            }
            walks.insert((fi, ni), w);
        }
    }

    // ---- fixpoint: transitive lock + allocation effects
    let mut lock_eff: BTreeMap<(usize, usize), BTreeSet<LockClass>> =
        walks.iter().map(|(k, w)| (*k, w.acquires.clone())).collect();
    let mut alloc_eff: BTreeMap<(usize, usize), Option<String>> = walks
        .iter()
        .map(|(k, w)| (*k, w.alloc_tokens.first().map(|(d, _)| d.clone())))
        .collect();
    loop {
        let mut changed = false;
        for (k, w) in &walks {
            for call in &w.calls {
                let callee_locks = lock_eff.get(&call.callee).cloned().unwrap_or_default();
                let mine = lock_eff.entry(*k).or_default();
                for c in callee_locks {
                    changed |= mine.insert(c);
                }
                let callee_alloc = alloc_eff.get(&call.callee).cloned().flatten();
                if let Some(d) = callee_alloc {
                    let mine = alloc_eff.entry(*k).or_default();
                    if mine.is_none() {
                        *mine = Some(format!("{} -> {}", call.name, d));
                        changed = true;
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }

    // ---- pass 2: edges, order rules, no_alloc
    let mut order_fps: BTreeSet<String> = BTreeSet::new();
    for (&(fi, ni), w) in &walks {
        let file = &model.files[fi];
        let item = &file.fns[ni];
        for (held, acq, line) in &w.intra_edges {
            let edge = LockEdge {
                file: file.path.clone(),
                function: item.name.clone(),
                held: held.clone(),
                acquired: acq.clone(),
                via: None,
            };
            check_order(&edge, *line, &mut findings, &mut order_fps);
            edge_set.insert(edge);
        }
        for call in &w.calls {
            let callee_locks = lock_eff.get(&call.callee).cloned().unwrap_or_default();
            for acq in &callee_locks {
                for held in &call.held {
                    let edge = LockEdge {
                        file: file.path.clone(),
                        function: item.name.clone(),
                        held: held.clone(),
                        acquired: acq.clone(),
                        via: Some(call.name.clone()),
                    };
                    check_order(&edge, call.line, &mut findings, &mut order_fps);
                    edge_set.insert(edge);
                }
            }
        }
        if item.no_alloc {
            for (what, line) in &w.alloc_tokens {
                findings.push(Finding {
                    checker: "no-alloc",
                    file: file.path.clone(),
                    line: *line,
                    function: item.name.clone(),
                    detail: format!("`{what}` inside a `// lint: no_alloc` function"),
                });
            }
            for call in &w.calls {
                if let Some(d) = alloc_eff.get(&call.callee).cloned().flatten() {
                    findings.push(Finding {
                        checker: "no-alloc",
                        file: file.path.clone(),
                        line: call.line,
                        function: item.name.clone(),
                        detail: format!(
                            "calls `{}()` which allocates ({d}) inside a \
                             `// lint: no_alloc` function",
                            call.name
                        ),
                    });
                }
            }
        }
    }

    // ---- required no_alloc coverage on the controller hot path
    for file in &model.files {
        if file.integration_test {
            continue;
        }
        for &(suffix, fname) in NO_ALLOC_REQUIRED {
            if !file.path.ends_with(suffix) {
                continue;
            }
            for item in &file.fns {
                if item.name == fname && !item.is_test && !item.no_alloc {
                    findings.push(Finding {
                        checker: "no-alloc",
                        file: file.path.clone(),
                        line: item.line,
                        function: item.name.clone(),
                        detail: format!(
                            "hot-path fn `{fname}` must carry `// lint: no_alloc` \
                             (required registry entry for {suffix})"
                        ),
                    });
                }
            }
        }
    }

    // ---- unsafe hygiene (all files, test code included)
    for file in &model.files {
        check_unsafe(file, &mut findings);
    }

    findings.sort_by(|a, b| {
        (&a.file, a.line, a.checker).cmp(&(&b.file, b.line, b.checker))
    });
    Analysis { findings, edges: edge_set.into_iter().collect() }
}

fn check_order(
    edge: &LockEdge,
    line: u32,
    findings: &mut Vec<Finding>,
    seen: &mut BTreeSet<String>,
) {
    for rule in ORDER_RULES {
        let (
            LockClass::Field { file: hf, field: held },
            LockClass::Field { file: af, field: acq },
        ) = (&edge.held, &edge.acquired)
        else {
            continue;
        };
        if held == rule.held
            && acq == rule.acquired
            && hf.ends_with(rule.file_suffix)
            && af.ends_with(rule.file_suffix)
        {
            let via = edge.via.as_deref().map(|v| format!(" (via `{v}()`)")).unwrap_or_default();
            let f = Finding {
                checker: "lock-order",
                file: edge.file.clone(),
                line,
                function: edge.function.clone(),
                detail: format!(
                    "acquires `{acq}` while holding `{held}`{via} — {}",
                    rule.doc
                ),
            };
            if seen.insert(f.fingerprint()) {
                findings.push(f);
            }
        }
    }
}

/// Is this file subject to the panic policy?
fn panic_policy_file(path: &str) -> bool {
    PANIC_POLICY_DIRS.iter().any(|d| path.contains(&format!("src/{d}")))
}

/// The single guard-tracking walk over one function body. Emits condvar
/// and panic findings inline; returns the lock/alloc/call summary.
fn walk_fn(
    model: &Model,
    fi: usize,
    item: &FnItem,
    findings: &mut Vec<Finding>,
) -> FnWalk {
    let file = &model.files[fi];
    let toks = &file.toks;
    let (body_open, body_close) = item.body;
    let policy = panic_policy_file(&file.path);

    let mut w = FnWalk::default();
    let mut guards: Vec<Guard> = Vec::new();
    let mut aliases: Vec<(String, LockClass, usize)> = Vec::new();
    let mut scope_opens: Vec<usize> = Vec::new();
    let mut depth = 0usize;
    let mut at_stmt_start = true;
    let mut stmt_head: Option<String> = None;
    let mut alias_pending: Option<(String, LockClass)> = None;
    let mut stmt_bound_guard = false;

    let mut j = body_open + 1;
    while j < body_close {
        let t = &toks[j];
        if t.kind == Kind::Comment {
            j += 1;
            continue;
        }
        let was_stmt_start = at_stmt_start;
        at_stmt_start = false;
        match t.kind {
            Kind::Punct if t.text == "{" => {
                scope_opens.push(j);
                depth += 1;
                guards.retain(|g| g.name.is_some());
                stmt_head = None;
                alias_pending = None;
                stmt_bound_guard = false;
                at_stmt_start = true;
            }
            Kind::Punct if t.text == "}" => {
                scope_opens.pop();
                depth = depth.saturating_sub(1);
                guards.retain(|g| g.name.is_some() && g.depth <= depth);
                for g in guards.iter_mut() {
                    if g.suspended_at.is_some_and(|d| depth < d) {
                        g.suspended_at = None;
                    }
                }
                aliases.retain(|(_, _, d)| *d <= depth);
                stmt_head = None;
                alias_pending = None;
                stmt_bound_guard = false;
                at_stmt_start = true;
            }
            Kind::Punct if t.text == ";" => {
                guards.retain(|g| g.name.is_some());
                if !stmt_bound_guard {
                    if let Some((name, class)) = alias_pending.take() {
                        aliases.push((name, class, depth));
                    }
                }
                stmt_head = None;
                alias_pending = None;
                stmt_bound_guard = false;
                at_stmt_start = true;
            }
            Kind::Ident if t.text == "let" => {
                // `let [mut] NAME =` — anything fancier is not a binding
                // we track (tuple patterns, typed lets)
                if let Some(mut k) = file.nc(j + 1) {
                    if file.is_ident(k, "mut") {
                        if let Some(k2) = file.nc(k + 1) {
                            k = k2;
                        }
                    }
                    if toks[k].kind == Kind::Ident {
                        if let Some(eq) = file.nc(k + 1) {
                            if file.is_punct(eq, "=") {
                                stmt_head = Some(toks[k].text.clone());
                                alias_pending = None;
                                j = eq + 1;
                                continue;
                            }
                        }
                    }
                }
            }
            Kind::Ident if t.text == "for" => {
                // `for PAT in HEADER {` — alias pattern idents to a lock
                // class referenced by the header (e.g. `for slot in
                // &self.slots`), scoped to the loop body.
                let mut pat: Vec<String> = Vec::new();
                let mut k = j + 1;
                let mut steps = 0;
                while k < body_close && steps < 16 && !file.is_ident(k, "in") {
                    if toks[k].kind == Kind::Ident && toks[k].text != "mut" {
                        pat.push(toks[k].text.clone());
                    }
                    k += 1;
                    steps += 1;
                }
                if k < body_close && file.is_ident(k, "in") {
                    let mut h = k + 1;
                    let mut hsteps = 0;
                    let mut class: Option<LockClass> = None;
                    let mut pdepth = 0i64;
                    while h < body_close && hsteps < 64 {
                        if toks[h].kind == Kind::Punct {
                            match toks[h].text.as_str() {
                                "(" | "[" => pdepth += 1,
                                ")" | "]" => pdepth -= 1,
                                "{" if pdepth == 0 => break,
                                _ => {}
                            }
                        } else if toks[h].kind == Kind::Ident && class.is_none() {
                            class = lookup_lock_name(model, file, &aliases, &toks[h].text);
                        }
                        h += 1;
                        hsteps += 1;
                    }
                    if let Some(c) = class {
                        for p in pat {
                            aliases.push((p, c.clone(), depth + 1));
                        }
                    }
                }
            }
            Kind::Ident if t.text == "drop" => {
                // `drop(name)`: at the guard's own depth this is an
                // unconditional release; inside a deeper block it only
                // suspends the guard for the rest of that branch.
                if let Some(op) = file.nc(j + 1) {
                    if file.is_punct(op, "(") {
                        if let Some(arg) = file.nc(op + 1) {
                            if toks[arg].kind == Kind::Ident {
                                if let Some(cl) = file.nc(arg + 1) {
                                    if file.is_punct(cl, ")") {
                                        let name = &toks[arg].text;
                                        let mut removed = false;
                                        guards.retain(|g| {
                                            let hit = g.name.as_deref() == Some(name.as_str())
                                                && g.depth == depth;
                                            removed |= hit;
                                            !hit
                                        });
                                        if !removed {
                                            for g in guards.iter_mut() {
                                                if g.name.as_deref() == Some(name.as_str()) {
                                                    g.suspended_at = Some(depth);
                                                }
                                            }
                                        }
                                        j = cl + 1;
                                        continue;
                                    }
                                }
                            }
                        }
                    }
                }
            }
            Kind::Ident
                if (t.text == "lock" || t.text == "read" || t.text == "write")
                    && prev_is_dot(file, j)
                    && next_is(file, j, "(") =>
            {
                let class = receiver_class(model, file, &aliases, j, body_open);
                // `.read()`/`.write()` only count when the receiver is a
                // known lock — they shadow io method names otherwise
                let counts = t.text == "lock" || matches!(class, LockClass::Field { .. });
                if counts {
                    for g in guards.iter().filter(|g| g.live()) {
                        w.intra_edges.push((g.class.clone(), class.clone(), t.line));
                    }
                    w.acquires.insert(class.clone());
                    let persists = stmt_head.is_some() && guard_persists(file, j, body_close);
                    if persists {
                        let name = stmt_head.clone();
                        guards.retain(|g| g.name != name);
                        guards.push(Guard { name, class, depth, suspended_at: None });
                        stmt_bound_guard = true;
                    } else {
                        guards.push(Guard { name: None, class, depth, suspended_at: None });
                    }
                }
            }
            Kind::Ident
                if (t.text == "wait" || t.text == "wait_timeout") && prev_is_dot(file, j) =>
            {
                if next_is(file, j, "(") && wait_has_args(file, j, body_close) {
                    let recv = nearest_receiver_ident(file, j, body_open);
                    let is_condvar = recv.as_deref().is_some_and(|r| {
                        model.condvar_names.contains(r)
                            || matches!(r, "cv" | "cond" | "condvar")
                    });
                    if is_condvar && !wait_is_loop_guarded(file, &scope_opens, body_open) {
                        findings.push(Finding {
                            checker: "condvar",
                            file: file.path.clone(),
                            line: t.line,
                            function: item.name.clone(),
                            detail: format!(
                                "condvar `{}.{}()` outside a `while`/`loop` — a wait \
                                 must re-check its predicate (spurious wakeups, racing \
                                 notifies)",
                                recv.as_deref().unwrap_or("?"),
                                t.text
                            ),
                        });
                    }
                }
            }
            Kind::Ident if policy && t.text == "catch_unwind" => {
                // a bare catch_unwind hides panics; in hot-path code it is
                // only legitimate as a *supervisor* — a site that fails the
                // in-flight request with a typed error and keeps the worker
                // alive. The tag documents (and CI-enforces) that contract.
                // Span 5: supervisor tags head multi-line comment blocks
                // that explain the recovery contract.
                if file.comment_near(t.line, 5, "lint: supervisor") {
                    w.supervised = true;
                } else {
                    findings.push(Finding {
                        checker: "supervisor",
                        file: file.path.clone(),
                        line: t.line,
                        function: item.name.clone(),
                        detail: "untagged `catch_unwind` in hot-path code — a \
                                 supervised worker must fail the in-flight request \
                                 with a typed error and keep draining; tag the site \
                                 `// lint: supervisor <why>` once it does"
                            .to_string(),
                    });
                }
            }
            Kind::Ident if policy && is_panic_token(file, j) => {
                let what = panic_label(file, j);
                if !file.comment_near(t.line, 3, "lint: allow(panic)") {
                    findings.push(Finding {
                        checker: "panic",
                        file: file.path.clone(),
                        line: t.line,
                        function: item.name.clone(),
                        detail: format!(
                            "untagged `{what}` in hot-path code — tag it \
                             `// lint: allow(panic) <why>` or handle the failure \
                             (lock guards: prefer `.unwrap_or_else(|e| e.into_inner())`)"
                        ),
                    });
                }
            }
            Kind::Ident if was_stmt_start && assign_target(file, j) => {
                // `name = …;` at statement start — a reassignment like
                // `parked = self.signal.lock().unwrap();` rebinds the guard
                stmt_head = Some(t.text.clone());
                alias_pending = None;
            }
            _ => {}
        }
        // allocation constructs (collected for every fn; only reported
        // for `no_alloc`-annotated ones)
        if let Some(what) = banned_alloc_at(file, j) {
            w.alloc_tokens.push((what, t.line));
        }
        // supervisor reply-resolution facts (consumed by check())
        if t.kind == Kind::Ident {
            if t.text == "record_dropped" {
                w.drops_job = true;
            } else if t.text == "reply" {
                w.mentions_reply = true;
                if let Some(d) = file.nc(j + 1) {
                    if file.is_punct(d, ".") {
                        if let Some(m) = file.nc(d + 1) {
                            if file.is_ident(m, "send") {
                                w.resolves_reply = true;
                            }
                        }
                    }
                }
            }
        }
        // call-site resolution
        if t.kind == Kind::Ident && next_is(file, j, "(") {
            if let Some(callee) = resolve_call(model, file, fi, j) {
                let held: Vec<LockClass> =
                    guards.iter().filter(|g| g.live()).map(|g| g.class.clone()).collect();
                w.calls.push(CallSite {
                    callee,
                    name: t.text.clone(),
                    line: t.line,
                    held,
                });
            }
        }
        // alias candidate: first lock-ish ident in a `let NAME = …` stmt
        if stmt_head.is_some()
            && alias_pending.is_none()
            && !stmt_bound_guard
            && t.kind == Kind::Ident
        {
            if let Some(c) = lookup_lock_name(model, file, &aliases, &t.text) {
                alias_pending = Some((stmt_head.clone().unwrap_or_default(), c));
            }
        }
        j += 1;
    }
    w
}

/// `name = …` (not `==`, not a match arm's `=>`) at statement start.
fn assign_target(file: &SourceFile, j: usize) -> bool {
    let Some(eq) = file.nc(j + 1) else { return false };
    if !file.is_punct(eq, "=") {
        return false;
    }
    match file.nc(eq + 1) {
        Some(n) => !file.is_punct(n, "=") && !file.is_punct(n, ">"),
        None => false,
    }
}

/// Does `name` denote a lock (field of this file, or live alias)?
fn lookup_lock_name(
    model: &Model,
    file: &SourceFile,
    aliases: &[(String, LockClass, usize)],
    name: &str,
) -> Option<LockClass> {
    if let Some((_, c, _)) = aliases.iter().rev().find(|(n, _, _)| n == name) {
        return Some(c.clone());
    }
    if model.lock_fields.contains(&(file.path.clone(), name.to_string())) {
        return Some(LockClass::Field { file: file.path.clone(), field: name.to_string() });
    }
    None
}

fn prev_is_dot(file: &SourceFile, j: usize) -> bool {
    j > 0 && file.pc(j - 1).is_some_and(|p| file.is_punct(p, "."))
}

fn next_is(file: &SourceFile, j: usize, p: &str) -> bool {
    file.nc(j + 1).is_some_and(|n| file.is_punct(n, p))
}

/// `.wait(` with at least one argument (excludes `Barrier::wait()`).
fn wait_has_args(file: &SourceFile, j: usize, hi: usize) -> bool {
    let Some(op) = file.nc(j + 1) else { return false };
    match file.nc(op + 1) {
        Some(a) if a < hi => !file.is_punct(a, ")"),
        _ => false,
    }
}

/// Walk the receiver chain left of the `.` before token `j`; the first
/// ident that names a lock field/alias decides the class.
fn receiver_class(
    model: &Model,
    file: &SourceFile,
    aliases: &[(String, LockClass, usize)],
    j: usize,
    lo: usize,
) -> LockClass {
    let chain = receiver_chain(file, j, lo);
    for name in &chain {
        if let Some(c) = lookup_lock_name(model, file, aliases, name) {
            return c;
        }
    }
    let label = chain
        .iter()
        .find(|n| *n != "self")
        .cloned()
        .unwrap_or_else(|| "expr".to_string());
    LockClass::Other { name: label }
}

fn nearest_receiver_ident(file: &SourceFile, j: usize, lo: usize) -> Option<String> {
    receiver_chain(file, j, lo).into_iter().next()
}

/// Idents of the chained receiver expression ending at the `.` before
/// token `j`, nearest first: `self.slots.get(&p)?.lock()` → `[get,
/// slots, self]` (balanced groups are skipped, `?` is transparent).
fn receiver_chain(file: &SourceFile, j: usize, lo: usize) -> Vec<String> {
    let toks = &file.toks;
    let mut out = Vec::new();
    // position of the `.`
    let Some(mut i) = file.pc(j.saturating_sub(1)) else { return out };
    if !file.is_punct(i, ".") {
        return out;
    }
    while i > lo && out.len() < 12 {
        let Some(p) = (i > 0).then(|| file.pc(i - 1)).flatten() else { break };
        if p < lo {
            break;
        }
        match toks[p].kind {
            Kind::Ident => {
                out.push(toks[p].text.clone());
                let Some(q) = (p > 0).then(|| file.pc(p - 1)).flatten() else { break };
                if q >= lo && (file.is_punct(q, ".") || file.is_punct(q, ":")) {
                    i = q;
                    if file.is_punct(q, ":") {
                        // `::` — step past both colons
                        match (q > 0).then(|| file.pc(q - 1)).flatten() {
                            Some(q2) if q2 >= lo && file.is_punct(q2, ":") => i = q2,
                            _ => break,
                        }
                    }
                } else {
                    break;
                }
            }
            Kind::Punct if toks[p].text == ")" || toks[p].text == "]" => {
                let (close, open) = if toks[p].text == ")" { (")", "(") } else { ("]", "[") };
                let mut d = 1i64;
                let mut q = p;
                while q > lo && d > 0 {
                    q -= 1;
                    if toks[q].kind == Kind::Punct {
                        if toks[q].text == close {
                            d += 1;
                        } else if toks[q].text == open {
                            d -= 1;
                        }
                    }
                }
                i = q;
            }
            Kind::Punct if toks[p].text == "?" => {
                i = p;
            }
            _ => break,
        }
    }
    out
}

/// After `lock` at `j`: does the statement end right after the unwrap
/// chain (→ the `let`/assignment target is the guard itself), or does
/// the chain continue into field/method access (→ the guard is a
/// statement temporary)?
fn guard_persists(file: &SourceFile, j: usize, hi: usize) -> bool {
    let toks = &file.toks;
    // skip the `()` of lock
    let Some(op) = file.nc(j + 1) else { return false };
    let Some(mut k) = skip_balanced(file, op, hi) else { return false };
    loop {
        let Some(dot) = file.nc(k) else { return false };
        if dot >= hi {
            return false;
        }
        if file.is_punct(dot, ";") {
            return true;
        }
        if !file.is_punct(dot, ".") {
            return false;
        }
        let Some(m) = file.nc(dot + 1) else { return false };
        if toks[m].kind != Kind::Ident
            || !["unwrap", "expect", "unwrap_or_else"].contains(&toks[m].text.as_str())
        {
            return false;
        }
        let Some(op2) = file.nc(m + 1) else { return false };
        if !file.is_punct(op2, "(") {
            return false;
        }
        let Some(k2) = skip_balanced(file, op2, hi) else { return false };
        k = k2;
    }
}

/// Given the index of a `(`, return the index just past its matching
/// `)` (None if unbalanced before `hi`).
fn skip_balanced(file: &SourceFile, open: usize, hi: usize) -> Option<usize> {
    let toks = &file.toks;
    let mut d = 0i64;
    let mut k = open;
    while k < hi {
        if toks[k].kind == Kind::Punct {
            match toks[k].text.as_str() {
                "(" => d += 1,
                ")" => {
                    d -= 1;
                    if d == 0 {
                        return Some(k + 1);
                    }
                }
                _ => {}
            }
        }
        k += 1;
    }
    None
}

/// How an open brace relates to a condvar wait nested under it.
enum BraceKind {
    /// `loop`/`while`/`for` body — the wait is re-checked.
    Loop,
    /// Function or closure boundary — an enclosing loop outside it does
    /// not re-run the wait.
    Barrier,
    /// Plain block, `if`/`match` arm, struct literal… keep looking out.
    Transparent,
}

fn classify_brace(file: &SourceFile, open: usize, lo: usize) -> BraceKind {
    let toks = &file.toks;
    let mut d = 0i64;
    let mut i = open;
    let mut steps = 0;
    while i > lo && steps < 64 {
        steps += 1;
        let Some(p) = (i > 0).then(|| file.pc(i - 1)).flatten() else { break };
        if p < lo {
            break;
        }
        i = p;
        match toks[p].kind {
            Kind::Punct => match toks[p].text.as_str() {
                ")" | "]" => d += 1,
                "(" | "[" => {
                    if d == 0 {
                        return BraceKind::Transparent; // `f({ … })` argument block
                    }
                    d -= 1;
                }
                "|" if d == 0 => return BraceKind::Barrier,
                ";" | "{" | "}" | "," if d == 0 => return BraceKind::Transparent,
                ">" if d == 0 => {
                    // `=>` match arm?
                    if let Some(q) = (p > 0).then(|| file.pc(p - 1)).flatten() {
                        if file.is_punct(q, "=") {
                            return BraceKind::Transparent;
                        }
                    }
                }
                _ => {}
            },
            Kind::Ident if d == 0 => match toks[p].text.as_str() {
                "loop" | "while" | "for" => return BraceKind::Loop,
                "fn" | "move" => return BraceKind::Barrier,
                "if" | "else" | "match" | "unsafe" => return BraceKind::Transparent,
                _ => {}
            },
            _ => {}
        }
    }
    BraceKind::Transparent
}

/// From innermost to outermost enclosing brace: a Loop before any
/// Barrier (or the function root) means the wait is re-checked.
fn wait_is_loop_guarded(file: &SourceFile, scope_opens: &[usize], body_open: usize) -> bool {
    for &open in scope_opens.iter().rev() {
        match classify_brace(file, open, body_open) {
            BraceKind::Loop => return true,
            BraceKind::Barrier => return false,
            BraceKind::Transparent => {}
        }
    }
    false // reached the fn body without a loop
}

/// `.unwrap(` / `.expect(` / `panic!` / `unreachable!` / `todo!` /
/// `unimplemented!` at token `j`?
fn is_panic_token(file: &SourceFile, j: usize) -> bool {
    let t = &file.toks[j];
    match t.text.as_str() {
        "unwrap" | "expect" => prev_is_dot(file, j) && next_is(file, j, "("),
        "panic" | "unreachable" | "todo" | "unimplemented" => next_is(file, j, "!"),
        _ => false,
    }
}

fn panic_label(file: &SourceFile, j: usize) -> String {
    let t = &file.toks[j];
    match t.text.as_str() {
        "unwrap" | "expect" => format!(".{}()", t.text),
        other => format!("{other}!"),
    }
}

/// Banned allocation construct starting at token `j`, if any.
fn banned_alloc_at(file: &SourceFile, j: usize) -> Option<String> {
    let t = &file.toks[j];
    if t.kind != Kind::Ident {
        return None;
    }
    match t.text.as_str() {
        "vec" | "format" if next_is(file, j, "!") => Some(format!("{}!", t.text)),
        "Vec" | "Box" | "String" => {
            // `Type::{new,with_capacity,from}`
            let c1 = file.nc(j + 1)?;
            if !file.is_punct(c1, ":") {
                return None;
            }
            let c2 = file.nc(c1 + 1)?;
            if !file.is_punct(c2, ":") {
                return None;
            }
            let m = file.nc(c2 + 1)?;
            if file.toks[m].kind == Kind::Ident
                && ["new", "with_capacity", "from"].contains(&file.toks[m].text.as_str())
            {
                Some(format!("{}::{}", t.text, file.toks[m].text))
            } else {
                None
            }
        }
        "to_string" | "to_owned" | "to_vec" if prev_is_dot(file, j) && next_is(file, j, "(") => {
            Some(format!(".{}()", t.text))
        }
        _ => None,
    }
}

/// Resolve a call at ident `j` to a crate function, conservatively.
fn resolve_call(model: &Model, file: &SourceFile, fi: usize, j: usize) -> Option<(usize, usize)> {
    let name = &file.toks[j].text;
    if name == "drop" {
        return None;
    }
    let prev = (j > 0).then(|| file.pc(j - 1)).flatten();
    let is_method = prev.is_some_and(|p| file.is_punct(p, "."));
    if is_method {
        let p = prev.unwrap_or(0);
        let self_direct = (p > 0)
            .then(|| file.pc(p - 1))
            .flatten()
            .is_some_and(|q| file.is_ident(q, "self"));
        if self_direct {
            return lookup_in_file(model, fi, name);
        }
        if METHOD_DENY.contains(&name.as_str()) {
            return None;
        }
        return lookup_unique(model, fi, name);
    }
    // `Path::name(` — only resolve through capitalized (type-like) paths
    if let Some(p) = prev {
        if file.is_punct(p, ":") {
            let q = (p > 0).then(|| file.pc(p - 1)).flatten();
            let is_path = q.is_some_and(|q2| file.is_punct(q2, ":"));
            if !is_path {
                return None;
            }
            let seg = q
                .and_then(|q2| (q2 > 0).then(|| file.pc(q2 - 1)).flatten())
                .filter(|&s| file.toks[s].kind == Kind::Ident)
                .map(|s| file.toks[s].text.clone())?;
            let typeish = seg == "Self" || seg.starts_with(char::is_uppercase);
            if !typeish || METHOD_DENY.contains(&name.as_str()) {
                return None;
            }
            return lookup_in_file(model, fi, name).or_else(|| lookup_unique(model, fi, name));
        }
    }
    // bare call
    if METHOD_DENY.contains(&name.as_str()) {
        return None;
    }
    lookup_in_file(model, fi, name).or_else(|| lookup_unique(model, fi, name))
}

/// The unique function named `name` in file `fi`, if exactly one.
fn lookup_in_file(model: &Model, fi: usize, name: &str) -> Option<(usize, usize)> {
    let entries = model.fn_index.get(name)?;
    let mut in_file = entries.iter().filter(|(f, _)| *f == fi);
    match (in_file.next(), in_file.next()) {
        (Some(&e), None) => Some(e),
        _ => None,
    }
}

/// The unique function named `name` crate-wide, if exactly one.
fn lookup_unique(model: &Model, _fi: usize, name: &str) -> Option<(usize, usize)> {
    let entries = model.fn_index.get(name)?;
    if entries.len() == 1 {
        Some(entries[0])
    } else {
        None
    }
}

/// Unsafe hygiene: every `unsafe` token needs a `// SAFETY:` comment on
/// its line or within the 3 lines above. Runs on test code too — test
/// unsafety (the counting global allocator) needs its invariant stated
/// just as much.
fn check_unsafe(file: &SourceFile, findings: &mut Vec<Finding>) {
    for (j, t) in file.toks.iter().enumerate() {
        if t.kind != Kind::Ident || t.text != "unsafe" {
            continue;
        }
        if file.comment_near(t.line, 3, "SAFETY:") {
            continue;
        }
        let function = file
            .fns
            .iter()
            .find(|f| f.body.0 <= j && j <= f.body.1)
            .map(|f| f.name.clone())
            .unwrap_or_else(|| "<item>".to_string());
        findings.push(Finding {
            checker: "unsafe",
            file: file.path.clone(),
            line: t.line,
            function,
            detail: "`unsafe` without a `// SAFETY:` comment stating the invariant it relies on"
                .to_string(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::source::build_model;

    fn run(files: &[(&str, &str)]) -> Analysis {
        let srcs: Vec<(String, String)> =
            files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect();
        check(&build_model(&srcs))
    }

    fn by<'a>(a: &'a Analysis, checker: &str) -> Vec<&'a Finding> {
        a.findings.iter().filter(|f| f.checker == checker).collect()
    }

    /// Shared scaffolding mirroring the real coalescer's lock fields.
    const DSO_PREAMBLE: &str = r#"
use std::sync::{Condvar, Mutex};
use std::collections::BTreeMap;
pub struct Coalescer {
    slots: BTreeMap<usize, Mutex<Option<u8>>>,
    signal: Mutex<()>,
    cv: Condvar,
}
"#;

    fn dso(body: &str) -> Analysis {
        let src = format!("{DSO_PREAMBLE}\nimpl Coalescer {{\n{body}\n}}\n");
        run(&[("src/dso/coalescer.rs", src.as_str())])
    }

    // ---- checker 1: lock-order ----

    #[test]
    fn seeded_inverted_slot_signal_order_is_caught() {
        let a = dso(r#"
    fn bad(&self, profile: usize) {
        let slot = self.slots.get(&profile).unwrap();
        let mut open = slot.lock().unwrap();
        let _parked = self.signal.lock().unwrap();
        open.take();
    }
"#);
        let f = by(&a, "lock-order");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert!(f[0].detail.contains("`signal` while holding `slots`"), "{}", f[0].detail);
    }

    #[test]
    fn nested_slot_locks_are_caught() {
        let a = dso(r#"
    fn nested(&self) {
        let a = self.slots.get(&0).unwrap().lock().unwrap();
        let b = self.slots.get(&1).unwrap().lock().unwrap();
        let _ = (a, b);
    }
"#);
        let f = by(&a, "lock-order");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert!(f[0].detail.contains("never nest"), "{}", f[0].detail);
    }

    #[test]
    fn flusher_direction_signal_then_slots_is_allowed_and_graphed() {
        let a = dso(r#"
    fn flusher(&self) {
        // lint: allow(panic) test scaffold
        let mut parked = self.signal.lock().unwrap();
        loop {
            for (_, slot) in &self.slots {
                let mut open = slot.lock().unwrap();
                open.take();
            }
            parked = self.cv.wait(parked).unwrap();
        }
    }
"#);
        assert!(by(&a, "lock-order").is_empty(), "{:?}", a.findings);
        assert!(by(&a, "condvar").is_empty(), "{:?}", a.findings);
        assert!(
            a.edges.iter().any(|e| e.held.label() == "signal" && e.acquired.label() == "slots"),
            "expected signal -> slots edge in {:?}",
            a.edges
        );
    }

    #[test]
    fn statement_temporaries_and_drop_release_the_guard() {
        let a = dso(r#"
    fn temp(&self) {
        // lint: allow(panic) test scaffold
        let leftover = self.slots.get(&0).unwrap().lock().unwrap().take();
        let _parked = self.signal.lock().unwrap();
        let _ = leftover;
    }
    fn dropped(&self) {
        // lint: allow(panic) test scaffold
        let open = self.slots.get(&0).unwrap().lock().unwrap();
        drop(open);
        let _parked = self.signal.lock().unwrap();
    }
"#);
        assert!(by(&a, "lock-order").is_empty(), "{:?}", a.findings);
    }

    #[test]
    fn inversion_through_a_callee_is_caught() {
        let a = dso(r#"
    fn outer(&self) {
        // lint: allow(panic) test scaffold
        let _open = self.slots.get(&0).unwrap().lock().unwrap();
        self.poke();
    }
    fn poke(&self) {
        // lint: allow(panic) test scaffold
        let _g = self.signal.lock().unwrap();
    }
"#);
        let f = by(&a, "lock-order");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert!(f[0].detail.contains("via `poke()`"), "{}", f[0].detail);
    }

    // ---- checker 2: condvar discipline ----

    #[test]
    fn seeded_if_guarded_wait_is_caught() {
        let a = run(&[("src/x.rs", r#"
use std::sync::{Condvar, Mutex};
struct W { m: Mutex<bool>, cv: Condvar }
impl W {
    fn bad(&self) {
        let mut g = self.m.lock().unwrap();
        if !*g {
            g = self.cv.wait(g).unwrap();
        }
        let _ = g;
    }
}
"#)]);
        let f = by(&a, "condvar");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert!(f[0].detail.contains("outside a `while`/`loop`"), "{}", f[0].detail);
    }

    #[test]
    fn while_and_loop_guarded_waits_are_accepted() {
        let a = run(&[("src/x.rs", r#"
use std::sync::{Condvar, Mutex};
struct W { m: Mutex<bool>, cv: Condvar }
impl W {
    fn good_while(&self) {
        let mut g = self.m.lock().unwrap();
        while !*g {
            g = self.cv.wait(g).unwrap();
        }
    }
    fn good_loop(&self) {
        let mut g = self.m.lock().unwrap();
        loop {
            match 1 {
                _ => { g = self.cv.wait(g).unwrap(); }
            }
        }
    }
}
"#)]);
        assert!(by(&a, "condvar").is_empty(), "{:?}", a.findings);
    }

    #[test]
    fn closure_is_a_loop_barrier_and_barrier_wait_is_ignored() {
        let a = run(&[("src/x.rs", r#"
use std::sync::{Barrier, Condvar, Mutex};
struct W { m: Mutex<bool>, cv: Condvar }
impl W {
    fn closure_bad(&self) {
        loop {
            let f = || {
                let g = self.m.lock().unwrap();
                let _g = self.cv.wait(g).unwrap();
            };
            f();
        }
    }
    fn barrier_ok(&self, b: &Barrier) {
        b.wait();
    }
}
"#)]);
        let f = by(&a, "condvar");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert_eq!(f[0].function, "closure_bad");
    }

    // ---- checker 3: no-alloc hot path ----

    #[test]
    fn seeded_allocation_under_no_alloc_is_caught() {
        let a = run(&[("src/x.rs", r#"
impl H {
    // lint: no_alloc
    fn hot(&self) -> usize {
        let v: Vec<u8> = Vec::new();
        v.len()
    }
    // lint: no_alloc
    fn hot_macro(&self) -> usize {
        vec![1u8].len()
    }
}
"#)]);
        let f = by(&a, "no-alloc");
        assert_eq!(f.len(), 2, "{:?}", a.findings);
        assert!(f[0].detail.contains("Vec::new"), "{}", f[0].detail);
        assert!(f[1].detail.contains("vec!"), "{}", f[1].detail);
    }

    #[test]
    fn allocation_via_same_crate_callee_is_caught() {
        let a = run(&[("src/x.rs", r#"
impl H {
    // lint: no_alloc
    fn hot(&self) -> String {
        self.helper()
    }
    fn helper(&self) -> String {
        format!("x")
    }
}
"#)]);
        let f = by(&a, "no-alloc");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert!(f[0].detail.contains("helper"), "{}", f[0].detail);
        assert!(f[0].detail.contains("format!"), "{}", f[0].detail);
    }

    #[test]
    fn alloc_free_annotated_fn_and_unannotated_allocs_are_accepted() {
        let a = run(&[("src/x.rs", r#"
impl H {
    // lint: no_alloc
    fn cold(&self, x: u64) -> u64 {
        x.wrapping_mul(3) + 1
    }
    fn free_to_alloc(&self) -> Vec<u8> {
        vec![1, 2, 3]
    }
}
"#)]);
        assert!(by(&a, "no-alloc").is_empty(), "{:?}", a.findings);
    }

    #[test]
    fn untagged_controller_hot_path_fn_is_a_finding() {
        let a = run(&[("src/cluster/controller.rs", r#"
impl OverloadController {
    fn decision(&self, t: u8) -> u8 {
        t
    }
    // lint: no_alloc — per-request hot path, must stay allocation-free
    fn note_submit(&self, t: u8) {
        let _ = t;
    }
}
"#)]);
        let f = by(&a, "no-alloc");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert_eq!(f[0].function, "decision");
        assert!(f[0].detail.contains("required registry"), "{}", f[0].detail);
    }

    #[test]
    fn registry_ignores_same_name_fns_in_other_files() {
        let a = run(&[("src/server/stages.rs", r#"
fn decision(x: u8) -> u8 {
    x
}
"#)]);
        assert!(by(&a, "no-alloc").is_empty(), "{:?}", a.findings);
    }

    // ---- checker 4: panic policy ----

    #[test]
    fn seeded_untagged_unwrap_in_dso_is_caught() {
        let a = run(&[("src/dso/x.rs", r#"
impl T {
    fn bad(&self) -> u8 {
        self.v.lock().unwrap()
    }
}
"#)]);
        let f = by(&a, "panic");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert!(f[0].detail.contains("into_inner"), "{}", f[0].detail);
    }

    #[test]
    fn tagged_poison_tolerant_and_non_policy_panics_are_accepted() {
        let a = run(&[
            ("src/dso/x.rs", r#"
impl T {
    fn tagged(&self) -> u8 {
        // lint: allow(panic) startup-only path, poisoning is fatal by design
        self.v.lock().unwrap()
    }
    fn poison_ok(&self) -> u8 {
        *self.v.lock().unwrap_or_else(|e| e.into_inner())
    }
}
"#),
            ("src/util/x.rs", r#"
fn free_to_unwrap(v: Option<u8>) -> u8 {
    v.unwrap()
}
"#),
        ]);
        assert!(by(&a, "panic").is_empty(), "{:?}", a.findings);
    }

    #[test]
    fn panic_macros_require_tags_too() {
        let a = run(&[("src/server/x.rs", r#"
fn bad(x: u8) {
    if x > 3 {
        unreachable!();
    }
}
fn tagged(x: u8) {
    if x > 3 {
        panic!("boom"); // lint: allow(panic) config validated at startup
    }
}
"#)]);
        let f = by(&a, "panic");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert!(f[0].detail.contains("unreachable!"), "{}", f[0].detail);
    }

    // ---- checker: supervisor (catch_unwind contract) ----

    #[test]
    fn untagged_catch_unwind_in_policy_dir_is_caught() {
        let a = run(&[("src/server/x.rs", r#"
fn worker_body(f: impl FnOnce() + std::panic::UnwindSafe) {
    let _ = std::panic::catch_unwind(f);
}
"#)]);
        let f = by(&a, "supervisor");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert!(f[0].detail.contains("lint: supervisor"), "{}", f[0].detail);
        assert_eq!(f[0].function, "worker_body");
    }

    #[test]
    fn tagged_supervisor_and_non_policy_catch_unwind_are_accepted() {
        let a = run(&[
            ("src/server/x.rs", r#"
fn supervised(f: impl FnOnce() + std::panic::UnwindSafe) {
    // lint: supervisor — fails the in-flight request with a typed
    // error and keeps the worker draining; body only borrows views
    // that outlive the unwind, so the respawned worker sees clean
    // state on the next iteration
    let _ = std::panic::catch_unwind(f);
}
"#),
            ("src/util/x.rs", r#"
fn free_to_catch(f: impl FnOnce() + std::panic::UnwindSafe) {
    let _ = std::panic::catch_unwind(f);
}
"#),
        ]);
        assert!(by(&a, "supervisor").is_empty(), "{:?}", a.findings);
    }

    #[test]
    fn supervisor_tag_too_far_above_does_not_count() {
        let a = run(&[("src/cluster/x.rs", r#"
fn drifted(f: impl FnOnce() + std::panic::UnwindSafe) {
    // lint: supervisor — this tag has drifted six lines away from
    // the site it is meant to justify, past the 5-line window the
    // checker accepts; the contract comment must stay attached to
    // the catch_unwind it documents, or reviewers cannot tell which
    // unwind boundary is supervised and which is a silent swallow,
    // so the checker treats this site as untagged
    let _ = std::panic::catch_unwind(f);
}
"#)]);
        assert_eq!(by(&a, "supervisor").len(), 1, "{:?}", a.findings);
    }

    #[test]
    fn supervisor_dropping_a_job_without_resolving_reply_is_caught() {
        let a = run(&[("src/server/x.rs", r#"
fn worker(job: Job, metrics: &Recorder) {
    // lint: supervisor — fails the in-flight request with a typed
    // error and keeps the worker draining
    let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run(&job)));
    if ran.is_err() {
        let _orphan = job.reply;
        metrics.record_dropped();
    }
}
"#)]);
        let f = by(&a, "supervisor");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert!(f[0].detail.contains("reply.send"), "{}", f[0].detail);
        assert_eq!(f[0].function, "worker");
    }

    #[test]
    fn supervisor_that_resolves_reply_before_dropping_is_accepted() {
        let a = run(&[("src/server/x.rs", r#"
fn worker(job: Job, metrics: &Recorder) {
    // lint: supervisor — fails the in-flight request with a typed
    // error and keeps the worker draining
    let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run(&job)));
    if ran.is_err() {
        metrics.record_dropped();
        let _ = job.reply.send(Err(Error::WorkerPanic("boom".into())));
    }
}
"#)]);
        assert!(by(&a, "supervisor").is_empty(), "{:?}", a.findings);
    }

    // ---- checker 5: unsafe hygiene ----

    #[test]
    fn seeded_uncommented_unsafe_is_caught() {
        let a = run(&[("src/x.rs", r#"
fn ok(p: *const u8) -> u8 {
    // SAFETY: caller guarantees p points at a live byte
    unsafe { *p }
}
fn bad(p: *const u8) -> u8 {
    unsafe { *p }
}
"#)]);
        let f = by(&a, "unsafe");
        assert_eq!(f.len(), 1, "{:?}", a.findings);
        assert_eq!(f[0].function, "bad");
    }

    #[test]
    fn unsafe_in_test_code_is_still_checked_but_other_checkers_skip_tests() {
        let a = run(&[("tests/t.rs", r#"
use std::sync::{Condvar, Mutex};
struct W { m: Mutex<bool>, cv: Condvar }
fn helper(w: &W) {
    let g = w.m.lock().unwrap();
    if !*g {
        let _g = w.cv.wait(g).unwrap();
    }
    unsafe { std::hint::unreachable_unchecked() }
}
"#)]);
        assert_eq!(by(&a, "unsafe").len(), 1, "{:?}", a.findings);
        assert!(by(&a, "condvar").is_empty(), "{:?}", a.findings);
        assert!(by(&a, "panic").is_empty(), "{:?}", a.findings);
    }

    // ---- fingerprints ----

    #[test]
    fn fingerprints_are_line_stable() {
        let before = run(&[("src/dso/x.rs", r#"
fn bad(v: &std::sync::Mutex<u8>) -> u8 {
    *v.lock().unwrap()
}
"#)]);
        let after = run(&[("src/dso/x.rs", r#"
// a new comment shifting everything down
// by a couple of lines
fn bad(v: &std::sync::Mutex<u8>) -> u8 {
    *v.lock().unwrap()
}
"#)]);
        let fp = |a: &Analysis| -> Vec<String> {
            a.findings.iter().map(|f| f.fingerprint()).collect()
        };
        assert!(!before.findings.is_empty());
        assert_eq!(fp(&before), fp(&after));
        assert_ne!(before.findings[0].line, after.findings[0].line);
    }
}
