//! `flame lint` — a self-hosted concurrency-invariant and hot-path
//! analyzer for this crate's own sources.
//!
//! The serving path's throughput rests on hand-rolled concurrency: the
//! DSO coalescer's slot locks with a documented acquisition order, the
//! condvar-parked flusher threads, the PDA fetch coalescer's sharded
//! single-flight tables, and the zero-allocation tracing hot path.
//! Those invariants used to live only in module doc comments and one
//! runtime allocator test; this module turns them into machine-checked
//! facts that run anywhere — it is dependency-free (hand-rolled lexer,
//! token-level checkers, `std` only) precisely so the check works in
//! build environments without a full toolchain-adjacent ecosystem.
//!
//! Pipeline: [`lexer`] tokenizes each file (raw strings, nested block
//! comments, char-vs-lifetime disambiguation), [`source`] builds a
//! per-crate model (functions, test regions, `Mutex`/`Condvar` fields,
//! annotations), and [`checkers`] runs five invariant checks over it:
//! lock-order, condvar discipline, `// lint: no_alloc` hot paths, the
//! panic policy for hot-path directories, and `// SAFETY:` hygiene for
//! `unsafe`.
//!
//! ## Soundness stance
//!
//! This is a reviewer that never sleeps, not a verifier. The analysis
//! is intentionally approximate: guards are tracked by the idioms this
//! codebase actually uses (`let g = x.lock().unwrap();`, `drop(g)`,
//! statement-scoped temporaries), and calls resolve only when
//! unambiguous. Constructs it cannot follow are skipped rather than
//! guessed at, so a finding is near-certainly real — which is what
//! lets CI fail hard on any non-baselined finding — while exotic code
//! could in principle evade it. Keep the invariants enforced here in
//! sync with the module docs they came from.
//!
//! ## Baselines
//!
//! Findings are identified by a line-number-free fingerprint
//! (`checker|file|function|detail`). A committed baseline file lists
//! fingerprints that are accepted (ideally none — fix findings instead
//! of grandfathering them); `flame lint --write-baseline` regenerates
//! it, and `flame lint` exits nonzero when any finding is not
//! baselined.

pub mod checkers;
pub mod lexer;
pub mod source;

pub use checkers::{check, Analysis, Finding, LockEdge};
pub use source::{build_model, Model};

use std::collections::BTreeSet;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Collect `(relative path, contents)` for every `.rs` file under
/// `root/src` and `root/tests`, deterministically ordered. `vendor/`
/// and `target/` never participate.
pub fn scan_root(root: &Path) -> io::Result<Vec<(String, String)>> {
    let mut files = Vec::new();
    for sub in ["src", "tests"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    files.sort();
    let mut out = Vec::with_capacity(files.len());
    for p in files {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        out.push((rel, fs::read_to_string(&p)?));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if name != "vendor" && name != "target" {
                collect_rs(&path, out)?;
            }
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Parse a baseline file's contents into the set of accepted
/// fingerprints. `#`-prefixed lines are comments.
pub fn parse_baseline(text: &str) -> BTreeSet<String> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

/// Render findings as baseline file contents (sorted, deduplicated).
pub fn format_baseline(findings: &[Finding]) -> String {
    let mut fps: Vec<String> = findings.iter().map(Finding::fingerprint).collect();
    fps.sort();
    fps.dedup();
    let mut out = String::from(
        "# flame lint baseline — accepted finding fingerprints, one per line.\n\
         # Regenerate with `flame lint --write-baseline`; prefer fixing findings\n\
         # over listing them here.\n",
    );
    for fp in fps {
        out.push_str(&fp);
        out.push('\n');
    }
    out
}

/// Split findings into (baselined, fresh) against an accepted set.
pub fn apply_baseline(
    analysis: &Analysis,
    accepted: &BTreeSet<String>,
) -> (Vec<Finding>, Vec<Finding>) {
    analysis
        .findings
        .iter()
        .cloned()
        .partition(|f| accepted.contains(&f.fingerprint()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_roundtrip() {
        let f = Finding {
            checker: "panic",
            file: "src/dso/x.rs".to_string(),
            line: 7,
            function: "bad".to_string(),
            detail: "untagged `.unwrap()`".to_string(),
        };
        let text = format_baseline(std::slice::from_ref(&f));
        let set = parse_baseline(&text);
        assert!(set.contains(&f.fingerprint()));
        assert_eq!(set.len(), 1, "comment lines must not parse as fingerprints");
    }

    #[test]
    fn apply_baseline_partitions() {
        let mk = |func: &str| Finding {
            checker: "panic",
            file: "src/dso/x.rs".to_string(),
            line: 1,
            function: func.to_string(),
            detail: "d".to_string(),
        };
        let a = Analysis { findings: vec![mk("one"), mk("two")], edges: Vec::new() };
        let accepted: BTreeSet<String> = [mk("one").fingerprint()].into_iter().collect();
        let (old, fresh) = apply_baseline(&a, &accepted);
        assert_eq!(old.len(), 1);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].function, "two");
    }
}
