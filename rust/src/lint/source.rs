//! Token-stream → source model for the lint checkers.
//!
//! Extracts, per file: function items (with body spans, test-ness, and
//! `// lint:` annotations), `Mutex`-typed fields (the lock classes the
//! lock-order checker reasons about), `Condvar`-typed field names (so
//! the condvar checker only fires on real condvars, not every method
//! called `wait`), and `#[cfg(test)]` / `#[test]` regions.
//!
//! Everything here is approximate on purpose — see the module docs in
//! `lint/mod.rs` for the soundness stance.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{lex, Kind, Tok};

/// A named lock class: the `Mutex`-typed field (or static) `field`
/// declared in `file`. Locks are classified by *declaration site*, so
/// every element of `slots: BTreeMap<usize, Mutex<..>>` shares one class
/// — exactly the granularity the documented lock orders use.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LockClass {
    Field { file: String, field: String },
    Other { name: String },
}

impl LockClass {
    pub fn label(&self) -> String {
        match self {
            LockClass::Field { field, .. } => field.clone(),
            LockClass::Other { name } => format!("?{name}"),
        }
    }
}

/// One `fn` item.
#[derive(Clone, Debug)]
pub struct FnItem {
    pub name: String,
    /// Token index of the `fn` keyword.
    pub kw: usize,
    /// Token indices of the body `{` and its matching `}`.
    pub body: (usize, usize),
    pub line: u32,
    pub is_test: bool,
    /// Carries a `// lint: no_alloc` annotation.
    pub no_alloc: bool,
}

/// One lexed + indexed source file.
pub struct SourceFile {
    /// Normalized path with forward slashes, e.g. `src/dso/coalescer.rs`.
    pub path: String,
    pub toks: Vec<Tok>,
    /// For each `{` token index, the index of its matching `}`.
    pub brace_match: BTreeMap<usize, usize>,
    /// Token-index ranges covered by `#[cfg(test)]` / `#[test]` items.
    pub test_ranges: Vec<(usize, usize)>,
    /// Whole file is test code (lives under a `tests/` root).
    pub integration_test: bool,
    pub fns: Vec<FnItem>,
    /// Lines that carry comments, with the comment text (block comments
    /// contribute one entry per line they span).
    pub comment_lines: BTreeMap<u32, String>,
}

impl SourceFile {
    /// Next non-comment token index at or after `i`.
    pub fn nc(&self, mut i: usize) -> Option<usize> {
        while i < self.toks.len() {
            if self.toks[i].kind != Kind::Comment {
                return Some(i);
            }
            i += 1;
        }
        None
    }

    /// Previous non-comment token index at or before `i`.
    pub fn pc(&self, mut i: usize) -> Option<usize> {
        loop {
            if self.toks[i].kind != Kind::Comment {
                return Some(i);
            }
            if i == 0 {
                return None;
            }
            i -= 1;
        }
    }

    pub fn is_ident(&self, i: usize, word: &str) -> bool {
        self.toks[i].kind == Kind::Ident && self.toks[i].text == word
    }

    pub fn is_punct(&self, i: usize, p: &str) -> bool {
        self.toks[i].kind == Kind::Punct && self.toks[i].text == p
    }

    pub fn in_test_region(&self, i: usize) -> bool {
        self.integration_test || self.test_ranges.iter().any(|&(a, b)| a <= i && i <= b)
    }

    /// True if a comment containing `needle` sits on `line` or up to
    /// `span` lines above it. This is the tag-attachment rule for
    /// `// lint: allow(panic)` and `// SAFETY:` comments.
    pub fn comment_near(&self, line: u32, span: u32, needle: &str) -> bool {
        let lo = line.saturating_sub(span);
        self.comment_lines
            .range(lo..=line)
            .any(|(_, text)| text.contains(needle))
    }
}

/// The whole crate, as far as the checkers care.
pub struct Model {
    pub files: Vec<SourceFile>,
    /// (file path, field name) of every `Mutex`-typed field/static.
    pub lock_fields: BTreeSet<(String, String)>,
    /// Names of `Condvar`-typed fields/statics, crate-wide.
    pub condvar_names: BTreeSet<String>,
    /// fn name → (file index, fn index), non-test fns only — the
    /// resolution table for the approximate call graph.
    pub fn_index: BTreeMap<String, Vec<(usize, usize)>>,
}

/// Build the model from `(path, source)` pairs.
pub fn build_model(sources: &[(String, String)]) -> Model {
    let mut files = Vec::with_capacity(sources.len());
    let mut lock_fields = BTreeSet::new();
    let mut condvar_names = BTreeSet::new();
    for (path, src) in sources {
        let path = path.replace('\\', "/");
        let toks = lex(src);
        let brace_match = match_braces(&toks);
        let comment_lines = index_comments(&toks);
        let integration_test = path.contains("tests/");
        let test_ranges = find_test_ranges(&toks, &brace_match);
        let mut sf = SourceFile {
            path: path.clone(),
            toks,
            brace_match,
            test_ranges,
            integration_test,
            fns: Vec::new(),
            comment_lines,
        };
        sf.fns = find_fns(&sf);
        harvest_sync_fields(&sf, &mut lock_fields, &mut condvar_names);
        files.push(sf);
    }
    let mut fn_index: BTreeMap<String, Vec<(usize, usize)>> = BTreeMap::new();
    for (fi, f) in files.iter().enumerate() {
        for (ni, item) in f.fns.iter().enumerate() {
            if !item.is_test && !f.in_test_region(item.kw) {
                fn_index.entry(item.name.clone()).or_default().push((fi, ni));
            }
        }
    }
    Model { files, lock_fields, condvar_names, fn_index }
}

/// Map each `{` to its matching `}` (a single stack pass — the lexer
/// guarantees braces inside strings/comments never reach us).
fn match_braces(toks: &[Tok]) -> BTreeMap<usize, usize> {
    let mut map = BTreeMap::new();
    let mut stack = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.kind == Kind::Punct {
            match t.text.as_str() {
                "{" => stack.push(i),
                "}" => {
                    if let Some(open) = stack.pop() {
                        map.insert(open, i);
                    }
                }
                _ => {}
            }
        }
    }
    map
}

/// Per-line comment text (block comments spread over their line span).
fn index_comments(toks: &[Tok]) -> BTreeMap<u32, String> {
    let mut map: BTreeMap<u32, String> = BTreeMap::new();
    for t in toks {
        if t.kind != Kind::Comment {
            continue;
        }
        for (off, seg) in t.text.split('\n').enumerate() {
            let entry = map.entry(t.line + off as u32).or_default();
            entry.push_str(seg);
            entry.push(' ');
        }
    }
    map
}

/// Find `#[cfg(test)]` / `#[test]`-attributed items and return their
/// token ranges (attribute through closing brace).
fn find_test_ranges(toks: &[Tok], brace_match: &BTreeMap<usize, usize>) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < toks.len() {
        let is_attr = toks[i].kind == Kind::Punct
            && toks[i].text == "#"
            && toks[i + 1].kind == Kind::Punct
            && toks[i + 1].text == "[";
        if !is_attr {
            i += 1;
            continue;
        }
        // scan attribute content to the matching `]`
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut has_test = false;
        while j < toks.len() && depth > 0 {
            if toks[j].kind == Kind::Punct {
                match toks[j].text.as_str() {
                    "[" => depth += 1,
                    "]" => depth -= 1,
                    _ => {}
                }
            } else if toks[j].kind == Kind::Ident && toks[j].text == "test" {
                has_test = true;
            }
            j += 1;
        }
        if !has_test {
            i = j;
            continue;
        }
        // item body: first `{` before a top-level `;`
        let start = i;
        let mut k = j;
        let mut pdepth = 0i64;
        while k < toks.len() {
            if toks[k].kind == Kind::Punct {
                match toks[k].text.as_str() {
                    "(" | "[" => pdepth += 1,
                    ")" | "]" => pdepth -= 1,
                    "{" if pdepth == 0 => {
                        if let Some(&close) = brace_match.get(&k) {
                            out.push((start, close));
                            k = close;
                        }
                        break;
                    }
                    ";" if pdepth == 0 => break, // e.g. `#[cfg(test)] mod t;`
                    _ => {}
                }
            }
            k += 1;
        }
        i = k + 1;
    }
    out
}

/// Find `fn` items and their bodies. Nested fns are reported separately
/// AND covered by the enclosing fn's span — checkers that walk a body
/// will attribute inner-fn tokens to both, which only ever errs toward
/// reporting, never suppressing.
fn find_fns(sf: &SourceFile) -> Vec<FnItem> {
    let mut out = Vec::new();
    let toks = &sf.toks;
    for kw in 0..toks.len() {
        if !sf.is_ident(kw, "fn") {
            continue;
        }
        let Some(ni) = sf.nc(kw + 1) else { continue };
        if toks[ni].kind != Kind::Ident {
            continue; // `fn()` pointer type, `Fn` bounds never hit this arm
        }
        let name = toks[ni].text.clone();
        // body opens at the first `{` at ()/[] depth 0; a `;` first means
        // a bodyless decl (trait method, extern fn) — skip those.
        let mut k = ni + 1;
        let mut pdepth = 0i64;
        let mut body = None;
        while k < toks.len() {
            if toks[k].kind == Kind::Punct {
                match toks[k].text.as_str() {
                    "(" | "[" => pdepth += 1,
                    ")" | "]" => pdepth -= 1,
                    "{" if pdepth == 0 => {
                        if let Some(&close) = sf.brace_match.get(&k) {
                            body = Some((k, close));
                        }
                        break;
                    }
                    ";" if pdepth == 0 => break,
                    _ => {}
                }
            }
            k += 1;
        }
        let Some(body) = body else { continue };
        let (is_test_attr, no_alloc) = fn_annotations(sf, kw);
        out.push(FnItem {
            name,
            kw,
            body,
            line: toks[kw].line,
            is_test: is_test_attr || sf.in_test_region(kw),
            no_alloc,
        });
    }
    out
}

/// Walk back from the `fn` keyword over qualifiers, attributes and
/// comments; collect `#[test]`-ness and `// lint:` annotations.
fn fn_annotations(sf: &SourceFile, kw: usize) -> (bool, bool) {
    const QUALIFIERS: &[&str] =
        &["pub", "crate", "in", "const", "async", "unsafe", "extern", "super", "self", "default"];
    let toks = &sf.toks;
    let mut is_test = false;
    let mut no_alloc = false;
    let mut i = kw;
    while i > 0 {
        i -= 1;
        let t = &toks[i];
        match t.kind {
            Kind::Comment => {
                if t.text.contains("lint: no_alloc") {
                    no_alloc = true;
                }
            }
            Kind::Str => {} // extern "C"
            Kind::Punct if t.text == "]" => {
                // attribute: walk back to the `#[`
                let mut depth = 1i64;
                let mut saw_test = false;
                while i > 0 && depth > 0 {
                    i -= 1;
                    match toks[i].kind {
                        Kind::Punct if toks[i].text == "]" => depth += 1,
                        Kind::Punct if toks[i].text == "[" => depth -= 1,
                        Kind::Ident if toks[i].text == "test" => saw_test = true,
                        _ => {}
                    }
                }
                if i > 0 && sf.is_punct(i - 1, "#") {
                    i -= 1;
                }
                is_test |= saw_test;
            }
            Kind::Punct if t.text == ")" => {
                // `pub(crate)` — walk back over the parens
                let mut depth = 1i64;
                while i > 0 && depth > 0 {
                    i -= 1;
                    match toks[i].kind {
                        Kind::Punct if toks[i].text == ")" => depth += 1,
                        Kind::Punct if toks[i].text == "(" => depth -= 1,
                        _ => {}
                    }
                }
            }
            Kind::Ident if QUALIFIERS.contains(&t.text.as_str()) => {}
            _ => break,
        }
    }
    (is_test, no_alloc)
}

/// Harvest `Mutex`- and `Condvar`-typed struct fields and statics.
fn harvest_sync_fields(
    sf: &SourceFile,
    lock_fields: &mut BTreeSet<(String, String)>,
    condvar_names: &mut BTreeSet<String>,
) {
    let toks = &sf.toks;
    // struct fields
    for i in 0..toks.len() {
        if !sf.is_ident(i, "struct") {
            continue;
        }
        let Some(ni) = sf.nc(i + 1) else { continue };
        if toks[ni].kind != Kind::Ident {
            continue;
        }
        // find the body `{` (skip tuple/unit structs)
        let mut k = ni + 1;
        let mut body = None;
        while k < toks.len() {
            if toks[k].kind == Kind::Punct {
                match toks[k].text.as_str() {
                    "{" => {
                        body = sf.brace_match.get(&k).map(|&c| (k, c));
                        break;
                    }
                    ";" | "(" => break,
                    _ => {}
                }
            }
            k += 1;
        }
        let Some((open, close)) = body else { continue };
        harvest_fields_in(sf, open + 1, close, lock_fields, condvar_names);
    }
    // statics: `static NAME: <type> =`
    for i in 0..toks.len() {
        if !sf.is_ident(i, "static") {
            continue;
        }
        let Some(mut ni) = sf.nc(i + 1) else { continue };
        if sf.is_ident(ni, "mut") {
            ni = match sf.nc(ni + 1) {
                Some(x) => x,
                None => continue,
            };
        }
        if toks[ni].kind != Kind::Ident {
            continue;
        }
        let name = toks[ni].text.clone();
        let Some(colon) = sf.nc(ni + 1) else { continue };
        if !sf.is_punct(colon, ":") {
            continue;
        }
        let mut k = colon + 1;
        while k < toks.len() && !sf.is_punct(k, "=") && !sf.is_punct(k, ";") {
            if sf.is_ident(k, "Mutex") {
                lock_fields.insert((sf.path.clone(), name.clone()));
            }
            if sf.is_ident(k, "Condvar") {
                condvar_names.insert(name.clone());
            }
            k += 1;
        }
    }
}

/// Parse `name: Type,` fields between `from..to` (a struct body).
fn harvest_fields_in(
    sf: &SourceFile,
    from: usize,
    to: usize,
    lock_fields: &mut BTreeSet<(String, String)>,
    condvar_names: &mut BTreeSet<String>,
) {
    let toks = &sf.toks;
    let mut i = from;
    while i < to {
        // skip comments and attributes
        if toks[i].kind == Kind::Comment {
            i += 1;
            continue;
        }
        if sf.is_punct(i, "#") {
            // skip `#[...]`
            let mut depth = 0i64;
            i += 1;
            while i < to {
                if sf.is_punct(i, "[") {
                    depth += 1;
                } else if sf.is_punct(i, "]") {
                    depth -= 1;
                    if depth == 0 {
                        i += 1;
                        break;
                    }
                }
                i += 1;
            }
            continue;
        }
        if sf.is_ident(i, "pub") {
            i += 1;
            if i < to && sf.is_punct(i, "(") {
                let mut depth = 1i64;
                i += 1;
                while i < to && depth > 0 {
                    if sf.is_punct(i, "(") {
                        depth += 1;
                    } else if sf.is_punct(i, ")") {
                        depth -= 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // expect `name :`
        if toks[i].kind == Kind::Ident && i + 1 < to && sf.is_punct(i + 1, ":") {
            let fname = toks[i].text.clone();
            // consume the type up to a `,` at bracket depth 0
            let mut k = i + 2;
            let mut depth = 0i64;
            let mut angle = 0i64;
            while k < to {
                if toks[k].kind == Kind::Punct {
                    match toks[k].text.as_str() {
                        "(" | "[" | "{" => depth += 1,
                        ")" | "]" | "}" => depth -= 1,
                        "<" => angle += 1,
                        ">" if angle > 0 => angle -= 1,
                        "," if depth == 0 && angle == 0 => break,
                        _ => {}
                    }
                } else if toks[k].kind == Kind::Ident {
                    if toks[k].text == "Mutex" || toks[k].text == "RwLock" {
                        lock_fields.insert((sf.path.clone(), fname.clone()));
                    } else if toks[k].text == "Condvar" {
                        condvar_names.insert(fname.clone());
                    }
                }
                k += 1;
            }
            i = k + 1;
            continue;
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_of(src: &str) -> Model {
        build_model(&[("src/x.rs".to_string(), src.to_string())])
    }

    #[test]
    fn fn_extraction_with_generics_and_where() {
        let src = "
impl Foo {
    pub fn get<F>(&self, f: F) -> Option<u8> where F: FnMut(&u8) -> bool { None }
}
fn free(x: fn() -> u8) -> u8 { x() }
trait T { fn decl(&self); }
";
        let m = model_of(src);
        let names: Vec<_> = m.files[0].fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["get", "free"]);
    }

    #[test]
    fn test_regions_cover_cfg_test_mod() {
        let src = "
fn live() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() { assert!(true); }
}
";
        let m = model_of(src);
        let f = &m.files[0];
        let live = f.fns.iter().find(|x| x.name == "live").unwrap();
        let t = f.fns.iter().find(|x| x.name == "t").unwrap();
        assert!(!live.is_test);
        assert!(t.is_test);
    }

    #[test]
    fn no_alloc_annotation_attaches_through_attrs() {
        let src = "
// hot path. lint: no_alloc
#[inline]
pub fn fast(&self) -> u64 { 0 }
pub fn slow(&self) -> u64 { 0 }
";
        let m = model_of(src);
        let f = &m.files[0];
        assert!(f.fns.iter().find(|x| x.name == "fast").unwrap().no_alloc);
        assert!(!f.fns.iter().find(|x| x.name == "slow").unwrap().no_alloc);
    }

    #[test]
    fn sync_field_harvest() {
        let src = "
struct S {
    pub slots: BTreeMap<usize, Mutex<Option<u8>>>,
    signal: Mutex<()>,
    cv: Condvar,
    plain: usize,
}
static GLOBAL: Mutex<Vec<u8>> = Mutex::new(Vec::new());
";
        let m = model_of(src);
        let has = |f: &str| m.lock_fields.contains(&("src/x.rs".to_string(), f.to_string()));
        assert!(has("slots"));
        assert!(has("signal"));
        assert!(has("GLOBAL"));
        assert!(!has("plain"));
        assert!(!has("cv"));
        assert!(m.condvar_names.contains("cv"));
    }

    #[test]
    fn comment_near_window() {
        let src = "
// SAFETY: upheld because reasons
fn f() {}
";
        let m = model_of(src);
        let f = &m.files[0];
        assert!(f.comment_near(3, 2, "SAFETY:"));
        assert!(!f.comment_near(3, 0, "SAFETY:"));
    }
}
