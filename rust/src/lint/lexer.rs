//! Hand-rolled Rust lexer for the `flame lint` analyzer.
//!
//! Dependency-free by design (ROADMAP: no toolchain beyond rustc in the
//! build container, and no registry access for syn/proc-macro2), so the
//! checkers work from a flat token stream instead of a real AST. The
//! lexer's one job is to never desync: string and comment contents must
//! not leak braces/keywords into the token stream, or every downstream
//! scope computation is garbage. Hence explicit handling for raw strings
//! (`r#"..."#`), byte strings, nested block comments, and the `'a`
//! lifetime vs `'x'` char-literal ambiguity.

/// Token classes. The checkers only ever look at `Ident`, `Punct` and
/// `Comment`; the rest exist so their *contents* are kept out of those.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Lifetime,
    Char,
    Str,
    Num,
    Punct,
    Comment,
}

/// One token with its (1-based) starting line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

/// Lex a whole source file. Unterminated constructs consume the rest of
/// the input rather than erroring: the linter must degrade gracefully on
/// code rustc itself would reject.
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            out.push(Tok { kind: Kind::Comment, text: b[start..i].iter().collect(), line });
            continue;
        }
        // block comment, nesting-aware
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.push(Tok {
                kind: Kind::Comment,
                text: b[start..i].iter().collect(),
                line: start_line,
            });
            continue;
        }
        // identifier — possibly a raw/byte string prefix (r" r#" b" br" b')
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            let word: String = b[start..i].iter().collect();
            let next = if i < n { b[i] } else { '\0' };
            let rawish = (word == "r" || word == "br") && (next == '"' || next == '#');
            let bytestr = word == "b" && next == '"';
            let bytechar = word == "b" && next == '\'';
            if rawish && scan_raw_string(&b, &mut i, &mut line) {
                out.push(Tok { kind: Kind::Str, text: String::new(), line });
                continue;
            }
            if bytestr {
                scan_string(&b, &mut i, &mut line);
                out.push(Tok { kind: Kind::Str, text: String::new(), line });
                continue;
            }
            if bytechar {
                scan_char(&b, &mut i);
                out.push(Tok { kind: Kind::Char, text: String::new(), line });
                continue;
            }
            out.push(Tok { kind: Kind::Ident, text: word, line });
            continue;
        }
        // plain string
        if c == '"' {
            scan_string(&b, &mut i, &mut line);
            out.push(Tok { kind: Kind::Str, text: String::new(), line });
            continue;
        }
        // lifetime vs char literal
        if c == '\'' {
            let p1 = if i + 1 < n { b[i + 1] } else { '\0' };
            let p2 = if i + 2 < n { b[i + 2] } else { '\0' };
            let ident_start = p1.is_alphabetic() || p1 == '_';
            if ident_start && p2 != '\'' {
                // `'a`, `'static`, `'_` — no closing quote follows
                i += 1;
                let start = i;
                while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                out.push(Tok {
                    kind: Kind::Lifetime,
                    text: b[start..i].iter().collect(),
                    line,
                });
            } else {
                scan_char(&b, &mut i);
                out.push(Tok { kind: Kind::Char, text: String::new(), line });
            }
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            // one fractional part, but never eat `..` range syntax
            if i + 1 < n && b[i] == '.' && b[i + 1].is_ascii_digit() {
                i += 1;
                while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
            }
            out.push(Tok { kind: Kind::Num, text: b[start..i].iter().collect(), line });
            continue;
        }
        out.push(Tok { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

/// Consume `"...."` starting at the opening quote; handles `\"` escapes.
fn scan_string(b: &[char], i: &mut usize, line: &mut u32) {
    debug_assert_eq!(b[*i], '"');
    *i += 1;
    while *i < b.len() {
        match b[*i] {
            '\\' => *i += 2,
            '"' => {
                *i += 1;
                return;
            }
            '\n' => {
                *line += 1;
                *i += 1;
            }
            _ => *i += 1,
        }
    }
}

/// Consume a raw string body starting at `#` or `"` (the `r`/`br` prefix
/// is already consumed). Returns false (without moving) if this is not
/// actually a raw string — e.g. `r#enum` raw identifiers.
fn scan_raw_string(b: &[char], i: &mut usize, line: &mut u32) -> bool {
    let mut j = *i;
    let mut hashes = 0usize;
    while j < b.len() && b[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= b.len() || b[j] != '"' {
        return false; // raw identifier like r#fn — leave `#` for the caller
    }
    j += 1;
    // scan for `"` followed by `hashes` hashes
    while j < b.len() {
        if b[j] == '\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if b[j] == '"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < b.len() && b[k] == '#' && seen < hashes {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                *i = k;
                return true;
            }
        }
        j += 1;
    }
    *i = j;
    true
}

/// Consume `'x'`, `'\n'`, `'\u{7fff}'`, `'}'` starting at the quote.
fn scan_char(b: &[char], i: &mut usize) {
    debug_assert_eq!(b[*i], '\'');
    *i += 1;
    while *i < b.len() {
        match b[*i] {
            '\\' => *i += 2,
            '\'' => {
                *i += 1;
                return;
            }
            _ => *i += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Net `{` minus `}` among Punct tokens — the quantity every scope
    /// computation downstream depends on.
    fn brace_balance(src: &str) -> i64 {
        lex(src)
            .iter()
            .filter(|t| t.kind == Kind::Punct)
            .map(|t| match t.text.as_str() {
                "{" => 1,
                "}" => -1,
                _ => 0,
            })
            .sum()
    }

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn raw_strings_hide_braces_and_quotes() {
        let src = r##"fn f() { let s = r#"{"x": "}"}"#; }"##;
        assert_eq!(brace_balance(src), 0);
        // nothing inside the raw string becomes an ident
        assert_eq!(idents(src), vec!["fn", "f", "let", "s", "r"]);
    }

    #[test]
    fn raw_string_multi_hash() {
        let src = "fn f() { let s = r##\"one \"# two {{\"##; }";
        assert_eq!(brace_balance(src), 0);
    }

    #[test]
    fn raw_identifiers_are_not_strings() {
        // r#fn is a raw identifier, not a raw string opener
        let src = "fn f() { let r#fn = 1; let x = r#fn; }";
        assert_eq!(brace_balance(src), 0);
        assert!(idents(src).iter().any(|w| w == "fn"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "fn f() { /* outer { /* inner } */ still } comment */ let x = 1; }";
        assert_eq!(brace_balance(src), 0);
        assert_eq!(idents(src), vec!["fn", "f", "let", "x"]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let src =
            "fn f<'a>(x: &'a str) { let c = 'x'; let b = '{'; let nl = '\\n'; let q = '\\''; }";
        assert_eq!(brace_balance(src), 0);
        let toks = lex(src);
        assert_eq!(toks.iter().filter(|t| t.kind == Kind::Lifetime).count(), 2);
        assert_eq!(toks.iter().filter(|t| t.kind == Kind::Char).count(), 4);
    }

    #[test]
    fn static_lifetime_and_placeholder() {
        let src = "fn f(x: &'static str, y: &'_ u8) {}";
        let toks = lex(src);
        let lts: Vec<_> = toks
            .iter()
            .filter(|t| t.kind == Kind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lts, vec!["static", "_"]);
        assert_eq!(brace_balance(src), 0);
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let src = "fn f() { let a = b\"{ not a brace }\"; let b2 = b'{'; let c = br#\"} }\"#; }";
        assert_eq!(brace_balance(src), 0);
    }

    #[test]
    fn macro_bodies_with_braces() {
        let src =
            "fn f() { let v = vec![{ 1 }, { 2 }]; assert!(matches!(v.len(), 2), \"{}\", 2); }";
        assert_eq!(brace_balance(src), 0);
    }

    #[test]
    fn format_strings_with_braces() {
        let src = "fn f(n: usize) { let s = format!(\"{{literal}} {n}\"); }";
        assert_eq!(brace_balance(src), 0);
        assert!(idents(src).iter().all(|w| w != "literal"));
    }

    #[test]
    fn escaped_quote_in_string() {
        let src = "fn f() { let s = \"a \\\" b { \"; }";
        assert_eq!(brace_balance(src), 0);
    }

    #[test]
    fn line_numbers_survive_multiline_constructs() {
        let src = "fn a() {}\n/* c1\n c2 */\nfn b() {}\nlet s = \"x\ny\";\nfn c() {}";
        let toks = lex(src);
        let line_of = |name: &str| {
            toks.iter()
                .find(|t| t.kind == Kind::Ident && t.text == name)
                .map(|t| t.line)
                .unwrap_or(0)
        };
        assert_eq!(line_of("a"), 1);
        assert_eq!(line_of("b"), 4);
        assert_eq!(line_of("c"), 6);
    }

    #[test]
    fn range_syntax_not_eaten_by_numbers() {
        let src = "fn f() { for i in 0..10 { let _ = i; } }";
        assert_eq!(brace_balance(src), 0);
        let nums: Vec<_> = lex(src)
            .into_iter()
            .filter(|t| t.kind == Kind::Num)
            .map(|t| t.text)
            .collect();
        assert_eq!(nums, vec!["0", "10"]);
    }

    #[test]
    fn floats_lex_as_one_number() {
        let src = "fn f() { let x = 1.5; let y = 2.0e3; }";
        let nums: Vec<_> = lex(src)
            .into_iter()
            .filter(|t| t.kind == Kind::Num)
            .map(|t| t.text)
            .collect();
        assert_eq!(nums, vec!["1.5", "2.0e3"]);
    }
}
