//! Timed-iteration runner with warmup and robust statistics.

use std::time::{Duration, Instant};

use crate::util::timeutil::fmt_duration;

/// Statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// Items/second, if items_per_iter was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n / self.mean.as_secs_f64().max(1e-12))
    }

    pub fn summary(&self) -> String {
        let tput = match self.throughput() {
            Some(t) => format!("  {:.1} k items/s", t / 1e3),
            None => String::new(),
        };
        format!(
            "{:<44} {:>10}/iter  p50 {:>10}  p99 {:>10}  ({} iters){tput}",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.p50),
            fmt_duration(self.p99),
            self.iters,
        )
    }
}

/// Harness configuration, parsed from bench argv.
#[derive(Clone, Debug)]
pub struct BenchArgs {
    /// Minimum measured iterations per case.
    pub min_iters: usize,
    /// Target measurement time per case.
    pub measure_time: Duration,
    /// Warmup time per case.
    pub warmup_time: Duration,
    /// Case-name filter (substring).
    pub filter: Option<String>,
    /// Scenario override for model benches (tiny/bench/base/long).
    pub scenario: Option<String>,
    /// Print figure series (Fig 12 mode) where supported.
    pub series: bool,
}

impl Default for BenchArgs {
    fn default() -> Self {
        BenchArgs {
            min_iters: 10,
            measure_time: Duration::from_secs(3),
            warmup_time: Duration::from_millis(500),
            filter: None,
            scenario: None,
            series: false,
        }
    }
}

impl BenchArgs {
    /// Parse `cargo bench -- <flags>` argv. Unknown flags are ignored so
    /// `cargo bench` harness flags (`--bench`) pass through.
    pub fn from_env() -> Self {
        let mut a = BenchArgs::default();
        let argv: Vec<String> = std::env::args().collect();
        let mut i = 1;
        while i < argv.len() {
            match argv[i].as_str() {
                "--min-iters" => {
                    i += 1;
                    a.min_iters = argv.get(i).and_then(|s| s.parse().ok()).unwrap_or(a.min_iters);
                }
                "--measure-ms" => {
                    i += 1;
                    if let Some(ms) = argv.get(i).and_then(|s| s.parse::<u64>().ok()) {
                        a.measure_time = Duration::from_millis(ms);
                    }
                }
                "--warmup-ms" => {
                    i += 1;
                    if let Some(ms) = argv.get(i).and_then(|s| s.parse::<u64>().ok()) {
                        a.warmup_time = Duration::from_millis(ms);
                    }
                }
                "--filter" => {
                    i += 1;
                    a.filter = argv.get(i).cloned();
                }
                "--scenario" => {
                    i += 1;
                    a.scenario = argv.get(i).cloned();
                }
                "--series" => a.series = true,
                _ => {}
            }
            i += 1;
        }
        a
    }

    pub fn wants(&self, name: &str) -> bool {
        self.filter.as_deref().map_or(true, |f| name.contains(f))
    }
}

/// The bench driver.
pub struct Bencher {
    pub args: BenchArgs,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(args: BenchArgs) -> Self {
        Bencher { args, results: Vec::new() }
    }

    pub fn from_env() -> Self {
        Self::new(BenchArgs::from_env())
    }

    /// Time `f` (one call = one iteration): warmup for `warmup_time`,
    /// then measure until both `min_iters` and `measure_time` are met.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> Option<BenchResult> {
        self.bench_with_items(name, None, f)
    }

    /// Like `bench`, with an items/iteration count for throughput rows
    /// (user-item pairs for the paper tables).
    pub fn bench_with_items<F: FnMut()>(
        &mut self,
        name: &str,
        items_per_iter: Option<f64>,
        mut f: F,
    ) -> Option<BenchResult> {
        if !self.args.wants(name) {
            return None;
        }
        // warmup
        let wstart = Instant::now();
        while wstart.elapsed() < self.args.warmup_time {
            f();
        }
        // measure
        let mut samples: Vec<Duration> = Vec::with_capacity(self.args.min_iters * 2);
        let mstart = Instant::now();
        while samples.len() < self.args.min_iters || mstart.elapsed() < self.args.measure_time {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
            if samples.len() >= 1_000_000 {
                break; // ultra-fast case; enough samples
            }
        }
        let r = summarize(name, &mut samples, items_per_iter);
        println!("{}", r.summary());
        self.results.push(r.clone());
        Some(r)
    }

    /// Look up a finished result by exact name.
    pub fn result(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }
}

fn summarize(name: &str, samples: &mut [Duration], items: Option<f64>) -> BenchResult {
    samples.sort();
    let n = samples.len();
    let idx = |q: f64| ((q * (n - 1) as f64).round() as usize).min(n - 1);
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean: total / (n as u32),
        p50: samples[idx(0.50)],
        p99: samples[idx(0.99)],
        min: samples[0],
        max: samples[n - 1],
        items_per_iter: items,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_args() -> BenchArgs {
        BenchArgs {
            min_iters: 5,
            measure_time: Duration::from_millis(5),
            warmup_time: Duration::from_millis(1),
            ..BenchArgs::default()
        }
    }

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bencher::new(fast_args());
        let r = b.bench("spin", || { std::hint::black_box(0); }).unwrap();
        assert!(r.iters >= 5);
        assert!(r.min <= r.p50 && r.p50 <= r.max);
        assert!(b.result("spin").is_some());
    }

    #[test]
    fn filter_skips() {
        let mut args = fast_args();
        args.filter = Some("wanted".to_string());
        let mut b = Bencher::new(args);
        assert!(b.bench("other", || {}).is_none());
        assert!(b.bench("wanted_case", || {}).is_some());
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bencher::new(fast_args());
        let r = b
            .bench_with_items("items", Some(100.0), || {
                std::thread::sleep(Duration::from_micros(100));
            })
            .unwrap();
        let t = r.throughput().unwrap();
        // 100 items / ~100µs ≈ 1e6 items/s, allow broad slack for CI noise
        assert!(t > 1e5 && t < 2e7, "throughput {t}");
    }

    #[test]
    fn summarize_orders_quantiles() {
        let mut samples: Vec<Duration> =
            (1..=100).map(|i| Duration::from_micros(i)).collect();
        let r = summarize("s", &mut samples, None);
        assert_eq!(r.iters, 100);
        assert!(r.p50 >= Duration::from_micros(49) && r.p50 <= Duration::from_micros(52));
        assert!(r.p99 >= Duration::from_micros(98));
    }
}
