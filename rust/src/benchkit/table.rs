//! Paper-style ASCII table rendering for bench output: every bench binary
//! prints the rows of the table/figure it regenerates (Table 3/4/5,
//! Fig 12/13) in the paper's own column layout.

/// Simple column-aligned table with a title and optional footnote.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    footnotes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            footnotes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn footnote(&mut self, note: &str) -> &mut Self {
        self.footnotes.push(note.to_string());
        self
    }

    /// Render to a string (also used by tests; `print` writes to stdout).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("\n{}\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        for n in &self.footnotes {
            out.push_str(&format!("  * {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a ratio as the paper quotes them: "1.9x".
pub fn ratio(new: f64, old: f64) -> String {
    if old <= 0.0 {
        return "n/a".to_string();
    }
    format!("{:.1}x", new / old)
}

/// Milliseconds with paper-style precision.
pub fn ms(v: f64) -> String {
    format!("{v:.2} ms")
}

/// Throughput in "k" user-item pairs/s, paper-style.
pub fn kthroughput(pairs_per_s: f64) -> String {
    format!("{:.1} k", pairs_per_s / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Table X", &["Ablation", "Throughput", "Latency"]);
        t.row_strs(&["-Cache", "67.4 k", "22.6 ms"]);
        t.row_strs(&["+Cache, +Mem Opt (Full PDA)", "126.6 k", "13.2 ms"]);
        t.footnote("throughput in thousands of user-item pairs/s");
        let s = t.render();
        assert!(s.contains("Table X"));
        assert!(s.contains("| -Cache "));
        assert!(s.contains("126.6 k"));
        assert!(s.contains("* throughput"));
        // all body lines same width
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|') || l.starts_with('+')).collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row_strs(&["only one"]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ratio(190.0, 100.0), "1.9x");
        assert_eq!(ratio(1.0, 0.0), "n/a");
        assert_eq!(ms(13.2), "13.20 ms");
        assert_eq!(kthroughput(126_600.0), "126.6 k");
    }
}
