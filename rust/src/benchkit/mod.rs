//! Mini-criterion: the bench harness behind every `cargo bench` target
//! (criterion itself is not in the offline vendor set). Provides warmup,
//! timed iteration with outlier-robust statistics, paper-style table
//! rendering, and a tiny argv parser for bench flags.

pub mod runner;
pub mod table;

pub use runner::{BenchArgs, BenchResult, Bencher};
pub use table::Table;
