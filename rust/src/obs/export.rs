//! Chrome trace-event export (Perfetto-compatible) + a minimal schema
//! checker for CI.
//!
//! Layout: pid = replica id, tid = worker thread, "X" complete events
//! for stage and shared spans, "s"/"f" flow pairs for the causal links
//! (rider → coalesced launch, waiter → single-flight leader), "M"
//! metadata events naming processes and threads. Load the file at
//! <https://ui.perfetto.dev> and follow the flow arrows.

use std::collections::{BTreeMap, BTreeSet};

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

use super::{SharedSpan, StageKind, Trace, TraceDump};

fn x_event(name: &str, cat: &str, pid: u32, tid: u64, ts: u64, dur: u64, args: Json) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("cat", Json::str(cat)),
        ("ph", Json::str("X")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("ts", Json::num(ts as f64)),
        ("dur", Json::num(dur.max(1) as f64)),
        ("args", args),
    ])
}

fn flow_pair(
    events: &mut Vec<Json>,
    arrow_id: u64,
    src: (&u32, u64, u64), // (pid, tid, ts)
    dst: (u32, u64, u64),
) {
    events.push(Json::obj(vec![
        ("name", Json::str("coalesce")),
        ("cat", Json::str("flow")),
        ("ph", Json::str("s")),
        ("id", Json::num(arrow_id as f64)),
        ("pid", Json::num(*src.0 as f64)),
        ("tid", Json::num(src.1 as f64)),
        ("ts", Json::num(src.2 as f64)),
    ]));
    events.push(Json::obj(vec![
        ("name", Json::str("coalesce")),
        ("cat", Json::str("flow")),
        ("ph", Json::str("f")),
        ("bp", Json::str("e")),
        ("id", Json::num(arrow_id as f64)),
        ("pid", Json::num(dst.0 as f64)),
        ("tid", Json::num(dst.1 as f64)),
        ("ts", Json::num(dst.2 as f64)),
    ]));
}

/// Render a [`TraceDump`] as Chrome trace-event JSON.
pub fn chrome_trace_json(dump: &TraceDump) -> String {
    let mut events: Vec<Json> = Vec::new();
    let mut arrow = 0u64;

    // dedupe traces retained in more than one store (ring + sla + slow)
    let mut traces: BTreeMap<u64, &Trace> = BTreeMap::new();
    for t in dump.traces.iter().chain(&dump.sla).chain(&dump.slowest) {
        traces.entry(t.trace_id).or_insert(t);
    }
    let shared: BTreeMap<u64, &SharedSpan> =
        dump.shared.iter().map(|s| (s.span_id, s)).collect();

    let mut seen_threads: BTreeSet<(u32, u64)> = BTreeSet::new();

    // shared (multi-request) spans
    for s in dump.shared.iter() {
        seen_threads.insert((s.pid, s.tid));
        let members: Vec<Json> =
            s.member_traces.iter().map(|&m| Json::num(m as f64)).collect();
        events.push(x_event(
            &s.label,
            s.kind.label(),
            s.pid,
            s.tid,
            s.begin_us,
            s.end_us.saturating_sub(s.begin_us),
            Json::obj(vec![
                ("span_id", Json::num(s.span_id as f64)),
                ("riders", Json::num(s.member_traces.len() as f64)),
                ("member_traces", Json::Arr(members)),
            ]),
        ));
    }

    // per-request traces
    for t in traces.values() {
        seen_threads.insert((t.pid, t.tid));
        let verdict = t.verdict.map(|v| v.label()).unwrap_or("-");
        events.push(x_event(
            &format!("request {}", t.request_id),
            "request",
            t.pid,
            t.tid,
            t.begin_us,
            t.total_us,
            Json::obj(vec![
                ("trace_id", Json::num(t.trace_id as f64)),
                ("budget_us", Json::num(t.budget_us as f64)),
                ("sla_missed", Json::Bool(t.sla_missed)),
                ("verdict", Json::str(verdict)),
            ]),
        ));
        for sp in &t.spans {
            seen_threads.insert((t.pid, sp.tid));
            events.push(x_event(
                sp.kind.label(),
                "stage",
                t.pid,
                sp.tid,
                sp.begin_us,
                sp.dur_us(),
                Json::obj(vec![
                    ("trace_id", Json::num(t.trace_id as f64)),
                    ("request_id", Json::num(t.request_id as f64)),
                ]),
            ));
            for &link in &sp.links {
                if let Some(src) = shared.get(&link) {
                    arrow += 1;
                    flow_pair(
                        &mut events,
                        arrow,
                        (&src.pid, src.tid, src.begin_us),
                        (t.pid, sp.tid, sp.begin_us),
                    );
                }
            }
        }
    }

    // out-of-band flows: bind to the rider's feature span if it has
    // one (that is where a shared fetch was waited on), else its first
    for &(trace_id, span_id) in &dump.flows {
        let (Some(t), Some(src)) = (traces.get(&trace_id), shared.get(&span_id)) else {
            continue;
        };
        let bind = t
            .spans
            .iter()
            .find(|s| s.kind == StageKind::Feature)
            .or_else(|| t.spans.first());
        if let Some(sp) = bind {
            arrow += 1;
            flow_pair(
                &mut events,
                arrow,
                (&src.pid, src.tid, src.begin_us),
                (t.pid, sp.tid, sp.begin_us),
            );
        }
    }

    // metadata: process / thread names
    let names: BTreeMap<u64, String> = super::thread_names().into_iter().collect();
    let pids: BTreeSet<u32> = seen_threads.iter().map(|&(p, _)| p).collect();
    for pid in pids {
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            ("args", Json::obj(vec![("name", Json::str(format!("flame replica {pid}")))])),
        ]));
    }
    for (pid, tid) in seen_threads {
        let name = names.get(&tid).cloned().unwrap_or_else(|| format!("thread-{tid}"));
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("args", Json::obj(vec![("name", Json::str(name))])),
        ]));
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
    .to_string()
}

/// What [`validate_chrome_trace`] counted — CI asserts on these.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCheck {
    pub events: usize,
    pub spans: usize,
    pub flow_starts: usize,
    pub flow_ends: usize,
    pub metadata: usize,
}

/// Minimal schema check over an emitted trace file: a `traceEvents`
/// array whose "X" events carry pid/tid/ts/dur/name, whose flow events
/// carry an id, and whose every flow finish has a matching start.
pub fn validate_chrome_trace(text: &str) -> Result<TraceCheck> {
    let doc = json::parse(text)?;
    let events = doc.get("traceEvents")?.as_arr()?;
    let mut check = TraceCheck::default();
    let mut starts: BTreeSet<u64> = BTreeSet::new();
    let mut ends: BTreeSet<u64> = BTreeSet::new();
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(|p| p.as_str().map(str::to_string))
            .map_err(|_| Error::Json(format!("event {i}: missing ph")))?;
        check.events += 1;
        match ph.as_str() {
            "X" => {
                for k in ["pid", "tid", "ts", "dur"] {
                    e.get(k)?
                        .as_f64()
                        .map_err(|_| Error::Json(format!("event {i}: bad {k}")))?;
                }
                e.get("name")?.as_str()?;
                check.spans += 1;
            }
            "s" | "f" => {
                let id = e.get("id")?.as_u64()?;
                for k in ["pid", "tid", "ts"] {
                    e.get(k)?.as_f64()?;
                }
                if ph == "s" {
                    starts.insert(id);
                    check.flow_starts += 1;
                } else {
                    ends.insert(id);
                    check.flow_ends += 1;
                }
            }
            "M" => {
                e.get("name")?.as_str()?;
                check.metadata += 1;
            }
            other => {
                return Err(Error::Json(format!("event {i}: unexpected ph {other:?}")));
            }
        }
    }
    if check.spans == 0 {
        return Err(Error::Json("trace has no span events".into()));
    }
    for id in &ends {
        if !starts.contains(id) {
            return Err(Error::Json(format!("flow finish {id} has no start")));
        }
    }
    Ok(check)
}

#[cfg(test)]
mod tests {
    use super::super::Tracer;
    use super::*;

    fn sample_dump() -> TraceDump {
        let t = Tracer::with_caps(1, 16, 16, 4, 16);
        let launch = t.new_span_id();
        t.emit_shared(SharedSpan {
            span_id: launch,
            kind: StageKind::Launch,
            label: "launch m=256".into(),
            begin_us: 50,
            end_us: 150,
            pid: 0,
            tid: super::super::tid(),
            member_traces: vec![1, 2],
        });
        for req in [7u64, 8] {
            let mut ctx = t.begin(req, 10_000).unwrap();
            ctx.span(StageKind::Feature, 0, 40);
            ctx.span_linked(StageKind::Compute, 40, 160, &[launch]);
            t.finish(ctx, 0, req == 8);
        }
        t.dump()
    }

    #[test]
    fn export_roundtrips_through_checker() {
        let text = chrome_trace_json(&sample_dump());
        let check = validate_chrome_trace(&text).unwrap();
        assert!(check.spans >= 5, "{check:?}"); // 1 launch + 2x(request + 2 stages)
        assert_eq!(check.flow_starts, check.flow_ends);
        assert!(check.flow_starts >= 2, "one arrow per rider: {check:?}");
        assert!(check.metadata >= 2, "{check:?}");
    }

    #[test]
    fn export_contains_launch_members_and_verdicts() {
        let text = chrome_trace_json(&sample_dump());
        assert!(text.contains("member_traces"), "{text}");
        assert!(text.contains("launch m=256"), "{text}");
        assert!(text.contains("sla_missed"), "{text}");
    }

    #[test]
    fn out_of_band_flow_binds_to_feature_span() {
        let mut dump = sample_dump();
        let rider = dump.traces[0].trace_id;
        let span = dump.shared[0].span_id;
        let before = validate_chrome_trace(&chrome_trace_json(&dump)).unwrap();
        dump.flows.push((rider, span));
        let after = validate_chrome_trace(&chrome_trace_json(&dump)).unwrap();
        assert_eq!(after.flow_starts, before.flow_starts + 1);
    }

    #[test]
    fn checker_rejects_malformed() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{}").is_err());
        assert!(validate_chrome_trace(r#"{"traceEvents":[]}"#).is_err());
        // X missing dur
        assert!(validate_chrome_trace(
            r#"{"traceEvents":[{"ph":"X","pid":0,"tid":1,"ts":0,"name":"x"}]}"#
        )
        .is_err());
        // flow finish without start
        assert!(validate_chrome_trace(
            r#"{"traceEvents":[
                {"ph":"X","pid":0,"tid":1,"ts":0,"dur":1,"name":"x"},
                {"ph":"f","bp":"e","id":9,"pid":0,"tid":1,"ts":0}]}"#
        )
        .is_err());
    }

    #[test]
    fn checker_accepts_minimal_valid() {
        let ok = validate_chrome_trace(
            r#"{"traceEvents":[
                {"ph":"X","pid":0,"tid":1,"ts":0,"dur":5,"name":"compute"},
                {"ph":"s","id":3,"pid":0,"tid":1,"ts":0},
                {"ph":"f","bp":"e","id":3,"pid":0,"tid":2,"ts":1},
                {"ph":"M","name":"process_name","pid":0,"args":{"name":"p"}}]}"#,
        )
        .unwrap();
        assert_eq!(
            ok,
            TraceCheck { events: 4, spans: 1, flow_starts: 1, flow_ends: 1, metadata: 1 }
        );
    }
}
