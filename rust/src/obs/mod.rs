//! Request-scoped tracing — observability kept off the hot path.
//!
//! The aggregate [`crate::metrics::Recorder`] answers "how is the fleet
//! doing"; this module answers "why was *this* request slow", which
//! PRs 3–5 made genuinely hard: a request's compute may run inside
//! another request's coalesced FKE launch, and its feature fetch or
//! even its whole response may ride a single-flight leader it never
//! met. The tracer therefore records *causal links*: a shared launch
//! emits one span whose member list names every rider's trace id, and
//! each rider's own span links back to the launch span id, so the
//! Chrome-trace export can draw flow arrows across requests.
//!
//! Cost model (mirrors the lock-free `Histogram` philosophy):
//! - tracing off (`trace_sample_n = 0` or no tracer attached): the
//!   request path sees one `OnceLock::get` returning `None` — no
//!   allocation, no lock, no atomic write;
//! - tracing on, request not head-sampled: the request carries a
//!   [`TraceContext`] with an *empty* span vec (`Vec::new` does not
//!   allocate); only its trace id is live so shared spans can still
//!   list it as a rider;
//! - head-sampled: spans are pushed into the context (thread-local,
//!   unsynchronized) and the completed trace lands in a bounded,
//!   sharded ring at finish — the only synchronized step.
//!
//! Tail retention keeps what head sampling would lose: every SLA-miss
//! exemplar (bounded, newest-first) and the top-k slowest traces
//! survive ring wraparound, each carrying an attribution verdict — the
//! stage that consumed the largest share of the deadline budget —
//! which is also mirrored into the `Recorder`'s per-stage SLA-miss
//! counters.

pub mod export;
pub mod prom;

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Pipeline stage a span belongs to. `Launch`/`Fetch`/`Cache` are the
/// shared (multi-request) span kinds; the rest are per-request stages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageKind {
    /// Intake-queue wait before a feature worker picks the request up.
    Queue,
    /// Feature assembly (PDA fetch + staging).
    Feature,
    /// Decoupled-pipeline handoff wait between feature and compute.
    Handoff,
    /// Model compute (DSO submit through score return).
    Compute,
    /// A shared engine launch carrying one or more requests' rows.
    Launch,
    /// A shared feature multiget executed by the fetch coalescer.
    Fetch,
    /// Result-cache interaction (hit / single-flight wait).
    Cache,
    Other,
}

impl StageKind {
    pub fn label(self) -> &'static str {
        match self {
            StageKind::Queue => "queue",
            StageKind::Feature => "feature",
            StageKind::Handoff => "handoff",
            StageKind::Compute => "compute",
            StageKind::Launch => "launch",
            StageKind::Fetch => "fetch",
            StageKind::Cache => "cache",
            StageKind::Other => "other",
        }
    }
}

/// One timed stage inside a request's trace.
#[derive(Clone, Debug)]
pub struct Span {
    pub kind: StageKind,
    pub begin_us: u64,
    pub end_us: u64,
    /// Worker thread that ran the stage (stable small id, see [`tid`]).
    pub tid: u64,
    /// Span ids of shared spans (launch / fetch / flight) this stage
    /// waited on — the cross-request causality edges.
    pub links: Vec<u64>,
}

impl Span {
    pub fn dur_us(&self) -> u64 {
        self.end_us.saturating_sub(self.begin_us)
    }
}

/// A completed request trace.
#[derive(Clone, Debug)]
pub struct Trace {
    pub trace_id: u64,
    pub request_id: u64,
    /// Replica id (0 for a standalone stack); Chrome-trace pid.
    pub pid: u32,
    /// Thread the trace finished on.
    pub tid: u64,
    pub begin_us: u64,
    pub total_us: u64,
    pub budget_us: u64,
    pub sla_missed: bool,
    /// Stage that consumed the largest share of the budget (None when
    /// the trace carried no spans — e.g. a sampled-out SLA miss).
    pub verdict: Option<StageKind>,
    pub spans: Vec<Span>,
}

/// A span emitted once on behalf of many requests: a coalesced engine
/// launch, a shared feature multiget, or a single-flight result-cache
/// computation. `member_traces` lists every rider — including riders
/// that head sampling dropped, so causality survives sampling.
#[derive(Clone, Debug)]
pub struct SharedSpan {
    pub span_id: u64,
    pub kind: StageKind,
    pub label: String,
    pub begin_us: u64,
    pub end_us: u64,
    pub pid: u32,
    pub tid: u64,
    pub member_traces: Vec<u64>,
}

/// Per-request tracing state, created at admission and finished at
/// response. Unsampled contexts carry only the (Copy) ids — their span
/// vec is empty and never grows, so they are allocation-free.
#[derive(Debug)]
pub struct TraceContext {
    trace_id: u64,
    request_id: u64,
    budget_us: u64,
    epoch: Instant,
    t0_us: u64,
    sampled: bool,
    spans: Vec<Span>,
}

impl TraceContext {
    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    pub fn request_id(&self) -> u64 {
        self.request_id
    }

    pub fn sampled(&self) -> bool {
        self.sampled
    }

    pub fn budget_us(&self) -> u64 {
        self.budget_us
    }

    /// Microseconds since the owning tracer's epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Microseconds since this trace began (admission) — what the SLA
    /// check compares against `budget_us`.
    pub fn elapsed_us(&self) -> u64 {
        self.now_us().saturating_sub(self.t0_us)
    }

    /// Record a stage span (no-op unless head-sampled).
    pub fn span(&mut self, kind: StageKind, begin_us: u64, end_us: u64) {
        self.span_linked(kind, begin_us, end_us, &[]);
    }

    /// Record a stage span that waited on shared spans `links` (id 0 =
    /// untraced, filtered out).
    pub fn span_linked(&mut self, kind: StageKind, begin_us: u64, end_us: u64, links: &[u64]) {
        if !self.sampled {
            return;
        }
        let links: Vec<u64> = links.iter().copied().filter(|&l| l != 0).collect();
        self.spans.push(Span { kind, begin_us, end_us, tid: tid(), links });
    }

    /// Record a stage span ending now with a known duration.
    pub fn span_ending_now(&mut self, kind: StageKind, dur_us: u64) {
        if !self.sampled {
            return;
        }
        let end = self.now_us();
        self.span(kind, end.saturating_sub(dur_us), end);
    }

    /// Attach a shared-span link to the most recent span (no-op when
    /// unsampled, id 0, or no span recorded yet).
    pub fn link_last(&mut self, span_id: u64) {
        if !self.sampled || span_id == 0 {
            return;
        }
        if let Some(s) = self.spans.last_mut() {
            s.links.push(span_id);
        }
    }
}

/// Everything the tracer retained, for export and tests.
#[derive(Clone, Debug, Default)]
pub struct TraceDump {
    /// Head-sampled traces still in the ring (newest survive overflow).
    pub traces: Vec<Trace>,
    /// SLA-miss exemplars (tail retention, survives ring wraparound).
    pub sla: Vec<Trace>,
    /// Top-k slowest traces (tail retention).
    pub slowest: Vec<Trace>,
    /// Shared launch / fetch / flight spans.
    pub shared: Vec<SharedSpan>,
    /// Extra (rider trace id → shared span id) edges reported out of
    /// band where no rider span existed yet to carry the link.
    pub flows: Vec<(u64, u64)>,
}

const RING_SHARDS: usize = 8;

/// The tracing sink: head-sampling admission, bounded sharded rings for
/// completed traces, tail retention for SLA misses and slowest
/// exemplars, and a bounded store of shared (cross-request) spans.
pub struct Tracer {
    epoch: Instant,
    sample_n: u64,
    admit: AtomicU64,
    next_trace: AtomicU64,
    next_span: AtomicU64,
    ring: Vec<Mutex<VecDeque<Trace>>>,
    ring_cap: usize,
    sla: Mutex<VecDeque<Trace>>,
    sla_cap: usize,
    slowest: Mutex<Vec<Trace>>,
    slow_k: usize,
    shared: Mutex<VecDeque<SharedSpan>>,
    shared_cap: usize,
    flows: Mutex<VecDeque<(u64, u64)>>,
}

impl Tracer {
    /// `sample_n`: head sampling keeps 1 in `sample_n` traces (1 =
    /// every request, 0 = tracing disabled — `begin` returns `None`).
    pub fn new(sample_n: u64) -> Tracer {
        Self::with_caps(sample_n, 512, 256, 32, 4096)
    }

    /// Fully parameterized constructor (tests shrink the caps).
    pub fn with_caps(
        sample_n: u64,
        ring_cap_per_shard: usize,
        sla_cap: usize,
        slow_k: usize,
        shared_cap: usize,
    ) -> Tracer {
        let mut ring = Vec::with_capacity(RING_SHARDS);
        for _ in 0..RING_SHARDS {
            ring.push(Mutex::new(VecDeque::new()));
        }
        Tracer {
            epoch: Instant::now(),
            sample_n,
            admit: AtomicU64::new(0),
            next_trace: AtomicU64::new(0),
            next_span: AtomicU64::new(0),
            ring,
            ring_cap: ring_cap_per_shard.max(1),
            sla: Mutex::new(VecDeque::new()),
            sla_cap: sla_cap.max(1),
            slowest: Mutex::new(Vec::new()),
            slow_k: slow_k.max(1),
            shared: Mutex::new(VecDeque::new()),
            shared_cap: shared_cap.max(1),
            flows: Mutex::new(VecDeque::new()),
        }
    }

    pub fn sample_n(&self) -> u64 {
        self.sample_n
    }

    /// Microseconds since the tracer's epoch (all span timestamps share
    /// this clock so the export lines up across threads).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Admit one request. Every admitted request gets a live trace id
    /// (cheap: one atomic) so shared spans can name it as a rider; only
    /// 1-in-`sample_n` get span recording.
    pub fn begin(&self, request_id: u64, budget_us: u64) -> Option<TraceContext> {
        if self.sample_n == 0 {
            return None;
        }
        let trace_id = self.next_trace.fetch_add(1, Ordering::Relaxed) + 1;
        let sampled = self.admit.fetch_add(1, Ordering::Relaxed) % self.sample_n == 0;
        Some(TraceContext {
            trace_id,
            request_id,
            budget_us,
            epoch: self.epoch,
            t0_us: self.now_us(),
            sampled,
            spans: if sampled { Vec::with_capacity(8) } else { Vec::new() },
        })
    }

    /// Allocate an id for a shared span (nonzero; 0 means "untraced").
    pub fn new_span_id(&self) -> u64 {
        self.next_span.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Record a shared (multi-request) span.
    pub fn emit_shared(&self, span: SharedSpan) {
        let mut s = self.shared.lock().unwrap();
        if s.len() >= self.shared_cap {
            s.pop_front();
        }
        s.push_back(span);
    }

    /// Report a causality edge out of band (rider trace → shared span)
    /// for paths where the rider has no span yet to carry the link —
    /// e.g. a feature id that rode another request's in-flight fetch.
    pub fn flow(&self, trace_id: u64, span_id: u64) {
        if trace_id == 0 || span_id == 0 {
            return;
        }
        let mut f = self.flows.lock().unwrap();
        if f.len() >= self.shared_cap {
            f.pop_front();
        }
        f.push_back((trace_id, span_id));
    }

    /// Finish a trace: compute the attribution verdict (stage with the
    /// largest span duration) and retain the trace — ring for sampled
    /// traces, the SLA store for misses (even unsampled ones, so the
    /// miss itself is never lost), and the top-k slowest set.
    pub fn finish(&self, ctx: TraceContext, pid: u32, sla_missed: bool) -> Option<StageKind> {
        let total_us = self.now_us().saturating_sub(ctx.t0_us);
        let verdict = ctx
            .spans
            .iter()
            .max_by_key(|s| s.dur_us())
            .map(|s| s.kind);
        let sampled = ctx.sampled;
        if !sampled && !sla_missed {
            return verdict;
        }
        let trace = Trace {
            trace_id: ctx.trace_id,
            request_id: ctx.request_id,
            pid,
            tid: tid(),
            begin_us: ctx.t0_us,
            total_us,
            budget_us: ctx.budget_us,
            sla_missed,
            verdict,
            spans: ctx.spans,
        };
        if sla_missed {
            let mut sla = self.sla.lock().unwrap();
            if sla.len() >= self.sla_cap {
                sla.pop_front();
            }
            sla.push_back(trace.clone());
        }
        if sampled {
            {
                let mut slow = self.slowest.lock().unwrap();
                slow.push(trace.clone());
                slow.sort_by(|a, b| b.total_us.cmp(&a.total_us));
                slow.truncate(self.slow_k);
            }
            let shard = (trace.tid as usize) % RING_SHARDS;
            let mut ring = self.ring[shard].lock().unwrap();
            if ring.len() >= self.ring_cap {
                ring.pop_front();
            }
            ring.push_back(trace);
        }
        verdict
    }

    /// Copy out everything retained.
    pub fn dump(&self) -> TraceDump {
        let mut traces = Vec::new();
        for shard in &self.ring {
            traces.extend(shard.lock().unwrap().iter().cloned());
        }
        TraceDump {
            traces,
            sla: self.sla.lock().unwrap().iter().cloned().collect(),
            slowest: self.slowest.lock().unwrap().clone(),
            shared: self.shared.lock().unwrap().iter().cloned().collect(),
            flows: self.flows.lock().unwrap().iter().cloned().collect(),
        }
    }
}

// ---- thread identity (stable small tids for the Chrome export) ----

static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static THREAD_NAMES: Mutex<Vec<(u64, String)>> = Mutex::new(Vec::new());

thread_local! {
    static TID: Cell<u64> = const { Cell::new(0) };
    static CURRENT_TRACE: Cell<u64> = const { Cell::new(0) };
}

/// Stable small id for the calling thread; registers the thread's name
/// on first use. Only called on traced paths (allocates the name once
/// per thread).
pub fn tid() -> u64 {
    TID.with(|t| {
        let v = t.get();
        if v != 0 {
            return v;
        }
        let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        t.set(v);
        let name = std::thread::current().name().unwrap_or("worker").to_string();
        if let Ok(mut names) = THREAD_NAMES.lock() {
            names.push((v, name));
        }
        v
    })
}

/// (tid, thread name) pairs registered so far.
pub fn thread_names() -> Vec<(u64, String)> {
    THREAD_NAMES.lock().map(|n| n.clone()).unwrap_or_default()
}

/// Mark the trace the calling thread is currently assembling for (0 =
/// none). Deep shared paths (the fetch coalescer) read this instead of
/// threading a context parameter through every signature.
// lint: no_alloc — per-request hot path, must stay allocation-free
pub fn set_current_trace(trace_id: u64) {
    CURRENT_TRACE.with(|c| c.set(trace_id));
}

/// Trace id the calling thread is currently working for (0 = none).
// lint: no_alloc — per-request hot path, must stay allocation-free
pub fn current_trace() -> u64 {
    CURRENT_TRACE.with(|c| c.get())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_admits_nothing() {
        let t = Tracer::new(0);
        assert!(t.begin(1, 1_000).is_none());
    }

    #[test]
    fn head_sampling_keeps_one_in_n() {
        let t = Tracer::new(4);
        let sampled = (0..16)
            .filter(|&i| t.begin(i, 0).unwrap().sampled())
            .count();
        assert_eq!(sampled, 4);
        // every admitted request still got a distinct live trace id
        let a = t.begin(100, 0).unwrap();
        let b = t.begin(101, 0).unwrap();
        assert_ne!(a.trace_id(), b.trace_id());
        assert_ne!(a.trace_id(), 0);
    }

    #[test]
    fn unsampled_context_records_no_spans() {
        let t = Tracer::new(2);
        let _first = t.begin(0, 0).unwrap(); // sampled
        let mut ctx = t.begin(1, 0).unwrap(); // not sampled
        assert!(!ctx.sampled());
        ctx.span(StageKind::Compute, 0, 10);
        ctx.span_linked(StageKind::Feature, 0, 5, &[7]);
        assert!(ctx.spans.is_empty(), "unsampled ctx must stay empty");
    }

    #[test]
    fn finish_computes_dominant_stage_verdict() {
        let t = Tracer::new(1);
        let mut ctx = t.begin(9, 10_000).unwrap();
        ctx.span(StageKind::Feature, 0, 100);
        ctx.span(StageKind::Compute, 100, 9_000);
        ctx.span(StageKind::Queue, 0, 10);
        let verdict = t.finish(ctx, 0, true);
        assert_eq!(verdict, Some(StageKind::Compute));
        let d = t.dump();
        assert_eq!(d.sla.len(), 1);
        assert_eq!(d.sla[0].verdict, Some(StageKind::Compute));
        assert!(d.sla[0].sla_missed);
    }

    #[test]
    fn ring_wraparound_keeps_newest_and_all_sla_exemplars() {
        // tiny ring: 2 per shard; everything lands on this test thread's
        // shard, so >2 finishes force wraparound
        let t = Tracer::with_caps(1, 2, 64, 4, 64);
        for i in 0..20u64 {
            let mut ctx = t.begin(i, 1).unwrap();
            ctx.span(StageKind::Compute, 0, 10 + i);
            // every 5th request misses its SLA
            t.finish(ctx, 0, i % 5 == 0);
        }
        let d = t.dump();
        assert_eq!(d.traces.len(), 2, "ring bounded per shard");
        let newest: Vec<u64> = d.traces.iter().map(|tr| tr.request_id).collect();
        assert!(newest.contains(&18) && newest.contains(&19), "newest survive: {newest:?}");
        let missed: Vec<u64> = d.sla.iter().map(|tr| tr.request_id).collect();
        assert_eq!(missed, vec![0, 5, 10, 15], "all SLA exemplars retained across wraparound");
    }

    #[test]
    fn slowest_exemplars_are_top_k() {
        let t = Tracer::with_caps(1, 4, 4, 2, 64);
        for i in 0..8u64 {
            let mut ctx = t.begin(i, 0).unwrap();
            ctx.span(StageKind::Compute, 0, i * 100);
            std::thread::sleep(std::time::Duration::from_micros(200 * i));
            t.finish(ctx, 0, false);
        }
        let d = t.dump();
        assert_eq!(d.slowest.len(), 2);
        assert!(d.slowest[0].total_us >= d.slowest[1].total_us);
    }

    #[test]
    fn sampled_out_rider_still_listed_on_shared_span() {
        let t = Tracer::with_caps(2, 8, 8, 4, 64);
        let riders: Vec<TraceContext> =
            (0..4).map(|i| t.begin(i, 0).unwrap()).collect();
        // 1-in-2 sampling: half the riders carry no spans
        assert!(riders.iter().any(|r| !r.sampled()));
        let launch_id = t.new_span_id();
        let members: Vec<u64> = riders.iter().map(|r| r.trace_id()).collect();
        t.emit_shared(SharedSpan {
            span_id: launch_id,
            kind: StageKind::Launch,
            label: "launch m=8".into(),
            begin_us: 0,
            end_us: 100,
            pid: 0,
            tid: tid(),
            member_traces: members.clone(),
        });
        for mut r in riders {
            r.span_linked(StageKind::Compute, 0, 100, &[launch_id]);
            t.finish(r, 0, false);
        }
        let d = t.dump();
        assert_eq!(d.shared.len(), 1);
        // every rider — sampled or not — appears on the launch span
        assert_eq!(d.shared[0].member_traces, members);
        // and each *sampled* trace carries the flow link back
        for tr in &d.traces {
            let linked = tr.spans.iter().any(|s| s.links.contains(&launch_id));
            assert!(linked, "sampled rider missing launch link: {tr:?}");
        }
        assert!(!d.traces.is_empty());
    }

    #[test]
    fn unsampled_sla_miss_is_still_retained() {
        let t = Tracer::with_caps(1_000_000, 4, 4, 4, 4);
        let _sampled = t.begin(0, 1).unwrap();
        let ctx = t.begin(1, 1).unwrap();
        assert!(!ctx.sampled());
        t.finish(ctx, 0, true);
        let d = t.dump();
        assert_eq!(d.sla.len(), 1);
        assert_eq!(d.sla[0].verdict, None, "no spans -> no verdict");
    }

    #[test]
    fn out_of_band_flows_are_bounded_and_dumped() {
        let t = Tracer::with_caps(1, 4, 4, 4, 3);
        t.flow(0, 5); // ignored: no trace
        t.flow(5, 0); // ignored: no span
        for i in 1..=5u64 {
            t.flow(i, 100 + i);
        }
        let d = t.dump();
        assert_eq!(d.flows.len(), 3, "bounded");
        assert_eq!(d.flows, vec![(3, 103), (4, 104), (5, 105)]);
    }

    #[test]
    fn current_trace_is_thread_local() {
        set_current_trace(42);
        assert_eq!(current_trace(), 42);
        let other = std::thread::spawn(|| current_trace()).join().unwrap();
        assert_eq!(other, 0);
        set_current_trace(0);
        assert_eq!(current_trace(), 0);
    }

    #[test]
    fn tids_are_stable_and_distinct() {
        let a = tid();
        assert_eq!(a, tid());
        let b = std::thread::spawn(|| tid()).join().unwrap();
        assert_ne!(a, b);
        let names = thread_names();
        assert!(names.iter().any(|(id, _)| *id == a));
    }
}
