//! Prometheus-style text exposition of the live [`MetricsSnapshot`],
//! plus a minimal HTTP/1.1 endpoint (`flame serve --metrics-addr`) so a
//! running server can be scraped without stopping it. No HTTP library
//! in the offline image — the server speaks just enough of the
//! protocol for `curl` and a Prometheus scraper: read the request head,
//! answer `200 text/plain; version=0.0.4`, close.

use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::metrics::MetricsSnapshot;

fn metric(out: &mut String, name: &str, help: &str, ty: &str, value: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {ty}");
    if value.fract() == 0.0 && value.abs() < 9e15 {
        let _ = writeln!(out, "{name} {}", value as i64);
    } else {
        let _ = writeln!(out, "{name} {value}");
    }
}

/// Render one snapshot in Prometheus text exposition format 0.0.4.
pub fn render(s: &MetricsSnapshot) -> String {
    let mut o = String::with_capacity(4096);
    metric(&mut o, "flame_requests_total", "Completed requests.", "counter", s.requests as f64);
    metric(
        &mut o,
        "flame_pairs_total",
        "Scored user-item pairs (the paper's throughput unit).",
        "counter",
        s.pairs as f64,
    );
    metric(
        &mut o,
        "flame_throughput_pairs_per_s",
        "User-item pairs per second over the snapshot window.",
        "gauge",
        s.throughput_pairs_per_s,
    );
    metric(&mut o, "flame_overall_mean_ms", "End-to-end latency mean.", "gauge", s.overall_mean_ms);
    metric(&mut o, "flame_overall_p50_ms", "End-to-end latency p50.", "gauge", s.overall_p50_ms);
    metric(&mut o, "flame_overall_p99_ms", "End-to-end latency p99.", "gauge", s.overall_p99_ms);
    metric(
        &mut o,
        "flame_compute_mean_ms",
        "Model compute latency mean.",
        "gauge",
        s.compute_mean_ms,
    );
    metric(&mut o, "flame_compute_p50_ms", "Model compute latency p50.", "gauge", s.compute_p50_ms);
    metric(&mut o, "flame_compute_p99_ms", "Model compute latency p99.", "gauge", s.compute_p99_ms);
    metric(
        &mut o,
        "flame_feature_mean_ms",
        "Feature stage latency mean.",
        "gauge",
        s.feature_mean_ms,
    );
    metric(&mut o, "flame_feature_p99_ms", "Feature stage latency p99.", "gauge", s.feature_p99_ms);
    metric(
        &mut o,
        "flame_queueing_mean_ms",
        "Intake queueing delay mean.",
        "gauge",
        s.queueing_mean_ms,
    );
    metric(
        &mut o,
        "flame_queueing_p99_ms",
        "Intake queueing delay p99.",
        "gauge",
        s.queueing_p99_ms,
    );
    metric(
        &mut o,
        "flame_handoff_mean_ms",
        "Pipeline handoff wait mean.",
        "gauge",
        s.handoff_mean_ms,
    );
    metric(&mut o, "flame_handoff_p99_ms", "Pipeline handoff wait p99.", "gauge", s.handoff_p99_ms);
    metric(&mut o, "flame_dropped_total", "Requests shed or failed.", "counter", s.dropped as f64);
    metric(
        &mut o,
        "flame_network_mb_per_s",
        "Feature-store network utilization.",
        "gauge",
        s.network_mb_per_s,
    );
    metric(
        &mut o,
        "flame_arena_growths_total",
        "Staging-arena growths.",
        "counter",
        s.arena_growths as f64,
    );
    metric(
        &mut o,
        "flame_result_cache_hits_total",
        "Result-cache hits.",
        "counter",
        s.result_hits as f64,
    );
    metric(
        &mut o,
        "flame_result_cache_misses_total",
        "Result-cache misses.",
        "counter",
        s.result_misses as f64,
    );
    metric(
        &mut o,
        "flame_result_cache_coalesced_total",
        "Requests that rode another request's in-flight computation.",
        "counter",
        s.result_coalesced as f64,
    );
    metric(
        &mut o,
        "flame_fetch_coalesced_total",
        "Feature ids that rode another request's in-flight fetch.",
        "counter",
        s.fetch_coalesced as f64,
    );
    metric(
        &mut o,
        "flame_fetch_batches_total",
        "Shared feature multigets.",
        "counter",
        s.fetch_batches as f64,
    );
    metric(
        &mut o,
        "flame_coalesce_batches_total",
        "DSO packed batches launched.",
        "counter",
        s.coalesce_batches as f64,
    );
    metric(
        &mut o,
        "flame_coalesced_rows_total",
        "Rows that shared a multi-request launch.",
        "counter",
        s.coalesced_rows as f64,
    );
    metric(
        &mut o,
        "flame_coalesce_occupancy_mean_pct",
        "Mean fill of packed batches at launch.",
        "gauge",
        s.coalesce_occupancy_mean_pct,
    );
    metric(
        &mut o,
        "flame_fke_flops_total",
        "Analytic FLOPs executed by FKE launches.",
        "counter",
        s.fke_flops as f64,
    );
    metric(
        &mut o,
        "flame_fke_tiles_skipped_total",
        "Attention tiles skipped as fully masked.",
        "counter",
        s.fke_tiles_skipped as f64,
    );
    let _ = writeln!(o, "# HELP flame_sla_miss_total SLA misses attributed to the dominant stage.");
    let _ = writeln!(o, "# TYPE flame_sla_miss_total counter");
    for (stage, v) in [
        ("queue", s.sla_miss_queue),
        ("feature", s.sla_miss_feature),
        ("handoff", s.sla_miss_handoff),
        ("compute", s.sla_miss_compute),
        ("other", s.sla_miss_other),
    ] {
        let _ = writeln!(o, "flame_sla_miss_total{{stage=\"{stage}\"}} {v}");
    }
    o
}

/// Append per-tenant series (`tenant="N"` labels) for every tenant that
/// has seen traffic. Emits nothing when no tenant view was ever
/// recorded, so single-tenant expositions are byte-identical to before
/// tenancy existed.
pub fn append_tenants(o: &mut String, tenants: &[crate::metrics::TenantCounts]) {
    if tenants.iter().all(|t| t.submitted() == 0) {
        return;
    }
    let active = || tenants.iter().enumerate().filter(|(_, t)| t.submitted() > 0);
    for (name, help, get) in [
        (
            "flame_tenant_requests_total",
            "Completed requests by tenant.",
            (|t| t.requests) as fn(&crate::metrics::TenantCounts) -> u64,
        ),
        ("flame_tenant_sla_miss_total", "SLA misses by tenant.", |t| t.sla_miss),
        ("flame_tenant_shed_total", "Requests shed at the front door by tenant.", |t| t.shed),
    ] {
        let _ = writeln!(o, "# HELP {name} {help}");
        let _ = writeln!(o, "# TYPE {name} counter");
        for (i, t) in active() {
            let _ = writeln!(o, "{name}{{tenant=\"{i}\"}} {}", get(t));
        }
    }
    let _ = writeln!(o, "# HELP flame_tenant_overall_p99_ms End-to-end latency p99 by tenant.");
    let _ = writeln!(o, "# TYPE flame_tenant_overall_p99_ms gauge");
    for (i, t) in active() {
        let _ = writeln!(
            o,
            "flame_tenant_overall_p99_ms{{tenant=\"{i}\"}} {}",
            t.overall_p99_us as f64 / 1_000.0
        );
    }
    let _ = writeln!(o, "# HELP flame_tenant_quality_total Served quality rungs by tenant.");
    let _ = writeln!(o, "# TYPE flame_tenant_quality_total counter");
    for (i, t) in active() {
        for (r, &n) in t.quality.iter().enumerate() {
            let label = crate::chaos::ServeQuality::from_index(r)
                .map_or("unknown", |q| q.as_str());
            let _ =
                writeln!(o, "flame_tenant_quality_total{{tenant=\"{i}\",quality=\"{label}\"}} {n}");
        }
    }
}

/// Append the cancelled-work ledger (`cause` x `stage` labels) for every
/// non-zero cell, plus the saved-compute counter. Emits nothing when no
/// request was ever cancelled, so expositions from runs without
/// cancellation are byte-identical to before the ledger existed.
pub fn append_cancelled(o: &mut String, r: &crate::metrics::Recorder) {
    use crate::cancel::{CancelCause, CancelStage};
    let matrix = r.cancelled_matrix();
    if matrix.iter().flatten().all(|&v| v == 0) {
        return;
    }
    let _ = writeln!(
        o,
        "# HELP flame_cancelled_total Requests dropped as doomed work, by cause and stage."
    );
    let _ = writeln!(o, "# TYPE flame_cancelled_total counter");
    for (c, row) in matrix.iter().enumerate() {
        let Some(cause) = CancelCause::from_index(c) else { continue };
        for (s, &v) in row.iter().enumerate() {
            let Some(stage) = CancelStage::from_index(s) else { continue };
            if v > 0 {
                let _ = writeln!(
                    o,
                    "flame_cancelled_total{{cause=\"{}\",stage=\"{}\"}} {v}",
                    cause.as_str(),
                    stage.as_str()
                );
            }
        }
    }
    metric(
        o,
        "flame_cancelled_saved_pairs_total",
        "User-item pairs of compute skipped thanks to early cancellation.",
        "counter",
        r.cancelled_saved_pairs() as f64,
    );
}

/// Render a live recorder: the aggregate exposition plus the per-tenant
/// series for every tenant that has seen traffic and the cancelled-work
/// ledger when any request was dropped as doomed.
pub fn render_recorder(r: &crate::metrics::Recorder) -> String {
    let mut o = render(&r.snapshot());
    append_tenants(&mut o, &r.tenant_counts());
    append_cancelled(&mut o, r);
    o
}

/// A live scrape endpoint: GET anything → the current exposition.
pub struct MetricsServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve `render_body()` to
    /// every connection.
    pub fn start<F>(addr: &str, render_body: F) -> Result<MetricsServer>
    where
        F: Fn() -> String + Send + 'static,
    {
        let listener =
            TcpListener::bind(addr).map_err(|e| Error::Io(format!("bind {addr}"), e))?;
        let local = listener.local_addr().map_err(|e| Error::Io("local_addr".into(), e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Io("set_nonblocking".into(), e))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("metrics-http".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // scrapes are rare; serve inline
                            let _ = serve_one(stream, &render_body);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .map_err(|e| Error::Internal(format!("spawn metrics-http: {e}")))?;
        Ok(MetricsServer { addr: local, stop, thread: Some(thread) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn serve_one<F: Fn() -> String>(
    mut stream: std::net::TcpStream,
    render_body: &F,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(250)))?;
    // drain the request head (best effort — we answer any request)
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 512];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 16 * 1024 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let body = render_body();
    let resp = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Recorder;
    use crate::obs::StageKind;

    #[test]
    fn exposition_contains_required_series() {
        let r = Recorder::new();
        r.record_request(22_000, 128);
        r.record_compute(5_000);
        r.record_sla_attribution(StageKind::Compute);
        let text = render(&r.snapshot_over(1.0));
        for name in [
            "flame_requests_total 1",
            "flame_pairs_total 128",
            "flame_overall_p99_ms",
            "flame_compute_p50_ms",
            "flame_throughput_pairs_per_s",
            "flame_result_cache_hits_total",
            "flame_coalesce_batches_total",
            "flame_sla_miss_total{stage=\"compute\"} 1",
            "flame_sla_miss_total{stage=\"queue\"} 0",
        ] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
        // every series carries HELP + TYPE
        assert_eq!(
            text.matches("# HELP").count(),
            text.matches("# TYPE").count()
        );
    }

    #[test]
    fn tenant_series_appear_only_when_tenants_saw_traffic() {
        use crate::workload::TenantId;
        let r = Recorder::new();
        r.record_request(1_000, 8);
        let quiet = render_recorder(&r);
        assert!(
            !quiet.contains("flame_tenant_"),
            "no tenant traffic → exposition unchanged:\n{quiet}"
        );
        r.record_tenant_request(TenantId(0), 2_000, false);
        r.record_tenant_request(TenantId(3), 9_000, true);
        r.record_tenant_shed(TenantId(3));
        r.record_tenant_quality(TenantId(3), crate::chaos::ServeQuality::Shed);
        let text = render_recorder(&r);
        for needle in [
            "flame_tenant_requests_total{tenant=\"0\"} 1",
            "flame_tenant_requests_total{tenant=\"3\"} 1",
            "flame_tenant_sla_miss_total{tenant=\"3\"} 1",
            "flame_tenant_shed_total{tenant=\"3\"} 1",
            "flame_tenant_overall_p99_ms{tenant=\"3\"}",
            "flame_tenant_quality_total{tenant=\"3\",quality=\"shed\"} 1",
        ] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
        assert!(
            !text.contains("tenant=\"1\""),
            "idle tenants must not emit series:\n{text}"
        );
    }

    #[test]
    fn cancelled_series_appear_only_after_a_drop() {
        use crate::cancel::{CancelCause, CancelStage};
        let r = Recorder::new();
        r.record_request(1_000, 8);
        let quiet = render_recorder(&r);
        assert!(
            !quiet.contains("flame_cancelled"),
            "no drops → exposition unchanged:\n{quiet}"
        );
        r.record_cancelled(CancelCause::Expired, CancelStage::Intake, 128);
        r.record_cancelled(CancelCause::Expired, CancelStage::Intake, 128);
        r.record_cancelled(CancelCause::ClientGone, CancelStage::Frontend, 0);
        let text = render_recorder(&r);
        for needle in [
            "flame_cancelled_total{cause=\"expired\",stage=\"intake\"} 2",
            "flame_cancelled_total{cause=\"client_gone\",stage=\"frontend\"} 1",
            "flame_cancelled_saved_pairs_total 256",
        ] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
        assert!(
            !text.contains("cause=\"hedge_loser\""),
            "zero cells must not emit series:\n{text}"
        );
    }

    #[test]
    fn http_endpoint_serves_exposition() {
        let server = MetricsServer::start("127.0.0.1:0", || {
            let r = Recorder::new();
            r.record_request(1_000, 8);
            render(&r.snapshot_over(1.0))
        })
        .unwrap();
        let mut stream = std::net::TcpStream::connect(server.addr).unwrap();
        stream
            .write_all(b"GET /metrics HTTP/1.1\r\nHost: localhost\r\n\r\n")
            .unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200 OK"), "{out}");
        assert!(out.contains("text/plain; version=0.0.4"), "{out}");
        assert!(out.contains("flame_requests_total 1"), "{out}");
        server.shutdown();
    }
}
