//! `flame` — leader binary: CLI over the serving stack.
//!
//! See `flame --help` (cli::help) for commands. The heavy lifting lives
//! in the library; this file is argument plumbing + reporting.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use flame::batching::RequestQueue;
use flame::benchkit::Table;
use flame::cli::{help, Args};
use flame::cluster::{
    ClusterConfig, ClusterRouter, ReplicaBackend, RoutePolicy, SimConfig, SimReplica,
    StackReplica,
};
use flame::config::{flops, CacheMode, DsoMode, Scenario, StackConfig, WorkloadConfig};
use flame::dso::{ComputeBackend, SimEngine};
use flame::fke::cpu::{CpuEngine, CpuEngineConfig, CpuModel};
use flame::fke::Variant;
use flame::manifest::Manifest;
use flame::metrics::Recorder;
use flame::obs::prom::MetricsServer;
use flame::obs::Tracer;
use flame::pda::numa::Topology;
use flame::runtime::Runtime;
use flame::server::pipeline::{ServingStack, StackBuilder};
use flame::workload::storm::StormSpec;
use flame::workload::{driver, trace, Generator, MDist};

fn main() -> Result<()> {
    let args = Args::from_env().context("parsing arguments")?;
    match args.subcommand.as_deref() {
        None | Some("help") => {
            print!("{}", help());
            Ok(())
        }
        Some("info") => cmd_info(&args),
        Some("serve") => cmd_serve(&args),
        Some("record") => cmd_record(&args),
        Some("trace-gen") => cmd_trace_gen(&args),
        Some("replay") => cmd_serve(&args), // replay is serve --trace
        Some("bind") => cmd_bind(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("trace-check") => cmd_trace_check(&args),
        Some("lint") => cmd_lint(&args),
        Some(other) => bail!("unknown command '{other}' — try `flame help`"),
    }
}

/// `flame lint` — run the self-hosted analyzer over this crate's own
/// sources and fail on any non-baselined finding.
fn cmd_lint(args: &Args) -> Result<()> {
    use std::path::{Path, PathBuf};

    let root: PathBuf = match args.get("src") {
        Some(dir) => PathBuf::from(dir),
        // auto-detect: repo root (rust/src), crate root (src), or the
        // build-time manifest dir as a last resort
        None if Path::new("rust/src").is_dir() => PathBuf::from("rust"),
        None if Path::new("src").is_dir() => PathBuf::from("."),
        None => PathBuf::from(env!("CARGO_MANIFEST_DIR")),
    };
    let sources = flame::lint::scan_root(&root)
        .with_context(|| format!("scanning {}", root.display()))?;
    if sources.is_empty() {
        bail!("no .rs sources under {} — pass --src DIR", root.display());
    }
    let analysis = flame::lint::check(&flame::lint::build_model(&sources));

    if args.has("graph") {
        println!("# inferred lock-acquisition graph (held -> acquired)");
        for e in &analysis.edges {
            println!("{}", e.render());
        }
        println!();
    }

    let baseline_path = match args.get("baseline") {
        Some(p) => PathBuf::from(p),
        None => root.join("lint_baseline.txt"),
    };
    if args.has("write-baseline") {
        std::fs::write(&baseline_path, flame::lint::format_baseline(&analysis.findings))
            .with_context(|| format!("writing {}", baseline_path.display()))?;
        println!(
            "wrote {} fingerprint(s) to {}",
            analysis.findings.len(),
            baseline_path.display()
        );
        return Ok(());
    }
    let accepted = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => flame::lint::parse_baseline(&text),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Default::default(),
        Err(e) => return Err(e).with_context(|| format!("reading {}", baseline_path.display())),
    };
    let (baselined, fresh) = flame::lint::apply_baseline(&analysis, &accepted);

    for f in &fresh {
        println!("{}", f.render());
    }
    println!(
        "flame lint: {} file(s), {} finding(s) ({} baselined, {} new)",
        sources.len(),
        analysis.findings.len(),
        baselined.len(),
        fresh.len()
    );
    if !fresh.is_empty() {
        bail!(
            "{} non-baselined finding(s) — fix them, tag them per the checker's \
             suggestion, or (rarely) `flame lint --write-baseline`",
            fresh.len()
        );
    }
    Ok(())
}

fn stack_config(args: &Args) -> Result<StackConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => StackConfig::from_file(std::path::Path::new(path))?,
        None => StackConfig::default(),
    };
    if let Some(mode) = args.get("cache") {
        cfg.pda.cache_mode = CacheMode::parse(mode)?;
    }
    if let Some(mode) = args.get("dso") {
        cfg.dso.mode = DsoMode::parse(mode)?;
    }
    if let Some(n) = args.get_parse::<usize>("workers")? {
        cfg.server.pipeline_workers = n;
    }
    if let Some(n) = args.get_parse::<usize>("executors")? {
        cfg.dso.executors_per_profile = n;
    }
    if args.has("coalesce") {
        cfg.dso.coalesce = true;
    }
    if let Some(t) = args.get_parse::<u64>("coalesce-wait-us")? {
        cfg.dso.coalesce_wait_us = t;
    }
    if args.has("pipeline") {
        cfg.server.pipeline = true;
    }
    if let Some(n) = args.get_parse::<usize>("feature-workers")? {
        cfg.server.feature_workers = n;
    }
    if let Some(n) = args.get_parse::<usize>("handoff-capacity")? {
        cfg.server.handoff_capacity = n;
    }
    if args.has("deadline-first") {
        cfg.server.deadline_first = true;
    }
    if let Some(d) = args.get_parse::<u64>("deadline-ms")? {
        cfg.server.deadline_ms = d;
    }
    if args.has("cancel") {
        cfg.server.cancel = true;
    }
    if let Some(n) = args.get_parse::<u64>("trace-sample-n")? {
        cfg.server.trace_sample_n = n;
    }
    if args.has("fetch-coalesce") {
        cfg.pda.fetch_coalesce = true;
    }
    if let Some(t) = args.get_parse::<u64>("fetch-wait-us")? {
        cfg.pda.fetch_wait_us = t;
    }
    if args.has("no-numa") {
        cfg.pda.numa_binding = false;
    }
    if args.has("no-staging") {
        cfg.pda.staging_arenas = false;
    }
    if let Some(r) = args.get_parse::<f64>("rate")? {
        cfg.workload.arrival_rate = Some(r);
    }
    if let Some(s) = args.get_parse::<u64>("seed")? {
        cfg.workload.seed = s;
    }
    if let Some(t) = args.get_parse::<f64>("theta")? {
        cfg.workload.zipf_theta = t;
    }
    if let Some(c) = args.get_parse::<u64>("catalog")? {
        cfg.workload.catalog_size = c;
    }
    if args.get("chaos").is_some() {
        // a chaos run turns the serve-side degradation ladder on:
        // over-budget requests truncate candidates instead of missing
        cfg.server.truncate_over_budget = true;
    }
    Ok(cfg)
}

/// Parse `--chaos SPEC` (+ `--chaos-seed`) into a shared fault plan.
fn chaos_plan(args: &Args) -> Result<Option<Arc<flame::chaos::FaultPlan>>> {
    match args.get("chaos") {
        Some(spec) => {
            let seed = args.get_parse::<u64>("chaos-seed")?.unwrap_or(0);
            let plan = flame::chaos::FaultPlan::parse(spec, seed)?;
            eprintln!("[flame] chaos armed: {spec} (seed {seed})");
            Ok(Some(Arc::new(plan)))
        }
        None => Ok(None),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("FLAME reproduction — system info\n");
    println!("paper operating envelope (Table 1): GR models 1e9..1e11 FLOPs/request, < 50 ms, 1e10..1e12 requests/day\n");
    for s in Scenario::all() {
        let c = s.config();
        println!("  {}", flops::envelope_summary(&c));
    }
    let topo = Topology::detect();
    println!("\nNUMA topology: {} node(s), {} CPU(s)", topo.n_nodes(), topo.n_cpus());
    for n in &topo.nodes {
        println!("  node{}: cpus {:?}", n.id, n.cpus);
    }
    let dir = args.get_or("artifacts", "artifacts");
    match Manifest::load(dir) {
        Ok(m) => {
            println!("\nartifacts ({dir}):");
            for (name, sa) in &m.scenarios {
                println!(
                    "  scenario {name}: L={} D={} blocks={} layers={} profiles {:?} ({:.1} MB weights)",
                    sa.config.seq_len,
                    sa.config.d_model,
                    sa.config.n_blocks,
                    sa.config.layers_per_block,
                    sa.config.m_profiles,
                    sa.weights_bytes as f64 / 1e6
                );
            }
            for e in &m.models {
                println!(
                    "  engine {}/{}/m{} -> {} ({:.2e} FLOPs)",
                    e.scenario, e.variant, e.m, e.path, e.flops as f64
                );
            }
        }
        Err(e) => println!("\nartifacts ({dir}): not available ({e}) — run `make artifacts`"),
    }
    Ok(())
}

/// Assemble one stack over artifact-free native backends (`--backend
/// cpu|sim`). The cpu path builds (or shares, for replicas) a seeded
/// [`CpuModel`] and wires each engine's FLOP/tile counters into the
/// stack's recorder.
fn build_native_stack(
    args: &Args,
    cfg: &StackConfig,
    scenario: &str,
    variant: &str,
    backend: &str,
    cpu_model: Option<&Arc<CpuModel>>,
) -> Result<Arc<ServingStack>> {
    let model_cfg = Scenario::parse(scenario)?.config();
    let recorder = Arc::new(Recorder::new());
    let backends: Vec<Arc<dyn ComputeBackend>> = match backend {
        "sim" => model_cfg
            .m_profiles
            .iter()
            .map(|&m| {
                Arc::new(
                    SimEngine::new(m, model_cfg.seq_len, model_cfg.d_model, model_cfg.n_tasks)
                        .with_delay(Duration::from_micros(300)),
                ) as Arc<dyn ComputeBackend>
            })
            .collect(),
        "cpu" => {
            let ecfg = CpuEngineConfig {
                variant: Variant::parse(variant)?,
                threads: args.get_parse::<usize>("threads")?.unwrap_or(0),
            };
            let owned;
            let model = match cpu_model {
                Some(m) => m,
                None => {
                    owned = CpuModel::new(&model_cfg, CpuModel::seed_for(scenario))?;
                    &owned
                }
            };
            CpuEngine::profile_set(model, &ecfg, Some(Arc::clone(&recorder)))
        }
        other => bail!("unknown backend '{other}' — expected cpu | sim"),
    };
    let stack = StackBuilder::new(scenario, variant, cfg.clone())
        .with_metrics(recorder)
        .build_from_backends(model_cfg, cfg.workload.seed, backends)
        .context("building native-backend stack")?;
    Ok(Arc::new(stack))
}

fn build_stack(args: &Args) -> Result<(Arc<flame::server::ServingStack>, StackConfig)> {
    let dir = args.get_or("artifacts", "artifacts");
    let scenario = args.get_or("scenario", "bench");
    let variant = args.get_or("variant", "fused");
    let cfg = stack_config(args)?;
    if let Some(backend) = args.get("backend") {
        eprintln!("[flame] building native {backend} stack: {scenario}/{variant} ...");
        let stack = build_native_stack(args, &cfg, scenario, variant, backend, None)?;
        eprintln!(
            "[flame] ready: profiles {:?}, backend {backend} (no artifacts)",
            stack.orchestrator.profiles()
        );
        return Ok((stack, cfg));
    }
    let manifest = Manifest::load(dir).context("loading manifest — run `make artifacts`")?;
    let runtime = Runtime::new().context("creating PJRT client")?;
    eprintln!("[flame] compiling {scenario}/{variant} engines ...");
    let stack = StackBuilder::new(scenario, variant, cfg.clone())
        .build(&runtime, &manifest)
        .context("building serving stack")?;
    eprintln!(
        "[flame] ready: profiles {:?}, platform {}",
        stack.orchestrator.profiles(),
        runtime.platform()
    );
    Ok((Arc::new(stack), cfg))
}

/// Tracer from the observability flags: `--trace-out` implies sampling
/// every request unless `trace_sample_n` (flag or config) narrows it.
fn trace_tracer(args: &Args, cfg_sample_n: u64) -> Option<Arc<Tracer>> {
    let n = if cfg_sample_n == 0 && args.get("trace-out").is_some() { 1 } else { cfg_sample_n };
    (n > 0).then(|| Arc::new(Tracer::new(n)))
}

/// Shut down the metrics endpoint, print a trace summary, and write the
/// Chrome trace-event JSON for `--trace-out`.
fn finish_observability(
    args: &Args,
    tracer: Option<Arc<Tracer>>,
    metrics_srv: Option<MetricsServer>,
) -> Result<()> {
    if let Some(srv) = metrics_srv {
        if let Some(hold) = args.get_parse::<f64>("metrics-hold-s")? {
            eprintln!("[flame] holding metrics endpoint on {} for {hold:.0}s ...", srv.addr);
            std::thread::sleep(Duration::from_secs_f64(hold.max(0.0)));
        }
        srv.shutdown();
    }
    let Some(tracer) = tracer else { return Ok(()) };
    let dump = tracer.dump();
    println!(
        "traces         : {} sampled, {} sla-miss exemplars, {} slowest retained, {} shared spans, {} flow links",
        dump.traces.len(),
        dump.sla.len(),
        dump.slowest.len(),
        dump.shared.len(),
        dump.flows.len()
    );
    if let Some(path) = args.get("trace-out") {
        let json = flame::obs::export::chrome_trace_json(&dump);
        std::fs::write(path, &json).with_context(|| format!("writing trace to {path}"))?;
        println!("trace written  : {path} (open in ui.perfetto.dev or chrome://tracing)");
    }
    Ok(())
}

fn cmd_trace_check(args: &Args) -> Result<()> {
    let path = args
        .get("trace-out")
        .or_else(|| args.positional.first().map(|s| s.as_str()))
        .context("trace-check needs a file: flame trace-check trace.json")?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let check = flame::obs::export::validate_chrome_trace(&text)?;
    println!(
        "{path}: ok — {} events ({} spans, {} flow starts / {} flow ends, {} metadata)",
        check.events, check.spans, check.flow_starts, check.flow_ends, check.metadata
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (stack, cfg) = build_stack(args)?;
    let tracer = trace_tracer(args, cfg.server.trace_sample_n);
    if let Some(t) = &tracer {
        stack.metrics.set_tracer(Arc::clone(t), 0);
    }
    let chaos = chaos_plan(args)?;
    if let Some(plan) = &chaos {
        stack.arm_chaos(Arc::clone(plan));
    }
    let metrics_srv = match args.get("metrics-addr") {
        Some(addr) => {
            let s = Arc::clone(&stack);
            let srv = MetricsServer::start(addr, move || {
                flame::obs::prom::render_recorder(&s.metrics)
            })?;
            eprintln!("[flame] metrics endpoint: http://{}/", srv.addr);
            Some(srv)
        }
        None => None,
    };
    let n_requests = args.get_parse::<usize>("requests")?.unwrap_or(64);
    let duration = Duration::from_secs_f64(args.get_parse::<f64>("duration-s")?.unwrap_or(10.0));

    // request stream: trace file or generator
    let requests = match args.get("trace") {
        Some(path) => trace::replay(std::path::Path::new(path))?,
        None => {
            let mut wl = cfg.workload.clone();
            if let Some(dist) = args.get("m-dist") {
                // skewed-upstream scenario: M drawn over the profile
                // support (including off-profile values)
                wl.candidate_mix = MDist::parse(dist)?.mix(stack.orchestrator.profiles());
            } else if wl.candidate_mix.len() == 1 && wl.candidate_mix[0].0 == 32 {
                // default mix: uniform over this scenario's profiles
                wl.candidate_mix =
                    WorkloadConfig::uniform_mix(stack.orchestrator.profiles());
            }
            let mut g = Generator::new(&wl, stack.model_cfg.seq_len);
            g.batch(n_requests)
        }
    };
    eprintln!("[flame] driving {} requests ...", requests.len());

    let report = if cfg.server.pipeline {
        // decoupled two-stage mode: feature workers overlap compute
        // submitters; the intake queue is the admission front door
        let handle = stack.spawn_pipeline();
        let report = match cfg.workload.arrival_rate {
            Some(rate) => driver::open_loop_pipeline(
                &handle,
                requests,
                rate,
                duration,
                cfg.workload.seed,
            ),
            None => handle.drive_closed_loop(
                &requests,
                cfg.server.feature_workers + cfg.server.pipeline_workers,
                duration,
            ),
        };
        handle.shutdown(); // drains both stages
        report
    } else {
        match cfg.workload.arrival_rate {
            Some(rate) => {
                // open loop: admission queue + pipeline workers, Poisson arrivals
                let queue = RequestQueue::new(cfg.dso.queue_capacity);
                let workers = stack.spawn_workers(Arc::clone(&queue), cfg.server.pipeline_workers);
                let report = driver::open_loop(
                    requests,
                    rate,
                    duration,
                    cfg.dso.queue_capacity,
                    cfg.workload.seed,
                    |r| queue.push(r.clone()).is_ok(),
                );
                while !queue.is_empty() {
                    std::thread::sleep(Duration::from_millis(5));
                }
                queue.close();
                for w in workers {
                    let _ = w.join();
                }
                report
            }
            // closed loop: one request in flight per worker, no queueing noise
            None => stack.drive_closed_loop(&requests, cfg.server.pipeline_workers, duration),
        }
    };

    let snap = stack.metrics.snapshot();
    println!("\n=== serve report ===");
    println!("submitted {} / completed {} / rejected {}", report.submitted, report.completed, report.rejected);
    println!("throughput     : {:.1} k user-item pairs/s", snap.throughput_pairs_per_s / 1e3);
    println!("overall latency: mean {:.2} ms  p50 {:.2} ms  p99 {:.2} ms", snap.overall_mean_ms, snap.overall_p50_ms, snap.overall_p99_ms);
    println!("compute latency: mean {:.2} ms  p99 {:.2} ms", snap.compute_mean_ms, snap.compute_p99_ms);
    println!("feature stage  : mean {:.2} ms", snap.feature_mean_ms);
    if cfg.server.pipeline {
        println!(
            "stage handoff  : mean {:.2} ms  p99 {:.2} ms ({} feature + {} compute workers, arena growths {})",
            snap.handoff_mean_ms,
            snap.handoff_p99_ms,
            cfg.server.feature_workers,
            cfg.server.pipeline_workers,
            snap.arena_growths
        );
    }
    if stack.query.fetch_coalesce_enabled() {
        let fs = stack.query.fetch_coalesce_stats();
        println!(
            "fetch coalesce : {} shared multigets ({} ids), {} rider ids, {} merged flushes",
            fs.batches, fs.batched_ids, fs.riders, fs.merged_flushes
        );
    }
    println!("network        : {:.1} MB/s", stack.network_mb_per_s());
    println!("cache hit rate : {:.1} %", stack.query.cache().stats.hit_rate() * 100.0);
    println!("dso waste      : {:.1} % padded rows", stack.orchestrator.waste_fraction() * 100.0);
    let ks = stack.orchestrator.kernel_stats();
    if ks.launches > 0 {
        println!(
            "fke kernels    : {} launches, {:.2} GFLOP executed ({:.2} GFLOP/s), tiles visited {} / skipped {} ({:.0} % skipped)",
            ks.launches,
            ks.flops as f64 / 1e9,
            ks.flops as f64 / 1e9 / snap.elapsed_s.max(1e-9),
            ks.tiles_visited,
            ks.tiles_skipped,
            ks.tile_skip_fraction() * 100.0
        );
    }
    if stack.orchestrator.coalesce_enabled() {
        let cs = stack.orchestrator.coalesce_stats();
        println!(
            "dso coalesce   : {} packed batches ({} multi-request), {} coalesced rows, occupancy mean {:.0} % / p50 {} %",
            cs.batches,
            cs.multi_request_batches,
            cs.coalesced_rows,
            cs.occupancy_mean_pct,
            cs.occupancy_p50_pct
        );
    }
    print_cancelled(&stack.metrics);
    if tracer.is_some() {
        let (q, f, h, c, o) = stack.metrics.sla_miss_attribution();
        if q + f + h + c + o > 0 {
            println!("sla attribution: queue {q} feature {f} handoff {h} compute {c} other {o}");
        }
    }
    if let Some(plan) = &chaos {
        let q = snap.quality;
        println!(
            "serve quality  : full {}  stale {}  truncated {}  cached {}  shed {}  (worker restarts {})",
            q[0], q[1], q[2], q[3], q[4], snap.worker_restarts
        );
        let inj = plan.injected();
        println!(
            "chaos injected : store delay/err/timeout {}/{}/{}  brownouts {}  crashes {}  stalls {}  panics {}",
            inj.store_delays,
            inj.store_errors,
            inj.store_timeouts,
            inj.brownout_hits,
            inj.crash_faults,
            inj.compute_stalls,
            inj.worker_panics
        );
    }
    finish_observability(args, tracer, metrics_srv)?;
    Ok(())
}

fn cmd_record(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .map(|s| s.to_string())
        .or_else(|| args.positional.first().cloned())
        .context("record needs --trace FILE")?;
    let scenario = Scenario::parse(args.get_or("scenario", "bench"))?;
    let cfg = stack_config(args)?;
    let mut wl = cfg.workload;
    wl.candidate_mix = match args.get("m-dist") {
        Some(dist) => MDist::parse(dist)?.mix(&scenario.config().m_profiles),
        None => WorkloadConfig::uniform_mix(&scenario.config().m_profiles),
    };
    let n = args.get_parse::<usize>("requests")?.unwrap_or(256);
    let mut g = Generator::new(&wl, scenario.config().seq_len);
    let reqs = g.batch(n);
    trace::record(std::path::Path::new(&path), &reqs)?;
    println!("wrote {n} requests to {path}");
    Ok(())
}

/// `flame trace-gen` — expand a storm scenario into a timed v2 trace.
/// The expansion is deterministic in `(--storm, --seed, workload
/// config)`, so every arm of an experiment — controller on, controller
/// off, different policies — replays the byte-identical storm.
fn cmd_trace_gen(args: &Args) -> Result<()> {
    let path = args
        .get("trace")
        .map(|s| s.to_string())
        .or_else(|| args.positional.first().cloned())
        .context("trace-gen needs --trace FILE")?;
    let scenario_name = args.get_or("scenario", "bench");
    let scenario = Scenario::parse(scenario_name)?;
    let cfg = stack_config(args)?;
    let mut wl = cfg.workload;
    wl.candidate_mix = match args.get("m-dist") {
        Some(dist) => MDist::parse(dist)?.mix(&scenario.config().m_profiles),
        None => WorkloadConfig::uniform_mix(&scenario.config().m_profiles),
    };
    let spec_text = args.get("storm").unwrap_or("");
    let spec =
        if spec_text.is_empty() { StormSpec::quiet() } else { StormSpec::parse(spec_text)? };
    let rate = args.get_parse::<f64>("rate")?.unwrap_or(1_000.0);
    let duration_s = args.get_parse::<f64>("duration-s")?.unwrap_or(5.0);
    let mut g = Generator::new(&wl, scenario.config().seq_len);
    let events = spec.generate(&mut g, rate, duration_s, wl.seed);
    let header = trace::TraceHeader {
        scenario: Some(scenario_name.to_string()),
        storm: (!spec_text.is_empty()).then(|| spec_text.to_string()),
        base_rate: Some(rate),
        ..trace::TraceHeader::v2()
    };
    trace::record_events(std::path::Path::new(&path), &header, &events)?;
    let mut per_tenant = [0u64; flame::workload::MAX_TENANTS];
    let mut invalidations = 0u64;
    for e in &events {
        match e {
            trace::TraceEvent::Arrival { req, .. } => per_tenant[req.tenant.index()] += 1,
            trace::TraceEvent::InvalidateUser { .. } => invalidations += 1,
        }
    }
    println!(
        "wrote {} events to {path}: {} arrivals, {invalidations} invalidations over {duration_s:.1}s @ {rate:.0}/s base",
        events.len(),
        per_tenant.iter().sum::<u64>()
    );
    for (i, &n) in per_tenant.iter().enumerate() {
        if n > 0 {
            println!("  tenant {i}: {n} arrivals");
        }
    }
    Ok(())
}

/// One-line cancelled-work ledger, rendered only when something was
/// actually dropped (quiet runs stay byte-identical).
fn print_cancelled(metrics: &Recorder) {
    use flame::cancel::CancelCause;
    let total = metrics.cancelled_total();
    if total == 0 {
        return;
    }
    println!(
        "cancelled      : {} dropped (expired {}  client-gone {}  hedge-loser {}  \
         shutdown {}), ~{} pairs of compute saved",
        total,
        metrics.cancelled_by_cause(CancelCause::Expired),
        metrics.cancelled_by_cause(CancelCause::ClientGone),
        metrics.cancelled_by_cause(CancelCause::HedgeLoser),
        metrics.cancelled_by_cause(CancelCause::Shutdown),
        metrics.cancelled_saved_pairs()
    );
}

fn cmd_bind(args: &Args) -> Result<()> {
    let n = args.get_parse::<usize>("replicas")?.unwrap_or(1);
    let addr = args.get_or("bind", "127.0.0.1:7178");
    let report_metrics: Arc<Recorder>;
    let server = if n > 1 {
        let stacks = build_stacks(args, n)?;
        let backends: Vec<Arc<dyn ReplicaBackend>> = stacks
            .into_iter()
            .map(|s| Arc::new(StackReplica::new(s)) as Arc<dyn ReplicaBackend>)
            .collect();
        let router = Arc::new(ClusterRouter::new(backends, cluster_config(args)?)?);
        println!("[flame] cluster front: {n} replicas, policy {}", router.policy().name());
        report_metrics = Arc::clone(&router.metrics);
        flame::server::tcp::TcpServer::start_cluster(router, addr)?
    } else {
        let (stack, cfg) = build_stack(args)?;
        report_metrics = Arc::clone(&stack.metrics);
        if cfg.server.pipeline {
            // staged front: submit + channel replies, so each connection
            // thread watches its socket and fires ClientGone on hangup
            let handle = Arc::new(stack.spawn_pipeline());
            println!(
                "[flame] pipeline front: {} feature + {} compute workers, cancel {}",
                cfg.server.feature_workers,
                cfg.server.pipeline_workers,
                if cfg.server.cancel { "on" } else { "off" }
            );
            flame::server::tcp::TcpServer::start_pipeline(handle, addr)?
        } else {
            flame::server::tcp::TcpServer::start(Arc::clone(&stack), addr)?
        }
    };
    println!("[flame] listening on {}", server.addr);
    // `--duration-s` serves for a bounded window, then drains gracefully:
    // the listener closes, in-flight requests finish and flush, and the
    // cancelled-work ledger (if any) is reported before exit.
    if let Some(secs) = args.get_parse::<f64>("duration-s")? {
        println!("[flame] serving for {secs:.0}s, then draining");
        std::thread::sleep(Duration::from_secs_f64(secs.max(0.0)));
        server.drain();
        println!("[flame] drained: listener closed, in-flight requests completed");
        print_cancelled(&report_metrics);
        return Ok(());
    }
    println!("[flame] press ctrl-c to stop");
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

/// Cluster knobs from flags (defaults: affinity policy, 50 ms deadline,
/// result cache on at 32k entries / 2 s TTL — `--result-cache-cap 0`
/// turns the tier off, `--no-coalesce` disables single-flight).
fn cluster_config(args: &Args) -> Result<ClusterConfig> {
    let mut c = ClusterConfig {
        result_cache: flame::cluster::ResultCacheConfig {
            capacity: args.get_parse::<usize>("result-cache-cap")?.unwrap_or(32_768),
            ..Default::default()
        },
        ..ClusterConfig::default()
    };
    if let Some(p) = args.get("policy") {
        c.policy = RoutePolicy::parse(p)?;
    }
    if let Some(d) = args.get_parse::<u64>("deadline-ms")? {
        c.deadline_ms = d;
    }
    if let Some(s) = args.get_parse::<usize>("slots")? {
        c.slots_per_replica = s;
    }
    if let Some(t) = args.get_parse::<u64>("result-ttl-ms")? {
        c.result_cache.ttl_ms = t;
    }
    if args.has("no-coalesce") {
        c.result_cache.coalesce = false;
    }
    if let Some(spec) = args.get("tenants") {
        c.tenants = flame::cluster::TenantSet::parse(spec)?;
    }
    if args.has("controller") {
        c.controller = true;
    }
    if args.get("chaos").is_some() {
        // a chaos run turns the router's degradation ladder on: hedged
        // re-dispatch against brownouts, deeper budget-aware retries
        c.hedge = true;
        c.max_retries = c.max_retries.max(2);
        c.retry_backoff_us = 50;
    }
    Ok(c)
}

/// Build `n` independent real serving stacks (shared runtime + manifest,
/// independent PDA caches and executor pools — one "replica" each).
/// With `--backend cpu|sim` the replicas are artifact-free: cpu replicas
/// share one weight set (`CpuModel`) but keep independent engines,
/// recorders, and PDA caches.
fn build_stacks(args: &Args, n: usize) -> Result<Vec<Arc<ServingStack>>> {
    let dir = args.get_or("artifacts", "artifacts");
    let scenario = args.get_or("scenario", "bench");
    let variant = args.get_or("variant", "fused");
    let cfg = stack_config(args)?;
    if let Some(backend) = args.get("backend") {
        let cpu_model = if backend == "cpu" {
            let model_cfg = Scenario::parse(scenario)?.config();
            Some(CpuModel::new(&model_cfg, CpuModel::seed_for(scenario))?)
        } else {
            None
        };
        return (0..n)
            .map(|i| {
                eprintln!(
                    "[flame] building replica {i}: native {backend} {scenario}/{variant} ..."
                );
                build_native_stack(args, &cfg, scenario, variant, backend, cpu_model.as_ref())
            })
            .collect();
    }
    let manifest = Manifest::load(dir).context("loading manifest — run `make artifacts`")?;
    let runtime = Runtime::new().context("creating PJRT client")?;
    let mut stacks = Vec::with_capacity(n);
    for i in 0..n {
        eprintln!("[flame] building replica {i}: {scenario}/{variant} engines ...");
        let stack = StackBuilder::new(scenario, variant, cfg.clone())
            .build(&runtime, &manifest)
            .with_context(|| format!("building replica {i}"))?;
        stacks.push(Arc::new(stack));
    }
    Ok(stacks)
}

fn cmd_cluster(args: &Args) -> Result<()> {
    let n = args.get_parse::<usize>("replicas")?.unwrap_or(3).max(1);
    let ccfg = cluster_config(args)?;
    let scfg = stack_config(args)?;
    let chaos = chaos_plan(args)?;
    let tracer = trace_tracer(args, scfg.server.trace_sample_n);
    let n_requests = args.get_parse::<usize>("requests")?.unwrap_or(2_000);
    let duration = Duration::from_secs_f64(args.get_parse::<f64>("duration-s")?.unwrap_or(10.0));
    let concurrency = args.get_parse::<usize>("concurrency")?.unwrap_or(4 * n);

    // paper-style non-uniform candidate mix (most requests small-M, a
    // heavy tail of large-M); real stacks use their profile set instead
    let mut mix: Vec<(usize, f64)> = vec![(128, 0.55), (256, 0.25), (512, 0.15), (1024, 0.05)];
    let mut seq_len = 32usize;
    // `--real` (artifacts) and `--backend cpu|sim` (artifact-free) both
    // drive real ServingStack replicas instead of the queueing sim
    let real_stacks = args.has("real") || args.get("backend").is_some();
    let backends: Vec<Arc<dyn ReplicaBackend>> = if real_stacks {
        let stacks = build_stacks(args, n)?;
        seq_len = stacks[0].model_cfg.seq_len;
        mix = WorkloadConfig::uniform_mix(stacks[0].orchestrator.profiles());
        if let Some(plan) = &chaos {
            // store/stall/panic clauses apply inside each real stack
            for s in &stacks {
                s.arm_chaos(Arc::clone(plan));
            }
        }
        if let Some(t) = &tracer {
            // pid 0 is the router; replicas render as processes 1..=n
            for (i, s) in stacks.iter().enumerate() {
                s.metrics.set_tracer(Arc::clone(t), (i + 1) as u32);
            }
        }
        stacks
            .into_iter()
            .map(|s| Arc::new(StackReplica::new(s)) as Arc<dyn ReplicaBackend>)
            .collect()
    } else {
        let sim = SimConfig { slots: ccfg.slots_per_replica, ..SimConfig::default() };
        (0..n)
            .map(|i| {
                let r = Arc::new(SimReplica::new(sim.clone()));
                if let Some(plan) = &chaos {
                    // brownout/crash clauses key on the replica index
                    r.arm_chaos(i, Arc::clone(plan));
                }
                r as Arc<dyn ReplicaBackend>
            })
            .collect()
    };

    let mut wl = scfg.workload;
    wl.candidate_mix = mix;
    wl.n_users = args.get_parse::<u64>("users")?.unwrap_or(2_000);
    let mut g = Generator::new(&wl, seq_len);
    let dup_rate = args.get_parse::<f64>("dup-rate")?.unwrap_or(0.0);

    // storm / trace replay: a timed event timeline (arrivals + feature
    // invalidations) instead of a request batch — with `--storm` the
    // timeline is expanded here, with `--trace` a recorded one replays
    let events = match (args.get("storm"), args.get("trace")) {
        (Some(spec), _) => {
            let storm = StormSpec::parse(spec)?;
            let rate = args.get_parse::<f64>("rate")?.unwrap_or(2_000.0);
            Some(storm.generate(&mut g, rate, duration.as_secs_f64(), wl.seed))
        }
        (None, Some(path)) => Some(trace::replay_events(std::path::Path::new(path))?.1),
        (None, None) => None,
    };

    let router = Arc::new(ClusterRouter::new(backends, ccfg)?);
    if let Some(t) = &tracer {
        router.metrics.set_tracer(Arc::clone(t), 0);
    }
    let metrics_srv = match args.get("metrics-addr") {
        Some(addr) => {
            let r = Arc::clone(&router);
            let srv = MetricsServer::start(addr, move || {
                flame::obs::prom::render_recorder(&r.metrics)
            })?;
            eprintln!("[flame] metrics endpoint: http://{}/", srv.addr);
            Some(srv)
        }
        None => None,
    };
    let drive_desc = match &events {
        Some(ev) => format!("{} storm events", ev.len()),
        None => format!("{n_requests} requests"),
    };
    eprintln!(
        "[flame] cluster: {n} replicas, policy {}, deadline {} ms, dup rate {:.0}% — driving {drive_desc} ...",
        router.policy().name(),
        router.deadline_us() / 1_000,
        dup_rate * 100.0,
    );

    let t0 = std::time::Instant::now();
    let report = match events {
        Some(events) => driver::open_loop_events(
            &events,
            1.0,
            4_096,
            |r| router.submit(r).is_ok(),
            |u| {
                router.invalidate_user(u);
            },
        ),
        None => {
            let requests = g.batch(n_requests);
            match args.get_parse::<f64>("rate")? {
                Some(rate) => driver::open_loop_cluster(
                    &router, requests, rate, duration, 4_096, wl.seed, dup_rate,
                ),
                None => {
                    let mut requests = requests;
                    driver::inject_duplicates(&mut requests, dup_rate, wl.seed);
                    driver::closed_loop(requests, concurrency, duration, |r| {
                        router.submit(r).is_ok()
                    })
                }
            }
        }
    };
    print_cluster_report(&router, &report, t0.elapsed().as_secs_f64());
    if let Some(plan) = &chaos {
        let inj = plan.injected();
        println!(
            "chaos injected : store delay/err/timeout {}/{}/{}  brownouts {}  crashes {}  stalls {}  panics {}",
            inj.store_delays,
            inj.store_errors,
            inj.store_timeouts,
            inj.brownout_hits,
            inj.crash_faults,
            inj.compute_stalls,
            inj.worker_panics
        );
    }
    if tracer.is_some() {
        let (q, f, h, c, o) = router.metrics.sla_miss_attribution();
        if q + f + h + c + o > 0 {
            println!("sla attribution: queue {q} feature {f} handoff {h} compute {c} other {o}");
        }
    }
    finish_observability(args, tracer, metrics_srv)?;
    Ok(())
}

fn print_cluster_report(
    router: &ClusterRouter,
    report: &driver::DriveReport,
    elapsed_s: f64,
) {
    let snap = router.snapshot();
    let agg = router.metrics.snapshot_over(elapsed_s);
    println!("\n=== cluster report ({}) ===", snap.policy);
    println!(
        "submitted {} / completed {} / rejected {}",
        report.submitted, report.completed, report.rejected
    );
    println!("throughput     : {:.1} k user-item pairs/s", agg.throughput_pairs_per_s / 1e3);
    println!(
        "overall latency: mean {:.2} ms  p50 {:.2} ms  p99 {:.2} ms",
        agg.overall_mean_ms, agg.overall_p50_ms, agg.overall_p99_ms
    );
    println!(
        "admission      : shed {}  sla misses {}  rerouted {}",
        snap.shed, snap.sla_misses, snap.rerouted
    );
    if snap.retries + snap.hedges + snap.probes_ok + snap.probes_failed > 0 {
        println!(
            "degradation    : retries {}  hedges {} (won {})  canary probes {} ok / {} failed",
            snap.retries, snap.hedges, snap.hedge_wins, snap.probes_ok, snap.probes_failed
        );
    }
    print_cancelled(&router.metrics);
    let q = agg.quality;
    if q.iter().skip(1).any(|&c| c > 0) {
        println!(
            "serve quality  : full {}  stale {}  truncated {}  cached {}  shed {}",
            q[0], q[1], q[2], q[3], q[4]
        );
    }
    let result_lookups = snap.result_hits + snap.result_misses + snap.result_coalesced;
    if result_lookups > 0 {
        println!(
            "result cache   : hits {}  misses {}  coalesced {}  ({:.1} % served without a replica)",
            snap.result_hits,
            snap.result_misses,
            snap.result_coalesced,
            (snap.result_hits + snap.result_coalesced) as f64 / result_lookups as f64 * 100.0
        );
    }
    println!("aggregate cache hit rate: {:.1} %", snap.aggregate_cache_hit_rate * 100.0);
    let mut t = Table::new(
        "per-replica",
        &["replica", "requests", "mean ms", "p99 ms", "hit rate %", "errors", "ejections", "healthy"],
    );
    for r in &snap.replicas {
        t.row(&[
            r.id.to_string(),
            r.requests.to_string(),
            format!("{:.2}", r.mean_ms),
            format!("{:.2}", r.p99_ms),
            format!("{:.1}", r.cache_hit_rate * 100.0),
            r.errors.to_string(),
            r.ejections.to_string(),
            r.healthy.to_string(),
        ]);
    }
    t.print();
    // per-tenant view: only rendered for multi-tenant traffic or when
    // the overload controller is armed (single-tenant output unchanged)
    let tenants = router.metrics.tenant_counts();
    let multi_tenant = tenants.iter().enumerate().any(|(i, t)| i > 0 && t.submitted() > 0);
    if multi_tenant || router.controller().is_some() {
        let mut tt = Table::new(
            "per-tenant",
            &[
                "tenant", "requests", "shed", "shed %", "miss %", "p50 ms", "p99 ms", "full",
                "degraded",
            ],
        );
        for (i, tc) in tenants.iter().enumerate() {
            if tc.submitted() == 0 {
                continue;
            }
            let degraded: u64 = tc.quality.iter().skip(1).sum();
            tt.row(&[
                i.to_string(),
                tc.requests.to_string(),
                tc.shed.to_string(),
                format!("{:.1}", tc.shed_rate() * 100.0),
                format!("{:.1}", tc.miss_rate() * 100.0),
                format!("{:.2}", tc.overall_p50_us as f64 / 1_000.0),
                format!("{:.2}", tc.overall_p99_us as f64 / 1_000.0),
                tc.quality[0].to_string(),
                degraded.to_string(),
            ]);
        }
        tt.print();
    }
    if let Some(ctrl) = router.controller() {
        let state: Vec<String> = tenants
            .iter()
            .enumerate()
            .filter(|(_, tc)| tc.submitted() > 0)
            .map(|(i, _)| {
                let tid = flame::workload::TenantId(i as u8);
                format!(
                    "t{i} blend {}‰ shed {}‰",
                    ctrl.blend_permille(tid),
                    ctrl.shed_permille(tid)
                )
            })
            .collect();
        println!("controller     : {} ticks  {}", ctrl.ticks(), state.join("  "));
    }
}
