//! Compute backends for the DSO executor pools.
//!
//! The orchestrator's unit of execution is a *packed batch*: one
//! profile-shaped `[M, D]` candidate tensor whose rows may come from
//! several concurrent requests (the batch coalescer's doing). Each
//! contiguous row segment binds its originating request's history, so
//! the engine interface is row-segmented: [`ComputeBackend::run_segmented`]
//! takes the candidate tensor plus an ordered list of (history, row
//! count) bindings.
//!
//! Two backends implement it:
//!
//! * [`crate::runtime::Engine`] — the compiled PJRT executable. Its HLO
//!   graph binds **one** history tensor per launch, so a mixed batch is
//!   emulated by replaying the launch once per distinct history and
//!   gathering each segment's rows. That preserves exact per-request
//!   scores but not the launch savings; compiling a natively segmented
//!   profile (per-row history indexing in the kernel) is the ROADMAP
//!   follow-up. Single-segment batches — every launch today — take the
//!   one-launch fast path unchanged.
//! * [`SimEngine`] — an artifact-free deterministic CPU backend with
//!   native per-segment history binding. Scores are a pure per-row
//!   function of (history summary, candidate row), evaluated in a fixed
//!   operation order, so any packing of the same rows produces
//!   bit-identical results — exactly the property the coalescer's score
//!   identity tests need. Tests and benches use it where artifacts /
//!   PJRT are unavailable.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::runtime::{Engine, HistBuffer};

/// A backend-owned handle to an uploaded history tensor, shareable
/// across the chunk executions of one request.
pub enum HistHandle {
    /// Device-resident `[L, D]` history (PJRT engine).
    Device(HistBuffer),
    /// Host-side per-dimension history summary (`SimEngine`): column
    /// means over the `L` axis, length `D`.
    Host(Vec<f32>),
    /// Raw `[L, D]` history copy (`fke::cpu::CpuEngine` — the native CPU
    /// engine binds full histories per segment inside one launch).
    Raw(Vec<f32>),
}

/// Cumulative kernel-execution counters of a compute backend. The PJRT
/// engine and `SimEngine` report zeroes (their cost model lives
/// elsewhere); the native CPU FKE fills every field.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KernelStats {
    /// Launches executed (`run_segmented` calls).
    pub launches: u64,
    /// Analytic FLOPs executed (GEMM-dominated accounting; the fused
    /// variant counts the attention work its mask schedule executes —
    /// visited-tile keys for scores, visible pairs for the weighted sum).
    pub flops: u64,
    /// Attention tiles visited by the mask-aware schedule.
    pub tiles_visited: u64,
    /// Attention tiles skipped as fully masked (0 for naive/api — they
    /// compute the dense score matrix).
    pub tiles_skipped: u64,
}

impl KernelStats {
    pub fn merge(&mut self, other: &KernelStats) {
        self.launches += other.launches;
        self.flops += other.flops;
        self.tiles_visited += other.tiles_visited;
        self.tiles_skipped += other.tiles_skipped;
    }

    /// Fraction of attention tiles the mask schedule skipped.
    pub fn tile_skip_fraction(&self) -> f64 {
        let total = self.tiles_visited + self.tiles_skipped;
        if total == 0 {
            return 0.0;
        }
        self.tiles_skipped as f64 / total as f64
    }
}

/// One row segment of a packed batch: `rows` consecutive candidate rows
/// scored against `hist`.
pub struct SegmentBind<'a> {
    pub hist: &'a HistHandle,
    pub rows: usize,
}

/// What an executor thread drives: a fixed-(M, D) scoring engine with
/// row-segmented history binding.
pub trait ComputeBackend: Send + Sync {
    /// Fixed candidate-row count (the profile size).
    fn m(&self) -> usize;
    fn n_tasks(&self) -> usize;
    fn d_model(&self) -> usize;
    /// Expected history length in f32 elements (`L * D`).
    fn hist_len(&self) -> usize;
    /// Upload / preprocess a history tensor once for reuse across
    /// launches.
    fn upload_hist(&self, hist: &[f32]) -> Result<HistHandle>;
    /// Execute one launch over `cands` `[M * D]`; `segments` partitions
    /// the M rows in order (their `rows` must sum to M), each bound to
    /// its own history. Returns `[M * n_tasks]` scores.
    fn run_segmented(&self, segments: &[SegmentBind<'_>], cands: &[f32]) -> Result<Vec<f32>>;
    /// Human-readable identity for error messages.
    fn label(&self) -> String;
    /// Rows this backend actually computes to serve one packed batch of
    /// `segments` segments. Natively segmented backends compute M rows
    /// in one launch; the PJRT emulation replays the launch per
    /// segment, so its real cost is `M * segments` — waste accounting
    /// must reflect that, not the orchestration-level ideal.
    fn executed_rows_for(&self, segments: usize) -> usize {
        let _ = segments;
        self.m()
    }
    /// Cumulative kernel counters (FLOPs, mask-tile schedule). Backends
    /// without a native cost model report zeroes.
    fn kernel_stats(&self) -> KernelStats {
        KernelStats::default()
    }

    /// Downcast for PJRT-engine-specific telemetry (`EngineStats`).
    fn as_engine(&self) -> Option<&Engine> {
        None
    }
}

pub(crate) fn check_segments(
    label: &str,
    segments: &[SegmentBind<'_>],
    cands_len: usize,
    m: usize,
    d: usize,
) -> Result<()> {
    if cands_len != m * d {
        return Err(Error::Internal(format!(
            "{label}: cands length {cands_len} != m {m} * d {d}"
        )));
    }
    let rows: usize = segments.iter().map(|s| s.rows).sum();
    if segments.is_empty() || rows != m {
        return Err(Error::Internal(format!(
            "{label}: segment rows {rows} (over {} segments) != m {m}",
            segments.len()
        )));
    }
    Ok(())
}

impl ComputeBackend for Engine {
    fn m(&self) -> usize {
        Engine::m(self)
    }

    fn n_tasks(&self) -> usize {
        self.config.n_tasks
    }

    fn d_model(&self) -> usize {
        self.config.d_model
    }

    fn hist_len(&self) -> usize {
        Engine::hist_len(self)
    }

    fn upload_hist(&self, hist: &[f32]) -> Result<HistHandle> {
        Ok(HistHandle::Device(Engine::upload_hist(self, hist)?))
    }

    fn run_segmented(&self, segments: &[SegmentBind<'_>], cands: &[f32]) -> Result<Vec<f32>> {
        let (m, d, nt) = (Engine::m(self), self.config.d_model, self.config.n_tasks);
        check_segments(&self.key.label(), segments, cands.len(), m, d)?;
        let device = |h: &HistHandle| -> Result<&HistBuffer> {
            match h {
                HistHandle::Device(buf) => Ok(buf),
                HistHandle::Host(_) | HistHandle::Raw(_) => Err(Error::Internal(format!(
                    "{}: host hist handle passed to the PJRT engine",
                    self.key.label()
                ))),
            }
        };
        if segments.len() == 1 {
            return self.run_with_hist(device(segments[0].hist)?, cands);
        }
        // Mixed-history emulation: the compiled graph binds one history
        // per launch, so replay it per segment and gather that segment's
        // rows. Scores are exact; the launch savings need a natively
        // segmented artifact (ROADMAP).
        let mut out = vec![0.0f32; m * nt];
        let mut off = 0usize;
        for seg in segments {
            let scores = self.run_with_hist(device(seg.hist)?, cands)?;
            out[off * nt..(off + seg.rows) * nt]
                .copy_from_slice(&scores[off * nt..(off + seg.rows) * nt]);
            off += seg.rows;
        }
        Ok(out)
    }

    fn label(&self) -> String {
        self.key.label()
    }

    fn executed_rows_for(&self, segments: usize) -> usize {
        Engine::m(self) * segments.max(1)
    }

    fn as_engine(&self) -> Option<&Engine> {
        Some(self)
    }
}

/// Artifact-free deterministic scoring backend (see module docs).
pub struct SimEngine {
    m: usize,
    seq_len: usize,
    d_model: usize,
    n_tasks: usize,
    /// Synthetic per-launch compute time (tests inject queue pressure
    /// and latency structure with it).
    compute_delay: Duration,
    /// Launches executed (tests assert launch savings with it).
    pub launches: AtomicU64,
}

impl SimEngine {
    pub fn new(m: usize, seq_len: usize, d_model: usize, n_tasks: usize) -> Self {
        SimEngine {
            m,
            seq_len,
            d_model,
            n_tasks,
            compute_delay: Duration::ZERO,
            launches: AtomicU64::new(0),
        }
    }

    /// Builder: sleep this long per launch (simulated model compute).
    pub fn with_delay(mut self, delay: Duration) -> Self {
        self.compute_delay = delay;
        self
    }

    pub fn launches(&self) -> u64 {
        self.launches.load(Ordering::Relaxed)
    }

    /// Fixed pseudo-weight for (task, dim) — any deterministic non-flat
    /// pattern works; the backend exists for packing-identity, not
    /// model fidelity.
    #[inline]
    fn weight(task: usize, k: usize) -> f32 {
        ((task * 31 + k * 17) % 13) as f32 / 13.0 - 0.5
    }
}

impl ComputeBackend for SimEngine {
    fn m(&self) -> usize {
        self.m
    }

    fn n_tasks(&self) -> usize {
        self.n_tasks
    }

    fn d_model(&self) -> usize {
        self.d_model
    }

    fn hist_len(&self) -> usize {
        self.seq_len * self.d_model
    }

    fn upload_hist(&self, hist: &[f32]) -> Result<HistHandle> {
        if hist.len() != self.hist_len() {
            return Err(Error::Internal(format!(
                "{}: hist length {} != expected {}",
                self.label(),
                hist.len(),
                self.hist_len()
            )));
        }
        // Column means over the L axis — the "device upload" analogue,
        // done once and reused across launches. Fixed accumulation
        // order keeps it bit-deterministic.
        let d = self.d_model;
        let mut summary = vec![0.0f32; d];
        for row in hist.chunks_exact(d) {
            for (s, &v) in summary.iter_mut().zip(row) {
                *s += v;
            }
        }
        let inv_l = 1.0 / self.seq_len as f32;
        for s in &mut summary {
            *s *= inv_l;
        }
        Ok(HistHandle::Host(summary))
    }

    fn run_segmented(&self, segments: &[SegmentBind<'_>], cands: &[f32]) -> Result<Vec<f32>> {
        let (m, d, nt) = (self.m, self.d_model, self.n_tasks);
        check_segments(&self.label(), segments, cands.len(), m, d)?;
        if !self.compute_delay.is_zero() {
            std::thread::sleep(self.compute_delay);
        }
        let mut out = Vec::with_capacity(m * nt);
        let mut row = 0usize;
        for seg in segments {
            let summary = match seg.hist {
                HistHandle::Host(s) if s.len() == d => s,
                HistHandle::Host(s) => {
                    return Err(Error::Internal(format!(
                        "{}: hist summary length {} != d {d}",
                        self.label(),
                        s.len()
                    )))
                }
                HistHandle::Device(_) | HistHandle::Raw(_) => {
                    return Err(Error::Internal(format!(
                        "{}: foreign hist handle passed to the sim engine",
                        self.label()
                    )))
                }
            };
            for r in row..row + seg.rows {
                let cand = &cands[r * d..(r + 1) * d];
                for t in 0..nt {
                    let mut z = 0.0f32;
                    for k in 0..d {
                        z += summary[k] * cand[k] * Self::weight(t, k);
                    }
                    out.push(1.0 / (1.0 + (-z).exp()));
                }
            }
            row += seg.rows;
        }
        self.launches.fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }

    fn label(&self) -> String {
        format!("sim/m{}", self.m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist(seq_len: usize, d: usize, salt: u64) -> Vec<f32> {
        (0..seq_len * d)
            .map(|i| (((i as u64 + salt) * 31 % 113) as f32 / 113.0) - 0.5)
            .collect()
    }

    fn cands(m: usize, d: usize, salt: u64) -> Vec<f32> {
        (0..m * d)
            .map(|i| (((i as u64 + salt) * 17 % 127) as f32 / 127.0) - 0.5)
            .collect()
    }

    #[test]
    fn sim_engine_scores_shape_and_range() {
        let e = SimEngine::new(8, 16, 4, 3);
        let h = e.upload_hist(&hist(16, 4, 1)).unwrap();
        let out = e
            .run_segmented(&[SegmentBind { hist: &h, rows: 8 }], &cands(8, 4, 2))
            .unwrap();
        assert_eq!(out.len(), 8 * 3);
        assert!(out.iter().all(|s| (0.0..=1.0).contains(s)));
        assert_eq!(e.launches(), 1);
    }

    #[test]
    fn sim_engine_packing_is_bit_identical() {
        // The coalescer's core contract: a row scores the same bits no
        // matter which batch it rides in or what occupies other rows.
        let e = SimEngine::new(8, 16, 4, 3);
        let ha = e.upload_hist(&hist(16, 4, 7)).unwrap();
        let hb = e.upload_hist(&hist(16, 4, 9)).unwrap();
        let ca = cands(3, 4, 11); // request A: 3 rows
        let cb = cands(5, 4, 13); // request B: 5 rows

        // packed: [A(3) | B(5)]
        let mut packed = ca.clone();
        packed.extend_from_slice(&cb);
        let out = e
            .run_segmented(
                &[SegmentBind { hist: &ha, rows: 3 }, SegmentBind { hist: &hb, rows: 5 }],
                &packed,
            )
            .unwrap();

        // solo: each request padded with arbitrary rows
        let mut solo_a = ca.clone();
        solo_a.extend_from_slice(&cands(5, 4, 99));
        let sa = e.run_segmented(&[SegmentBind { hist: &ha, rows: 8 }], &solo_a).unwrap();
        let mut solo_b = cb.clone();
        solo_b.extend_from_slice(&cands(3, 4, 98));
        let sb = e.run_segmented(&[SegmentBind { hist: &hb, rows: 8 }], &solo_b).unwrap();

        assert_eq!(&out[..3 * 3], &sa[..3 * 3], "A's rows must be bit-identical");
        assert_eq!(&out[3 * 3..], &sb[..5 * 3], "B's rows must be bit-identical");
    }

    #[test]
    fn sim_engine_rejects_bad_shapes() {
        let e = SimEngine::new(8, 16, 4, 3);
        assert!(e.upload_hist(&hist(8, 4, 1)).is_err(), "short hist rejected");
        let h = e.upload_hist(&hist(16, 4, 1)).unwrap();
        // segment rows don't cover m
        assert!(e
            .run_segmented(&[SegmentBind { hist: &h, rows: 5 }], &cands(8, 4, 2))
            .is_err());
        // cands wrong length
        assert!(e
            .run_segmented(&[SegmentBind { hist: &h, rows: 8 }], &cands(7, 4, 2))
            .is_err());
    }
}
