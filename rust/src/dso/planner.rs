//! The DSO batch-split planner.
//!
//! "When an upstream request arrives, we dynamically split the task based
//! on batch size (in descending order), assign it to the corresponding
//! executor in the queue" (§3.3). Given the available profile sizes
//! (ascending) and a request of M candidates, produce the chunk sizes to
//! dispatch: greedily take the largest profile that fits the remainder;
//! the final remainder is padded up to the smallest covering profile.

/// A planned split: chunk sizes (each a valid profile) plus how many
/// padded rows the tail chunk carries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SplitPlan {
    /// Profile sizes to execute, in dispatch (descending) order.
    pub chunks: Vec<usize>,
    /// Wasted rows: sum(chunks) - m.
    pub padding: usize,
}

impl SplitPlan {
    /// Total rows executed (≥ m).
    pub fn total(&self) -> usize {
        self.chunks.iter().sum()
    }
}

/// Compute the descending-order split of `m` candidates over `profiles`
/// (strictly ascending, non-empty).
///
/// Invariants (property-tested):
/// * every chunk is one of the profiles;
/// * chunks are non-increasing;
/// * total >= m and total - m < smallest profile (minimal padding under
///   the greedy policy);
/// * a request equal to one profile maps to exactly that profile.
pub fn plan_split(m: usize, profiles: &[usize]) -> SplitPlan {
    assert!(!profiles.is_empty(), "no profiles");
    debug_assert!(profiles.windows(2).all(|w| w[0] < w[1]), "profiles must ascend");
    let smallest = profiles[0];
    let mut chunks = Vec::new();
    let mut rest = m;
    // greedy descending
    for &p in profiles.iter().rev() {
        while rest >= p {
            chunks.push(p);
            rest -= p;
        }
    }
    let mut padding = 0;
    if rest > 0 {
        // pad the remainder up to the smallest covering profile
        let cover = *profiles.iter().find(|&&p| p >= rest).unwrap_or(&smallest);
        padding = cover - rest;
        chunks.push(cover);
        // keep dispatch order non-increasing
        chunks.sort_unstable_by(|a, b| b.cmp(a));
    }
    SplitPlan { chunks, padding }
}

/// Rows executed by the implicit-shape baseline (pad to max profile in
/// ceil(m / max) executions) — used by benches to report waste.
pub fn padded_rows(m: usize, max_profile: usize) -> usize {
    m.div_ceil(max_profile) * max_profile
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::util::propcheck;

    const PROFILES: &[usize] = &[128, 256, 512, 1024];

    #[test]
    fn exact_profile_maps_to_itself() {
        for &p in PROFILES {
            let plan = plan_split(p, PROFILES);
            assert_eq!(plan.chunks, vec![p]);
            assert_eq!(plan.padding, 0);
        }
    }

    #[test]
    fn descending_order() {
        let plan = plan_split(1024 + 512 + 128, PROFILES);
        assert_eq!(plan.chunks, vec![1024, 512, 128]);
        assert_eq!(plan.padding, 0);
    }

    #[test]
    fn remainder_padded_to_covering_profile() {
        let plan = plan_split(1000, PROFILES);
        // 1000 = 512 + 256 + 128 + 104(pad to 128)
        assert_eq!(plan.chunks, vec![512, 256, 128, 128]);
        assert_eq!(plan.padding, 24);
        assert_eq!(plan.total(), 1024);
    }

    #[test]
    fn tiny_request_uses_smallest() {
        let plan = plan_split(1, PROFILES);
        assert_eq!(plan.chunks, vec![128]);
        assert_eq!(plan.padding, 127);
    }

    #[test]
    fn zero_request_is_empty() {
        let plan = plan_split(0, PROFILES);
        assert!(plan.chunks.is_empty());
        assert_eq!(plan.padding, 0);
    }

    #[test]
    fn padded_rows_baseline() {
        assert_eq!(padded_rows(1, 1024), 1024);
        assert_eq!(padded_rows(1024, 1024), 1024);
        assert_eq!(padded_rows(1025, 1024), 2048);
    }

    #[test]
    fn prop_conservation_and_order() {
        propcheck::check("split conserves items, orders chunks", 2000, |g| {
            let m = g.usize_in(0, 5000);
            let plan = plan_split(m, PROFILES);
            prop_ensure!(plan.total() >= m, "total {} < m {m}", plan.total());
            prop_ensure!(plan.total() - m == plan.padding, "padding accounting");
            prop_ensure!(
                plan.padding < PROFILES[0].max(1),
                "padding {} >= smallest profile",
                plan.padding
            );
            prop_ensure!(
                plan.chunks.iter().all(|c| PROFILES.contains(c)),
                "chunk not a profile: {:?}",
                plan.chunks
            );
            prop_ensure!(
                plan.chunks.windows(2).all(|w| w[0] >= w[1]),
                "not descending: {:?}",
                plan.chunks
            );
            Ok(())
        });
    }

    #[test]
    fn prop_any_profile_set() {
        propcheck::check("split valid for random profile sets", 1000, |g| {
            // random strictly-ascending profile set
            let mut profs = g.vec_usize(1, 5, 1, 300);
            profs.sort_unstable();
            profs.dedup();
            let m = g.usize_in(0, 2000);
            let plan = plan_split(m, &profs);
            prop_ensure!(plan.total() >= m, "coverage");
            prop_ensure!(
                plan.chunks.iter().all(|c| profs.contains(c)),
                "chunks {:?} profiles {:?}",
                plan.chunks,
                profs
            );
            prop_ensure!(plan.padding < profs[0].max(1) || profs.len() == 1,
                "padding {} vs smallest {}", plan.padding, profs[0]);
            Ok(())
        });
    }

    #[test]
    fn split_beats_baseline_padding_on_mixed_m() {
        // the whole point of the DSO: less wasted compute than pad-to-max
        for m in [128usize, 256, 384, 512, 640, 768, 1000, 1024] {
            let dso = plan_split(m, PROFILES).total();
            let baseline = padded_rows(m, 1024);
            assert!(dso <= baseline, "m={m}: dso {dso} > baseline {baseline}");
        }
        // strict win on the average of the Table 5 mix
        let mix = [128usize, 256, 512, 1024];
        let dso: usize = mix.iter().map(|&m| plan_split(m, PROFILES).total()).sum();
        let base: usize = mix.iter().map(|&m| padded_rows(m, 1024)).sum();
        assert!(dso * 2 < base, "dso {dso} base {base}");
    }
}
