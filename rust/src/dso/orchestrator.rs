//! The DSO orchestrator: per-profile executor pools fed by an index
//! queue, descending batch-split dispatch, and the implicit-shape
//! (pad-to-max) baseline.
//!
//! Paper mapping (§3.3): a TensorRT profile+stream+graph triple is our
//! (engine, executor thread, preallocated staging) triple; "push the
//! index back to the queue after computation" is the worker loop pulling
//! the next job from its profile's channel. Requests are split with
//! `planner::plan_split` and chunks run concurrently across profiles.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::{DsoConfig, DsoMode};
use crate::error::{Error, Result};
use crate::runtime::{Engine, HistBuffer};

use super::planner::{padded_rows, plan_split, SplitPlan};

/// One chunk job for an executor. The reply carries (chunk index,
/// scores, executor-queue delay µs).
struct Job {
    /// Device-resident history shared by every chunk of the request —
    /// uploaded once in `submit` (§Perf: per-chunk re-upload removed).
    hist: Arc<HistBuffer>,
    cands: Vec<f32>,
    reply: Sender<Result<(usize, Vec<f32>, u64)>>,
    chunk_index: usize,
    enqueued: Instant,
}

/// Per-profile executor pool: a channel + N worker threads around one
/// compiled engine.
struct ProfilePool {
    tx: Sender<Job>,
    engine: Arc<Engine>,
    _workers: Vec<std::thread::JoinHandle<()>>,
}

/// Outcome metadata for one orchestrated request.
#[derive(Clone, Debug)]
pub struct ExecOutcome {
    /// Scores [m * n_tasks] for the *requested* m (padding stripped).
    pub scores: Vec<f32>,
    /// Profile chunks executed.
    pub chunks: Vec<usize>,
    /// Padded (wasted) rows.
    pub padding: usize,
    /// Pure model-compute wall time (max over parallel chunks), µs.
    pub compute_us: u64,
    /// Queueing delay before the first chunk started, µs.
    pub queue_us: u64,
}

/// The orchestrator over one (scenario, variant)'s profile engines.
pub struct Orchestrator {
    mode: DsoMode,
    pools: BTreeMap<usize, ProfilePool>,
    profiles: Vec<usize>,
    n_tasks: usize,
    d_model: usize,
    in_flight: Arc<AtomicUsize>,
    queue_capacity: usize,
    pub padded_rows_total: AtomicU64,
    pub executed_rows_total: AtomicU64,
}

impl Orchestrator {
    /// Build from one engine per profile (ascending M). Each profile gets
    /// `cfg.executors_per_profile` worker threads.
    pub fn new(engines: Vec<Engine>, cfg: &DsoConfig) -> Result<Self> {
        if engines.is_empty() {
            return Err(Error::Config("orchestrator needs at least one engine".into()));
        }
        let n_tasks = engines[0].config.n_tasks;
        let d_model = engines[0].config.d_model;
        let mut pools = BTreeMap::new();
        let mut profiles = Vec::new();
        let in_flight = Arc::new(AtomicUsize::new(0));
        for engine in engines {
            let m = engine.m();
            let engine = Arc::new(engine);
            let (tx, rx) = channel::<Job>();
            let rx = Arc::new(Mutex::new(rx));
            let mut workers = Vec::new();
            for w in 0..cfg.executors_per_profile.max(1) {
                let rx = Arc::clone(&rx);
                let eng = Arc::clone(&engine);
                let inflight = Arc::clone(&in_flight);
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("dso-m{m}-{w}"))
                        .spawn(move || executor_loop(rx, eng, inflight))
                        .map_err(|e| Error::Internal(format!("spawn executor: {e}")))?,
                );
            }
            profiles.push(m);
            pools.insert(m, ProfilePool { tx, engine, _workers: workers });
        }
        profiles.sort_unstable();
        Ok(Orchestrator {
            mode: cfg.mode,
            pools,
            profiles,
            n_tasks,
            d_model,
            in_flight,
            queue_capacity: cfg.queue_capacity,
            padded_rows_total: AtomicU64::new(0),
            executed_rows_total: AtomicU64::new(0),
        })
    }

    pub fn profiles(&self) -> &[usize] {
        &self.profiles
    }

    pub fn mode(&self) -> DsoMode {
        self.mode
    }

    pub fn max_profile(&self) -> usize {
        *self.profiles.last().unwrap()
    }

    /// Engine handle for a profile (benches/diagnostics).
    pub fn engine(&self, m: usize) -> Option<&Arc<Engine>> {
        self.pools.get(&m).map(|p| &p.engine)
    }

    /// The split this orchestrator will use for a request of `m`.
    pub fn plan(&self, m: usize) -> SplitPlan {
        match self.mode {
            DsoMode::Explicit => plan_split(m, &self.profiles),
            DsoMode::ImplicitPad => {
                let max = self.max_profile();
                let total = padded_rows(m, max);
                SplitPlan { chunks: vec![max; total / max], padding: total - m }
            }
        }
    }

    /// Execute one request: `hist` [L*D] shared across chunks, `cands`
    /// [m*D]. Returns stripped scores + execution metadata.
    pub fn submit(&self, hist: Arc<Vec<f32>>, cands: &[f32], m: usize) -> Result<ExecOutcome> {
        self.submit_slice(&hist, cands, m)
    }

    /// Like `submit` but borrowing the history slice: uploads it to the
    /// device once and shares the buffer across all chunk executors.
    pub fn submit_slice(&self, hist: &[f32], cands: &[f32], m: usize) -> Result<ExecOutcome> {
        if m == 0 {
            return Ok(ExecOutcome {
                scores: Vec::new(),
                chunks: Vec::new(),
                padding: 0,
                compute_us: 0,
                queue_us: 0,
            });
        }
        if cands.len() != m * self.d_model {
            return Err(Error::Internal(format!(
                "cands len {} != m {m} * d {}",
                cands.len(),
                self.d_model
            )));
        }
        let plan = self.plan(m);
        if self.in_flight.load(Ordering::Relaxed) + plan.chunks.len() > self.queue_capacity {
            return Err(Error::Overloaded(format!(
                "executor queue at capacity {}",
                self.queue_capacity
            )));
        }
        self.padded_rows_total.fetch_add(plan.padding as u64, Ordering::Relaxed);
        self.executed_rows_total.fetch_add(plan.total() as u64, Ordering::Relaxed);

        // upload the shared history once (any pool's engine: one client)
        let hist_dev = Arc::new(
            self.pools
                .values()
                .next()
                .ok_or_else(|| Error::Internal("no pools".into()))?
                .engine
                .upload_hist(hist)?,
        );

        // dispatch chunks (descending): chunk i covers rows [off, off+take)
        let (reply_tx, reply_rx): (
            Sender<Result<(usize, Vec<f32>, u64)>>,
            Receiver<Result<(usize, Vec<f32>, u64)>>,
        ) = channel();
        let mut offsets = Vec::with_capacity(plan.chunks.len());
        let mut off = 0usize;
        let submit_t = Instant::now();
        for (ci, &chunk) in plan.chunks.iter().enumerate() {
            let take = chunk.min(m - off);
            offsets.push((off, take));
            // build the chunk's candidate tensor, padding the tail chunk
            // by repeating the last real row (scores for pad rows are
            // stripped; repeating keeps values in-distribution).
            let mut buf = vec![0.0f32; chunk * self.d_model];
            let src = &cands[off * self.d_model..(off + take) * self.d_model];
            buf[..src.len()].copy_from_slice(src);
            if take < chunk {
                let last = &cands[(off + take - 1) * self.d_model..(off + take) * self.d_model];
                for r in take..chunk {
                    buf[r * self.d_model..(r + 1) * self.d_model].copy_from_slice(last);
                }
            }
            let pool = self.pools.get(&chunk).ok_or_else(|| {
                Error::UnknownEngine(format!("no executor pool for profile {chunk}"))
            })?;
            self.in_flight.fetch_add(1, Ordering::Relaxed);
            pool.tx
                .send(Job {
                    hist: Arc::clone(&hist_dev),
                    cands: buf,
                    reply: reply_tx.clone(),
                    chunk_index: ci,
                    enqueued: submit_t,
                })
                .map_err(|_| Error::Internal("executor pool closed".into()))?;
            off += take;
        }
        drop(reply_tx);

        // collect; queue_us is the delay before the *first* chunk was
        // picked up (min over chunks) — the request could not have
        // started computing any earlier
        let mut parts: Vec<Option<Vec<f32>>> = vec![None; plan.chunks.len()];
        let mut queue_us = u64::MAX;
        for _ in 0..plan.chunks.len() {
            let (ci, scores, chunk_queue_us) = reply_rx
                .recv()
                .map_err(|_| Error::Internal("executor dropped reply".into()))??;
            parts[ci] = Some(scores);
            queue_us = queue_us.min(chunk_queue_us);
        }
        let compute_us = submit_t.elapsed().as_micros() as u64;

        // assemble in request order, stripping padding
        let mut scores = Vec::with_capacity(m * self.n_tasks);
        for (ci, part) in parts.into_iter().enumerate() {
            let part = part.ok_or_else(|| Error::Internal("missing chunk".into()))?;
            let (_, take) = offsets[ci];
            scores.extend_from_slice(&part[..take * self.n_tasks]);
        }
        debug_assert_eq!(scores.len(), m * self.n_tasks);
        Ok(ExecOutcome {
            scores,
            chunks: plan.chunks,
            padding: plan.padding,
            compute_us,
            queue_us,
        })
    }

    /// Fraction of executed rows that were padding (waste metric).
    pub fn waste_fraction(&self) -> f64 {
        let ex = self.executed_rows_total.load(Ordering::Relaxed);
        if ex == 0 {
            return 0.0;
        }
        self.padded_rows_total.load(Ordering::Relaxed) as f64 / ex as f64
    }
}

fn executor_loop(
    rx: Arc<Mutex<Receiver<Job>>>,
    engine: Arc<Engine>,
    in_flight: Arc<AtomicUsize>,
) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // orchestrator dropped
            }
        };
        let queue_us = job.enqueued.elapsed().as_micros() as u64;
        let result = engine
            .run_with_hist(&job.hist, &job.cands)
            .map(|scores| (job.chunk_index, scores, queue_us));
        in_flight.fetch_sub(1, Ordering::Relaxed);
        let _ = job.reply.send(result);
    }
}
