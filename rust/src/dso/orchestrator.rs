//! The DSO orchestrator: per-profile executor pools fed by an index
//! queue, descending batch-split dispatch, cross-request batch
//! coalescing, and the implicit-shape (pad-to-max) baseline.
//!
//! Paper mapping (§3.3): a TensorRT profile+stream+graph triple is our
//! (engine, executor thread, preallocated staging) triple; "push the
//! index back to the queue after computation" is the worker loop pulling
//! the next job from its profile's channel. Requests are split with
//! `planner::plan_split` and chunks run concurrently across profiles.
//!
//! The unit of execution is a packed [`Job`]: one profile-shaped batch
//! whose rows may come from several requests (each a [`Segment`] binding
//! its own history). Full chunks dispatch directly as single-segment
//! jobs; tail remainders go through the [`Coalescer`] when enabled, so
//! concurrent requests' remainders share a launch instead of each
//! padding its own. Executors demux per-segment score rows back to each
//! request's reply channel — scatter/gather that preserves every
//! request's candidate order exactly.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cancel::{CancelStage, CancelToken};
use crate::chaos::{ChaosSlot, FaultPlan, PanicSite};
use crate::config::{DsoConfig, DsoMode};
use crate::error::{Error, Result};
use crate::metrics::Recorder;
use crate::obs::{self, SharedSpan, StageKind};
use crate::runtime::Engine;

use super::backend::{ComputeBackend, HistHandle, SegmentBind};
use super::coalescer::{BufferPool, Coalescer, CoalesceStats};
use super::planner::{padded_rows, plan_split, SplitPlan};

/// One row segment of a packed job: `rows` consecutive candidate rows
/// belonging to one request chunk, bound to that request's history.
pub(crate) struct Segment {
    pub hist: Arc<HistHandle>,
    /// Real rows (padding is never part of a segment).
    pub rows: usize,
    /// Index of this chunk in the originating request's split plan.
    pub chunk_index: usize,
    pub enqueued: Instant,
    /// Originating request's trace id (0 = untraced). Carried so a
    /// packed launch can name every rider on its shared launch span.
    pub trace_id: u64,
    /// Originating request's cancel token (`None` = the caller does not
    /// participate in cooperative cancellation). Checked when a pending
    /// batch is inspected and immediately before an engine launch.
    pub cancel: Option<CancelToken>,
    pub reply: Sender<Result<ChunkDone>>,
}

/// One packed batch for an executor: a profile-shaped candidate tensor
/// plus the ordered segments its rows came from.
pub(crate) struct Job {
    pub cands: Vec<f32>,
    pub segments: Vec<Segment>,
}

/// Executor reply for one request chunk (already demuxed: scores cover
/// this chunk's real rows only).
pub(crate) struct ChunkDone {
    pub chunk_index: usize,
    pub scores: Vec<f32>,
    /// Delay between submit/enqueue and executor pickup, µs.
    pub queue_us: u64,
    /// Wall time of the engine launch that served this chunk, µs.
    pub compute_us: u64,
    /// Shared launch-span id this chunk rode (0 = untraced launch).
    pub launch_id: u64,
}

/// Per-profile executor pool: a channel + N worker threads around one
/// compiled engine.
struct ProfilePool {
    tx: Sender<Job>,
    engine: Arc<dyn ComputeBackend>,
    _workers: Vec<std::thread::JoinHandle<()>>,
}

/// Outcome metadata for one orchestrated request.
#[derive(Clone, Debug)]
pub struct ExecOutcome {
    /// Scores [m * n_tasks] for the *requested* m (padding stripped).
    pub scores: Vec<f32>,
    /// Profile chunks executed.
    pub chunks: Vec<usize>,
    /// Planned padded rows. With coalescing enabled this is the
    /// *pre-coalescing* figure — the realized padding (usually lower,
    /// because other requests' rows filled the tail) is tracked in
    /// `padded_rows_total`.
    pub padding: usize,
    /// Pure model-compute wall time: the slowest chunk's engine launch,
    /// measured around the launch itself — executor-queue delay and
    /// coalesce wait are excluded (they are `queue_us`).
    pub compute_us: u64,
    /// Queueing delay before the first chunk started, µs.
    pub queue_us: u64,
    /// Shared launch-span ids the request's chunks rode (deduped,
    /// empty unless the request was traced) — the caller links its
    /// compute span to these so cross-request causality is visible.
    pub launch_ids: Vec<u64>,
}

/// The orchestrator over one (scenario, variant)'s profile engines.
pub struct Orchestrator {
    mode: DsoMode,
    pools: BTreeMap<usize, ProfilePool>,
    profiles: Vec<usize>,
    n_tasks: usize,
    d_model: usize,
    in_flight: Arc<AtomicUsize>,
    queue_capacity: usize,
    buffers: Arc<BufferPool>,
    coalescer: Option<Arc<Coalescer>>,
    flusher: Option<std::thread::JoinHandle<()>>,
    pub padded_rows_total: Arc<AtomicU64>,
    pub executed_rows_total: Arc<AtomicU64>,
    /// Fault-injection point shared with every executor thread:
    /// compute-backend stalls and executor-panic schedules.
    chaos: Arc<ChaosSlot>,
}

impl Orchestrator {
    /// Build from one engine per profile (ascending M). Each profile gets
    /// `cfg.executors_per_profile` worker threads.
    pub fn new(engines: Vec<Engine>, cfg: &DsoConfig) -> Result<Self> {
        Self::from_backends(Self::erase(engines), cfg, None)
    }

    /// Like [`Orchestrator::new`], but coalescer/occupancy telemetry is
    /// mirrored into `recorder` (the serving stack's metrics).
    pub fn with_recorder(
        engines: Vec<Engine>,
        cfg: &DsoConfig,
        recorder: Arc<Recorder>,
    ) -> Result<Self> {
        Self::from_backends(Self::erase(engines), cfg, Some(recorder))
    }

    fn erase(engines: Vec<Engine>) -> Vec<Arc<dyn ComputeBackend>> {
        engines
            .into_iter()
            .map(|e| Arc::new(e) as Arc<dyn ComputeBackend>)
            .collect()
    }

    /// Build from any backend set — real PJRT engines or artifact-free
    /// [`super::SimEngine`]s (tests, benches, examples).
    pub fn from_backends(
        backends: Vec<Arc<dyn ComputeBackend>>,
        cfg: &DsoConfig,
        recorder: Option<Arc<Recorder>>,
    ) -> Result<Self> {
        if backends.is_empty() {
            return Err(Error::Config("orchestrator needs at least one engine".into()));
        }
        let n_tasks = backends[0].n_tasks();
        let d_model = backends[0].d_model();
        for b in &backends {
            if b.n_tasks() != n_tasks || b.d_model() != d_model {
                return Err(Error::Config(format!(
                    "backend {} disagrees on (n_tasks, d_model)",
                    b.label()
                )));
            }
        }
        let buffers = Arc::new(BufferPool::new(2 * cfg.executors_per_profile.max(1) + 2));
        let padded_rows_total = Arc::new(AtomicU64::new(0));
        let executed_rows_total = Arc::new(AtomicU64::new(0));
        let chaos = Arc::new(ChaosSlot::new());
        let mut pools = BTreeMap::new();
        let mut profiles = Vec::new();
        let in_flight = Arc::new(AtomicUsize::new(0));
        for engine in backends {
            let m = engine.m();
            let (tx, rx) = channel::<Job>();
            let rx = Arc::new(Mutex::new(rx));
            let mut workers = Vec::new();
            for w in 0..cfg.executors_per_profile.max(1) {
                let ctx = ExecutorCtx {
                    rx: Arc::clone(&rx),
                    engine: Arc::clone(&engine),
                    in_flight: Arc::clone(&in_flight),
                    buffers: Arc::clone(&buffers),
                    executed_rows: Arc::clone(&executed_rows_total),
                    padded_rows: Arc::clone(&padded_rows_total),
                    recorder: recorder.clone(),
                    chaos: Arc::clone(&chaos),
                };
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("dso-m{m}-{w}"))
                        .spawn(move || executor_loop(ctx))
                        .map_err(|e| Error::Internal(format!("spawn executor: {e}")))?,
                );
            }
            if pools.insert(m, ProfilePool { tx, engine, _workers: workers }).is_some() {
                return Err(Error::Config(format!("duplicate profile m={m}")));
            }
            profiles.push(m);
        }
        profiles.sort_unstable();

        let (coalescer, flusher) = if cfg.coalesce {
            let senders: BTreeMap<usize, Sender<Job>> =
                pools.iter().map(|(&m, p)| (m, p.tx.clone())).collect();
            let co = Arc::new(Coalescer::new(
                cfg.coalesce_wait_us,
                d_model,
                senders,
                Arc::clone(&buffers),
                Arc::clone(&in_flight),
                recorder,
            ));
            let runner = Arc::clone(&co);
            let handle = std::thread::Builder::new()
                .name("dso-coalesce-flush".into())
                .spawn(move || runner.run_flusher())
                .map_err(|e| Error::Internal(format!("spawn coalesce flusher: {e}")))?;
            (Some(co), Some(handle))
        } else {
            (None, None)
        };

        Ok(Orchestrator {
            mode: cfg.mode,
            pools,
            profiles,
            n_tasks,
            d_model,
            in_flight,
            queue_capacity: cfg.queue_capacity,
            buffers,
            coalescer,
            flusher,
            padded_rows_total,
            executed_rows_total,
            chaos,
        })
    }

    /// Arm the executors' fault-injection point with a chaos plan
    /// (compute stalls and executor-panic schedules).
    pub fn arm_chaos(&self, plan: Arc<FaultPlan>) {
        self.chaos.arm(plan);
    }

    pub fn profiles(&self) -> &[usize] {
        &self.profiles
    }

    pub fn mode(&self) -> DsoMode {
        self.mode
    }

    pub fn max_profile(&self) -> usize {
        // lint: allow(panic) profiles is validated non-empty at construction
        *self.profiles.last().unwrap()
    }

    /// Backend handle for a profile (benches/diagnostics).
    pub fn backend(&self, m: usize) -> Option<&Arc<dyn ComputeBackend>> {
        self.pools.get(&m).map(|p| &p.engine)
    }

    /// Cumulative kernel counters summed across every profile backend
    /// (all zeroes unless the backends are native CPU FKE engines).
    pub fn kernel_stats(&self) -> super::backend::KernelStats {
        let mut ks = super::backend::KernelStats::default();
        for p in self.pools.values() {
            ks.merge(&p.engine.kernel_stats());
        }
        ks
    }

    /// Reserved executor-queue units currently outstanding (admission
    /// reservations that have not completed yet).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Whether cross-request coalescing is active.
    pub fn coalesce_enabled(&self) -> bool {
        self.coalescer.is_some()
    }

    /// Coalescer counters (zeroes when coalescing is off).
    pub fn coalesce_stats(&self) -> CoalesceStats {
        self.coalescer.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// The split this orchestrator will use for a request of `m`.
    pub fn plan(&self, m: usize) -> SplitPlan {
        match self.mode {
            DsoMode::Explicit => plan_split(m, &self.profiles),
            DsoMode::ImplicitPad => {
                let max = self.max_profile();
                let total = padded_rows(m, max);
                SplitPlan { chunks: vec![max; total / max], padding: total - m }
            }
        }
    }

    /// Execute one request: `hist` [L*D] shared across chunks, `cands`
    /// [m*D]. Returns stripped scores + execution metadata.
    pub fn submit(&self, hist: Arc<Vec<f32>>, cands: &[f32], m: usize) -> Result<ExecOutcome> {
        self.submit_slice(&hist, cands, m)
    }

    /// Like `submit` but borrowing the history slice: uploads it to the
    /// device once and shares the buffer across all chunk executors.
    pub fn submit_slice(&self, hist: &[f32], cands: &[f32], m: usize) -> Result<ExecOutcome> {
        self.submit_traced(hist, cands, m, 0)
    }

    /// Like [`Orchestrator::submit_slice`], stamping every dispatched
    /// segment with the request's trace id so shared launches can name
    /// it as a rider (`trace_id` 0 = untraced; the default path).
    pub fn submit_traced(
        &self,
        hist: &[f32],
        cands: &[f32],
        m: usize,
        trace_id: u64,
    ) -> Result<ExecOutcome> {
        self.submit_cancellable(hist, cands, m, trace_id, None)
    }

    /// Like [`Orchestrator::submit_traced`], carrying the request's
    /// [`CancelToken`]: the token is re-checked immediately after
    /// admission (the last cheap point before device upload and
    /// dispatch), every dispatched segment carries a clone so the
    /// coalescer can evict it from a still-open batch, and a packed job
    /// whose riders *all* cancelled skips its engine launch entirely.
    /// Drop sites reply [`Error::Cancelled`] with the stage that dropped
    /// the work; the caller is the single site that counts it.
    pub fn submit_cancellable(
        &self,
        hist: &[f32],
        cands: &[f32],
        m: usize,
        trace_id: u64,
        cancel: Option<CancelToken>,
    ) -> Result<ExecOutcome> {
        if m == 0 {
            return Ok(ExecOutcome {
                scores: Vec::new(),
                chunks: Vec::new(),
                padding: 0,
                compute_us: 0,
                queue_us: 0,
                launch_ids: Vec::new(),
            });
        }
        if cands.len() != m * self.d_model {
            return Err(Error::Internal(format!(
                "cands len {} != m {m} * d {}",
                cands.len(),
                self.d_model
            )));
        }
        let plan = self.plan(m);

        // admission: a single atomic reservation of all chunk units. The
        // CAS loop (not load-then-add) means concurrent submits can never
        // drive the count past capacity, even transiently.
        let want = plan.chunks.len();
        let mut cur = self.in_flight.load(Ordering::Acquire);
        loop {
            if cur + want > self.queue_capacity {
                return Err(Error::Overloaded(format!(
                    "executor queue at capacity {}",
                    self.queue_capacity
                )));
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + want,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        // From here on every early return must release the units that
        // will never reach an executor. Units reach exactly one owner:
        // executors release what they run, the coalescer releases what
        // it evicts (cancelled riders) or accepted but cannot deliver,
        // and this function releases what was never handed off at all.
        let release = |n: usize| {
            if n > 0 {
                self.in_flight.fetch_sub(n, Ordering::AcqRel);
            }
        };

        // pre-dispatch token check: the admission wait above may have
        // outlived the request — this is the last cheap point to bail
        // before the device upload and executor dispatch
        if let Some(cause) = cancel.as_ref().and_then(|t| t.poll()) {
            release(want);
            return Err(Error::Cancelled(cause, CancelStage::Launch));
        }

        for &chunk in &plan.chunks {
            if !self.pools.contains_key(&chunk) {
                release(want);
                return Err(Error::UnknownEngine(format!(
                    "no executor pool for profile {chunk}"
                )));
            }
        }

        // upload the shared history once (any pool's engine: one client)
        // lint: allow(panic) pools is validated non-empty at construction
        let hist_dev = match self.pools.values().next().unwrap().engine.upload_hist(hist) {
            Ok(h) => Arc::new(h),
            Err(e) => {
                release(want);
                return Err(e);
            }
        };

        // dispatch chunks (descending): chunk i covers rows [off, off+take)
        let (reply_tx, reply_rx): (
            Sender<Result<ChunkDone>>,
            Receiver<Result<ChunkDone>>,
        ) = channel();
        let d = self.d_model;
        let mut takes = Vec::with_capacity(plan.chunks.len());
        let mut off = 0usize;
        let mut dispatched = 0usize;
        for (ci, &chunk) in plan.chunks.iter().enumerate() {
            let take = chunk.min(m - off);
            takes.push(take);
            let rows = &cands[off * d..(off + take) * d];
            let sent = match (&self.coalescer, take < chunk) {
                // tail remainder + coalescing on: pack with other
                // requests' remainders instead of padding alone
                (Some(co), true) => co.enqueue(
                    chunk,
                    &hist_dev,
                    rows,
                    take,
                    ci,
                    trace_id,
                    cancel.clone(),
                    reply_tx.clone(),
                ),
                _ => self.dispatch_direct(
                    chunk,
                    rows,
                    take,
                    ci,
                    trace_id,
                    cancel.clone(),
                    &hist_dev,
                    &reply_tx,
                ),
            };
            if let Err(e) = sent {
                release(want - dispatched);
                return Err(e);
            }
            dispatched += 1;
            off += take;
        }
        drop(reply_tx);

        // collect; queue_us is the delay before the *first* chunk was
        // picked up (min over chunks) — the request could not have
        // started computing any earlier. compute_us is the slowest
        // chunk's launch time (chunks run in parallel).
        let mut parts: Vec<Option<Vec<f32>>> = vec![None; plan.chunks.len()];
        let mut queue_us = u64::MAX;
        let mut compute_us = 0u64;
        let mut launch_ids: Vec<u64> = Vec::new();
        for _ in 0..plan.chunks.len() {
            let done = reply_rx
                .recv()
                .map_err(|_| Error::Internal("executor dropped reply".into()))??;
            queue_us = queue_us.min(done.queue_us);
            compute_us = compute_us.max(done.compute_us);
            if done.launch_id != 0 && !launch_ids.contains(&done.launch_id) {
                launch_ids.push(done.launch_id);
            }
            parts[done.chunk_index] = Some(done.scores);
        }

        // assemble in request order; parts carry real rows only
        let mut scores = Vec::with_capacity(m * self.n_tasks);
        for (ci, part) in parts.into_iter().enumerate() {
            let part = part.ok_or_else(|| Error::Internal("missing chunk".into()))?;
            debug_assert_eq!(part.len(), takes[ci] * self.n_tasks);
            scores.extend_from_slice(&part);
        }
        debug_assert_eq!(scores.len(), m * self.n_tasks);
        Ok(ExecOutcome {
            scores,
            chunks: plan.chunks,
            padding: plan.padding,
            compute_us,
            queue_us,
            launch_ids,
        })
    }

    /// Dispatch one chunk as its own single-segment job (full chunks
    /// always; remainders too when coalescing is off — padded locally by
    /// repeating the last real row).
    #[allow(clippy::too_many_arguments)]
    fn dispatch_direct(
        &self,
        chunk: usize,
        rows: &[f32],
        take: usize,
        chunk_index: usize,
        trace_id: u64,
        cancel: Option<CancelToken>,
        hist: &Arc<HistHandle>,
        reply: &Sender<Result<ChunkDone>>,
    ) -> Result<()> {
        let d = self.d_model;
        let mut buf = self.buffers.get(chunk * d);
        buf[..take * d].copy_from_slice(rows);
        if take < chunk {
            super::coalescer::pad_with_last_row(&mut buf, take, chunk, d);
        }
        self.pools
            .get(&chunk)
            .ok_or_else(|| Error::UnknownEngine(format!("no executor pool for profile {chunk}")))?
            .tx
            .send(Job {
                cands: buf,
                segments: vec![Segment {
                    hist: Arc::clone(hist),
                    rows: take,
                    chunk_index,
                    enqueued: Instant::now(),
                    trace_id,
                    cancel,
                    reply: reply.clone(),
                }],
            })
            .map_err(|_| Error::Internal("executor pool closed".into()))
    }

    /// Fraction of executed rows that were padding (waste metric).
    /// Rows are accounted by the executors via
    /// `ComputeBackend::executed_rows_for`, so a backend that emulates
    /// mixed-history batches by replaying the launch (the PJRT engine)
    /// reports its real cost, not the orchestration-level ideal.
    pub fn waste_fraction(&self) -> f64 {
        let ex = self.executed_rows_total.load(Ordering::Relaxed);
        if ex == 0 {
            return 0.0;
        }
        self.padded_rows_total.load(Ordering::Relaxed) as f64 / ex as f64
    }
}

impl Drop for Orchestrator {
    fn drop(&mut self) {
        // Stop the flusher before the pools (and their senders) go away;
        // it drains any open batches on the way out.
        if let Some(co) = &self.coalescer {
            co.begin_shutdown();
        }
        if let Some(handle) = self.flusher.take() {
            let _ = handle.join();
        }
    }
}

/// Everything one executor thread needs (bundled so worker spawning
/// stays readable).
struct ExecutorCtx {
    rx: Arc<Mutex<Receiver<Job>>>,
    engine: Arc<dyn ComputeBackend>,
    in_flight: Arc<AtomicUsize>,
    buffers: Arc<BufferPool>,
    executed_rows: Arc<AtomicU64>,
    padded_rows: Arc<AtomicU64>,
    /// For launch spans: the stack's recorder carries the tracer when
    /// tracing is on (None / no tracer ⇒ zero per-launch overhead).
    recorder: Option<Arc<Recorder>>,
    /// Fault-injection point: compute stalls and executor panics.
    chaos: Arc<ChaosSlot>,
}

fn executor_loop(ctx: ExecutorCtx) {
    let ExecutorCtx { rx, engine, in_flight, buffers, executed_rows, padded_rows, recorder, chaos } =
        ctx;
    let n_tasks = engine.n_tasks();
    let m = engine.m();
    loop {
        let job = {
            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return, // orchestrator dropped
            }
        };
        // lint: supervisor — a panic mid-launch (injected or real) must
        // fail this job's riders with a typed error, release their queue
        // units, and leave the executor alive for the next job. The job
        // is only borrowed by the supervised body, so its reply channels
        // and buffer survive an unwind.
        let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(&job, &engine, &executed_rows, &padded_rows, &recorder, &chaos, n_tasks, m)
        }));
        if ran.is_err() {
            if let Some(r) = &recorder {
                r.record_worker_restart();
            }
            for seg in &job.segments {
                let _ = seg.reply.send(Err(Error::WorkerPanic(format!(
                    "{}: executor panicked mid-launch",
                    engine.label()
                ))));
            }
        }
        in_flight.fetch_sub(job.segments.len(), Ordering::AcqRel);
        buffers.put(job.cands);
    }
}

/// The supervised per-job body of [`executor_loop`]: accounting, the
/// engine launch, and per-segment demux. Split out so the unwind
/// boundary around it stays visually small.
#[allow(clippy::too_many_arguments)]
fn run_job(
    job: &Job,
    engine: &Arc<dyn ComputeBackend>,
    executed_rows: &AtomicU64,
    padded_rows: &AtomicU64,
    recorder: &Option<Arc<Recorder>>,
    chaos: &ChaosSlot,
    n_tasks: usize,
    m: usize,
) {
    if let Some(plan) = chaos.get() {
        if let Some(us) = plan.compute_stall_us() {
            crate::util::timeutil::precise_wait(Duration::from_micros(us));
        }
        if plan.panic_due(PanicSite::Executor) {
            // lint: allow(panic) chaos injection, caught by the executor supervisor
            panic!("chaos: injected executor panic");
        }
    }
    // pre-launch purge: if *every* rider's token has fired, the launch
    // serves no one — reply each segment its typed cause and skip the
    // engine entirely. A mixed job launches untouched: riders packed
    // next to live rows complete normally (score identity preserved).
    if !job.segments.is_empty()
        && job.segments.iter().all(|s| s.cancel.as_ref().and_then(|t| t.poll()).is_some())
    {
        for seg in &job.segments {
            let cause = seg
                .cancel
                .as_ref()
                .and_then(|t| t.cause())
                .unwrap_or(crate::cancel::CancelCause::Expired);
            let _ = seg.reply.send(Err(Error::Cancelled(cause, CancelStage::Launch)));
        }
        return;
    }
    let picked = Instant::now();
    let real_rows: usize = job.segments.iter().map(|s| s.rows).sum();
    let pad = m - real_rows;
    // waste accounting lives here, where the backend's real launch
    // cost is known (a segment-emulating backend replays per hist)
    let launched = engine.executed_rows_for(job.segments.len());
    executed_rows.fetch_add(launched as u64, Ordering::Relaxed);
    padded_rows.fetch_add((launched - real_rows) as u64, Ordering::Relaxed);
    let last = job.segments.len() - 1;
    let binds: Vec<SegmentBind<'_>> = job
        .segments
        .iter()
        .enumerate()
        .map(|(i, s)| SegmentBind {
            hist: &s.hist,
            // pad rows repeat the last segment's final row, so they
            // bind that segment's history
            rows: s.rows + if i == last { pad } else { 0 },
        })
        .collect();
    // shared launch span: one per packed launch when any rider is
    // traced. Lists every rider's trace id — including riders head
    // sampling dropped — so cross-request causality survives
    // sampling; riders link back through `launch_id`.
    let tracing = recorder
        .as_ref()
        .filter(|_| job.segments.iter().any(|s| s.trace_id != 0))
        .and_then(|r| r.tracer().map(|t| (Arc::clone(t), r.tracer_pid())));
    let launch_begin = tracing.as_ref().map_or(0, |(t, _)| t.now_us());
    // compute_us is measured around the launch alone — queue delay
    // (including coalesce wait) is reported separately per segment
    let t0 = Instant::now();
    let result = engine.run_segmented(&binds, &job.cands);
    let compute_us = t0.elapsed().as_micros() as u64;
    let launch_id = match &tracing {
        Some((t, pid)) => {
            let id = t.new_span_id();
            t.emit_shared(SharedSpan {
                span_id: id,
                kind: StageKind::Launch,
                label: format!(
                    "launch m={m} [{}] ×{}",
                    engine.label(),
                    job.segments.len()
                ),
                begin_us: launch_begin,
                end_us: t.now_us(),
                pid: *pid,
                tid: obs::tid(),
                member_traces: job
                    .segments
                    .iter()
                    .map(|s| s.trace_id)
                    .filter(|&id| id != 0)
                    .collect(),
            });
            id
        }
        None => 0,
    };
    match result {
        Ok(scores) => {
            let mut off = 0usize;
            for seg in &job.segments {
                let part = scores[off * n_tasks..(off + seg.rows) * n_tasks].to_vec();
                off += seg.rows;
                let queue_us =
                    picked.saturating_duration_since(seg.enqueued).as_micros() as u64;
                let _ = seg.reply.send(Ok(ChunkDone {
                    chunk_index: seg.chunk_index,
                    scores: part,
                    queue_us,
                    compute_us,
                    launch_id,
                }));
            }
        }
        Err(e) => {
            for seg in &job.segments {
                let _ = seg.reply.send(Err(Error::Internal(format!(
                    "{}: packed launch failed: {e}",
                    engine.label()
                ))));
            }
        }
    }
}
