//! DSO — Dynamic Stream Orchestrator (paper §3.3, Fig 10).
//!
//! The explicit-shape execution layer: one precompiled engine per
//! candidate-count profile, each wrapped in executors with preallocated
//! resources, an executor index queue, and the batch-routing planner that
//! splits an incoming request's M candidates across profiles **in
//! descending order**. The implicit-shape baseline (pad everything to the
//! max profile) lives here too so Table 5 is one flag apart.
//!
//! On top of the split sits the **cross-request batch coalescer**
//! (`coalescer`): with `DsoConfig::coalesce` on, tail remainders of
//! concurrent requests pack into one shared profile launch (bounded by
//! `coalesce_wait_us`) instead of each padding its own — the dominant
//! waste under the paper's non-uniform upstream candidate counts.
//! Engines implement the row-segmented [`ComputeBackend`] interface so a
//! packed batch can bind a history per request segment; [`SimEngine`] is
//! the artifact-free deterministic backend used to prove score identity
//! under any packing.

pub mod backend;
mod coalescer;
pub mod orchestrator;
pub mod planner;

pub use backend::{ComputeBackend, HistHandle, KernelStats, SegmentBind, SimEngine};
pub use coalescer::CoalesceStats;
pub use orchestrator::{ExecOutcome, Orchestrator};
pub use planner::{plan_split, SplitPlan};
