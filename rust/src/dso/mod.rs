//! DSO — Dynamic Stream Orchestrator (paper §3.3, Fig 10).
//!
//! The explicit-shape execution layer: one precompiled engine per
//! candidate-count profile, each wrapped in executors with preallocated
//! resources, an executor index queue, and the batch-routing planner that
//! splits an incoming request's M candidates across profiles **in
//! descending order**. The implicit-shape baseline (pad everything to the
//! max profile) lives here too so Table 5 is one flag apart.

pub mod orchestrator;
pub mod planner;

pub use orchestrator::{Orchestrator, ExecOutcome};
pub use planner::{plan_split, SplitPlan};
