//! Cross-request batch coalescer: per-profile pending-batch slots that
//! pack candidate-row remainders from *different* concurrent requests
//! into one engine launch.
//!
//! Today a 1-candidate request pads a full profile launch on its own
//! (127/128 rows wasted at the paper's smallest profile) and every
//! concurrent small request pays its own launch. The coalescer gives
//! each profile one open [`PendingBatch`]; a request's tail remainder
//! copies its real rows into the batch at the current fill offset and
//! registers a reply segment. The batch is dispatched when it fills, or
//! when its `coalesce_wait_us` deadline expires (a dedicated flusher
//! thread watches the earliest deadline), so the added per-request
//! latency is bounded and the < 50 ms envelope holds. The executor
//! demuxes each launch's output rows back to the originating requests'
//! reply channels — every request still receives scores in its own
//! candidate order (see `orchestrator::executor_loop`).
//!
//! Locking: each profile has its own slot mutex, so concurrent
//! remainder enqueues contend (and pay the row memcpy) only within a
//! profile — a burst across profiles never serializes on one lock. A
//! separate signal mutex + condvar parks the flusher; it is taken only
//! when a fresh batch opens (new earliest deadline) or at shutdown,
//! never while a slot lock is held, so the two lock orders cannot
//! deadlock and the wakeup cannot be lost.
//!
//! Buffers for packed batches (and for the direct-dispatch path) come
//! from a [`BufferPool`], killing the per-job `vec![0.0; chunk * d]`
//! allocation the hot path used to pay.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cancel::{CancelStage, CancelToken};
use crate::error::{Error, Result};
use crate::metrics::{Histogram, Recorder};

use super::orchestrator::{Job, Segment};

/// Pooled, size-keyed f32 buffers for chunk/batch candidate tensors.
/// `get` hands out a possibly-dirty buffer of exactly the requested
/// length — callers overwrite every row (real rows + padding), so no
/// zeroing pass is paid on reuse.
pub(crate) struct BufferPool {
    shelves: Mutex<BTreeMap<usize, Vec<Vec<f32>>>>,
    max_per_size: usize,
}

impl BufferPool {
    pub(crate) fn new(max_per_size: usize) -> Self {
        BufferPool { shelves: Mutex::new(BTreeMap::new()), max_per_size: max_per_size.max(1) }
    }

    pub(crate) fn get(&self, len: usize) -> Vec<f32> {
        if let Some(buf) = self
            .shelves
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get_mut(&len)
            .and_then(|shelf| shelf.pop())
        {
            return buf;
        }
        vec![0.0; len]
    }

    pub(crate) fn put(&self, buf: Vec<f32>) {
        if buf.is_empty() {
            return;
        }
        let mut shelves = self.shelves.lock().unwrap_or_else(|e| e.into_inner());
        let shelf = shelves.entry(buf.len()).or_default();
        if shelf.len() < self.max_per_size {
            shelf.push(buf);
        }
    }
}

/// Fill rows `[fill_rows, total_rows)` of `buf` (row width `d`) by
/// repeating the last real row — in-distribution padding whose scores
/// are never returned to anyone. Shared by the direct-dispatch path and
/// the coalescer so the two can never diverge on what pad rows contain.
// lint: no_alloc — per-request hot path, must stay allocation-free
pub(crate) fn pad_with_last_row(buf: &mut [f32], fill_rows: usize, total_rows: usize, d: usize) {
    debug_assert!(fill_rows > 0 && fill_rows <= total_rows);
    debug_assert!(buf.len() >= total_rows * d);
    let (head, tail) = buf.split_at_mut(fill_rows * d);
    let last = &head[(fill_rows - 1) * d..fill_rows * d];
    for r in 0..total_rows - fill_rows {
        tail[r * d..(r + 1) * d].copy_from_slice(last);
    }
}

/// One open (not yet dispatched) packed batch for a profile.
struct PendingBatch {
    profile: usize,
    /// `[profile * d]` candidate buffer; rows `[0, fill)` are real.
    buf: Vec<f32>,
    segments: Vec<Segment>,
    fill: usize,
    deadline: Instant,
}

/// Counters snapshot for reporting (CLI, benches, tests).
#[derive(Clone, Debug, Default)]
pub struct CoalesceStats {
    /// Packed remainder batches dispatched.
    pub batches: u64,
    /// Batches that carried rows from ≥ 2 requests.
    pub multi_request_batches: u64,
    /// Real rows that rode a shared (multi-request) launch.
    pub coalesced_rows: u64,
    /// Mean fill fraction of dispatched batches, percent.
    pub occupancy_mean_pct: f64,
    /// Median fill fraction, percent.
    pub occupancy_p50_pct: u64,
}

/// The coalescer proper: per-profile slots + deadline flusher state.
pub(crate) struct Coalescer {
    /// One open-batch slot per profile (key set fixed at construction).
    slots: BTreeMap<usize, Mutex<Option<PendingBatch>>>,
    /// Flusher parking lot — see module docs for the lock order.
    signal: Mutex<()>,
    cv: Condvar,
    wait: Duration,
    d: usize,
    senders: BTreeMap<usize, Sender<Job>>,
    pool: Arc<BufferPool>,
    shutdown: AtomicBool,
    batches: AtomicU64,
    multi_batches: AtomicU64,
    coalesced_rows: AtomicU64,
    occupancy: Histogram,
    /// The orchestrator's admission counter. Once a segment is accepted
    /// into a batch, its reserved unit is owned by the job lifecycle:
    /// released by the executor after the launch, by
    /// [`Coalescer::evict_cancelled`] when a cancelled rider leaves a
    /// still-open batch, or — if the batch can never reach an executor
    /// — by [`Coalescer::dispatch`]'s failure path, so capacity is
    /// never leaked.
    in_flight: Arc<AtomicUsize>,
    recorder: Option<Arc<Recorder>>,
}

impl Coalescer {
    pub(crate) fn new(
        wait_us: u64,
        d: usize,
        senders: BTreeMap<usize, Sender<Job>>,
        pool: Arc<BufferPool>,
        in_flight: Arc<AtomicUsize>,
        recorder: Option<Arc<Recorder>>,
    ) -> Self {
        Coalescer {
            slots: senders.keys().map(|&m| (m, Mutex::new(None))).collect(),
            signal: Mutex::new(()),
            cv: Condvar::new(),
            wait: Duration::from_micros(wait_us),
            d,
            senders,
            pool,
            shutdown: AtomicBool::new(false),
            batches: AtomicU64::new(0),
            multi_batches: AtomicU64::new(0),
            coalesced_rows: AtomicU64::new(0),
            occupancy: Histogram::new(),
            in_flight,
            recorder,
        }
    }

    /// Add `take` rows (`rows` = `take * d` f32s) of a request's tail
    /// remainder to `profile`'s open batch, opening one if needed and
    /// dispatching any batch this fills (or displaces for lack of room).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn enqueue(
        &self,
        profile: usize,
        hist: &Arc<super::backend::HistHandle>,
        rows: &[f32],
        take: usize,
        chunk_index: usize,
        trace_id: u64,
        cancel: Option<CancelToken>,
        reply: Sender<Result<super::orchestrator::ChunkDone>>,
    ) -> Result<()> {
        debug_assert!(take > 0 && take <= profile);
        debug_assert_eq!(rows.len(), take * self.d);
        let slot = self
            .slots
            .get(&profile)
            .ok_or_else(|| Error::UnknownEngine(format!("no coalesce slot for profile {profile}")))?;
        let mut ready: Vec<PendingBatch> = Vec::new();
        let mut opened = false;
        {
            let mut open = slot.lock().unwrap_or_else(|e| e.into_inner());
            // cancelled riders leave the open batch first — that may
            // free enough room to avoid displacing it
            if let Some(batch) = open.as_mut() {
                self.evict_cancelled(batch);
                if batch.fill == 0 {
                    // every rider left: recycle the buffer; the slot
                    // reopens below with a fresh deadline
                    if let Some(empty) = open.take() {
                        self.pool.put(empty.buf);
                    }
                }
            }
            // no room left for this remainder: close the open batch out
            let displace = open.as_ref().is_some_and(|b| profile - b.fill < take);
            if displace {
                // lint: allow(panic) guarded: displace is only true when open is Some
                ready.push(open.take().unwrap());
            }
            let filled = {
                let batch = open.get_or_insert_with(|| {
                    opened = true;
                    PendingBatch {
                        profile,
                        buf: self.pool.get(profile * self.d),
                        segments: Vec::new(),
                        fill: 0,
                        deadline: Instant::now() + self.wait,
                    }
                });
                batch.buf[batch.fill * self.d..(batch.fill + take) * self.d]
                    .copy_from_slice(rows);
                batch.segments.push(Segment {
                    hist: Arc::clone(hist),
                    rows: take,
                    chunk_index,
                    enqueued: Instant::now(),
                    trace_id,
                    cancel,
                    reply,
                });
                batch.fill += take;
                batch.fill == profile
            };
            if filled {
                // lint: allow(panic) guarded: filled implies the batch was just inserted
                ready.push(open.take().unwrap());
            }
        }
        if opened {
            // a fresh batch sets a new earliest deadline; notify under
            // the signal mutex (never while a slot is held) so the
            // flusher cannot miss it between its scan and its wait
            let _parked = self.signal.lock().unwrap_or_else(|e| e.into_inner());
            self.cv.notify_all();
        }
        for batch in ready {
            self.dispatch(batch);
        }
        Ok(())
    }

    /// Pad, account, and hand a closed batch to its profile's executor
    /// pool. (Executed/padded row totals are accounted by the executor,
    /// which knows the backend's real launch cost.)
    ///
    /// Infallible from the caller's view: a batch that cannot reach an
    /// executor (pool closed — the process is shutting down or broken)
    /// releases its segments' admission units and drops the job, whose
    /// broken reply channels surface as errors to the waiting submits.
    /// Remove every segment whose cancel token has fired from a
    /// still-open batch: later rows shift down to close the gap (so the
    /// batch re-pads from its new fill), the rider's reply resolves with
    /// a typed [`Error::Cancelled`] at the coalescer stage, and its
    /// admission unit is released here — no executor will ever own it.
    /// Callers hold the batch exclusively (slot lock, or taken out).
    fn evict_cancelled(&self, batch: &mut PendingBatch) {
        let mut off = 0usize;
        let mut i = 0usize;
        while i < batch.segments.len() {
            let rows = batch.segments[i].rows;
            let fired = batch.segments[i].cancel.as_ref().and_then(|t| t.poll());
            match fired {
                Some(cause) => {
                    let seg = batch.segments.remove(i);
                    // shift the rows above the evicted span down
                    let start = off * self.d;
                    let end = batch.fill * self.d;
                    batch.buf.copy_within(start + rows * self.d..end, start);
                    batch.fill -= rows;
                    let _ = seg
                        .reply
                        .send(Err(Error::Cancelled(cause, CancelStage::Coalescer)));
                    self.in_flight.fetch_sub(1, Ordering::AcqRel);
                }
                None => {
                    off += rows;
                    i += 1;
                }
            }
        }
    }

    fn dispatch(&self, mut batch: PendingBatch) {
        // last-chance purge: riders cancelled while the batch waited
        // out its deadline leave now, so the launch only carries rows
        // somebody still wants — an emptied batch never launches
        self.evict_cancelled(&mut batch);
        if batch.fill == 0 {
            self.pool.put(batch.buf);
            return;
        }
        let profile = batch.profile;
        if batch.fill < profile {
            pad_with_last_row(&mut batch.buf, batch.fill, profile, self.d);
        }
        // derive the telemetry once; the recorder mirror receives the
        // derived values so the two sinks can never disagree
        let occ_pct = (batch.fill * 100 / profile.max(1)) as u64;
        let shared_rows = if batch.segments.len() >= 2 { batch.fill as u64 } else { 0 };
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.occupancy.record(occ_pct);
        if shared_rows > 0 {
            self.multi_batches.fetch_add(1, Ordering::Relaxed);
            self.coalesced_rows.fetch_add(shared_rows, Ordering::Relaxed);
        }
        if let Some(rec) = &self.recorder {
            rec.record_coalesce_batch(occ_pct, shared_rows);
        }
        let undeliverable = match self.senders.get(&profile) {
            Some(tx) => match tx.send(Job { cands: batch.buf, segments: batch.segments }) {
                Ok(()) => return,
                Err(send_err) => send_err.0.segments.len(),
            },
            // unreachable: slots and senders share one key set
            None => batch.segments.len(),
        };
        self.in_flight.fetch_sub(undeliverable, Ordering::AcqRel);
        log::warn!(
            "coalesced batch for profile {profile} undeliverable (pool closed); \
             released {undeliverable} admission units"
        );
    }

    /// Deadline watcher: dispatches batches whose wait expired; parked
    /// on the condvar otherwise. Runs on a dedicated thread until
    /// [`Coalescer::begin_shutdown`].
    pub(crate) fn run_flusher(&self) {
        let mut parked = self.signal.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if self.shutdown.load(Ordering::Acquire) {
                drop(parked);
                for slot in self.slots.values() {
                    let leftover = slot.lock().unwrap_or_else(|e| e.into_inner()).take();
                    if let Some(batch) = leftover {
                        self.dispatch(batch);
                    }
                }
                return;
            }
            // scan for the earliest open deadline, collecting expired
            // batches (slot locks are taken briefly, one at a time,
            // while holding `signal` — enqueue never holds a slot while
            // taking `signal`, so the orders cannot deadlock)
            let now = Instant::now();
            let mut next: Option<Instant> = None;
            let mut expired: Vec<PendingBatch> = Vec::new();
            for slot in self.slots.values() {
                let mut open = slot.lock().unwrap_or_else(|e| e.into_inner());
                let deadline = open.as_ref().map(|b| b.deadline);
                match deadline {
                    Some(dl) if dl <= now => {
                        // lint: allow(panic) guarded: the Some(dl) arm proves open is Some
                        expired.push(open.take().unwrap());
                    }
                    Some(dl) => {
                        next = Some(next.map_or(dl, |n| n.min(dl)));
                    }
                    None => {}
                }
            }
            if !expired.is_empty() {
                drop(parked);
                for batch in expired {
                    self.dispatch(batch);
                }
                parked = self.signal.lock().unwrap_or_else(|e| e.into_inner());
                continue;
            }
            parked = match next {
                None => self.cv.wait(parked).unwrap_or_else(|e| e.into_inner()),
                Some(deadline) => {
                    self.cv
                        .wait_timeout(parked, deadline.saturating_duration_since(now))
                        .unwrap_or_else(|e| e.into_inner())
                        .0
                }
            };
        }
    }

    /// Stop the flusher (it drains open batches on the way out). Notifies
    /// under the signal mutex so the wakeup cannot be lost between the
    /// flusher's shutdown check and its condvar wait.
    pub(crate) fn begin_shutdown(&self) {
        let _parked = self.signal.lock().unwrap_or_else(|e| e.into_inner());
        self.shutdown.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    pub(crate) fn stats(&self) -> CoalesceStats {
        CoalesceStats {
            batches: self.batches.load(Ordering::Relaxed),
            multi_request_batches: self.multi_batches.load(Ordering::Relaxed),
            coalesced_rows: self.coalesced_rows.load(Ordering::Relaxed),
            occupancy_mean_pct: self.occupancy.mean(),
            occupancy_p50_pct: self.occupancy.p50(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_pool_reuses_exact_sizes() {
        let pool = BufferPool::new(4);
        let a = pool.get(16);
        assert_eq!(a.len(), 16);
        let ptr = a.as_ptr();
        pool.put(a);
        let b = pool.get(16);
        assert_eq!(b.as_ptr(), ptr, "same-size request must reuse the pooled buffer");
        assert_eq!(pool.get(32).len(), 32, "other sizes allocate fresh");
    }

    #[test]
    fn buffer_pool_bounds_shelf_depth() {
        let pool = BufferPool::new(2);
        for _ in 0..5 {
            pool.put(vec![0.0; 8]);
        }
        let shelved = pool.shelves.lock().unwrap().get(&8).map(|s| s.len());
        assert_eq!(shelved, Some(2), "shelf must stay bounded");
    }

    #[test]
    fn buffer_pool_ignores_empty() {
        let pool = BufferPool::new(2);
        pool.put(Vec::new());
        assert!(pool.shelves.lock().unwrap().is_empty());
    }

    #[test]
    fn pad_fills_tail_with_last_real_row() {
        // 2 real rows of width 3, padded to 4 rows
        let mut buf = vec![1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        pad_with_last_row(&mut buf, 2, 4, 3);
        assert_eq!(&buf[6..9], &[2.0, 2.0, 2.0]);
        assert_eq!(&buf[9..12], &[2.0, 2.0, 2.0]);
        // real rows untouched
        assert_eq!(&buf[..6], &[1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn pad_noop_when_full() {
        let mut buf = vec![3.0; 6];
        pad_with_last_row(&mut buf, 2, 2, 3);
        assert_eq!(buf, vec![3.0; 6]);
    }
}
