//! Native CPU Fused Kernel Engine — the Table-4 ablation ladder as a
//! real, multithreaded compute backend (paper §3.2).
//!
//! Until this module existed the repo's only executable engine hid
//! behind the offline `xla` vendor stub; the FKE was an analytic
//! registry. [`CpuEngine`] turns the ladder into running FLOPs on any
//! bare checkout, one [`Variant`] per engine-construction level:
//!
//! * **naive** — "ONNX Model Conversion": straightforward per-op loops
//!   and materialized intermediates. Separate Q/K/V GEMMs, a
//!   materialized `[n, n]` additive mask-bias tensor, a materialized
//!   per-head score matrix, fresh buffers per op, and textbook `ijk`
//!   GEMM loops whose inner contraction strides the weight matrix by
//!   its row width (cache-hostile, scalar).
//! * **api** — "TensorRT API Impl.": a deliberately constructed graph.
//!   One fused QKV GEMM, cache-blocked `ikj` GEMM loops (unit-stride
//!   inner loops the compiler can vectorize without reassociating),
//!   per-thread scratch rows, a transposed key panel per layer, and no
//!   `[n, n]` score materialization — attention streams one query row
//!   at a time. FFN/head stages reuse arena buffers instead of
//!   allocating per op.
//! * **fused** — api + kernel fusion: the mask-aware attention tile
//!   schedule (same block choice and visit rule as
//!   [`super::attention_tile_stats`]) skips fully-masked tiles instead
//!   of computing-then-masking; the pre-LN FFN runs as fused per-row
//!   tiles (no `[n, d_ff]` activation panel, mirroring the
//!   `ffn_vmem_bytes` blocking); and the gating + expert head fuses
//!   score and reduce into one pass per candidate row.
//!
//! **Score identity.** All three variants execute the same math in the
//! same per-element accumulation order (ascending contraction index,
//! bias added after the sum, shared LayerNorm/GELU/softmax helpers), so
//! their scores agree bit-for-bit up to `±0.0` — skipped masked keys
//! contribute exact zeros in the dense variants (`exp(-1e9 - max)`
//! underflows to `+0.0`). The cross-variant identity suite asserts
//! `fused == api` exactly and `api` within 1e-5 of `naive` (insurance
//! against benign reassociation; see `tests/fke_cpu.rs`).
//!
//! **Native segmentation.** [`ComputeBackend::run_segmented`] binds one
//! history *per row segment inside a single launch*: a coalescer-packed
//! mixed batch of M rows from S requests executes M candidate rows once
//! (plus one history prefill per segment — the same prefill S solo
//! launches would pay), so `executed_rows_for(S) == M` and the
//! orchestrator's waste accounting finally reflects real savings. The
//! PJRT engine, by contrast, emulates mixed batches by replaying the
//! launch per segment (`M * S` rows). Because every candidate row
//! attends only to its own segment's history plus itself, packed scores
//! are bit-identical to solo launches under any packing (property-tested
//! in `tests/fke_cpu.rs`).
//!
//! The model is the rust mirror of `python/compile/model.py`'s
//! Climber-like GR forward: per block, pre-LN transformer layers over
//! `[hist_block; candidates]` with the SUMI mask (history causal;
//! candidates see all history plus themselves only), then bit-wise
//! gating fusion across blocks and the expert MLP → sigmoid task heads.
//! Weights are seeded in-process (`CpuModel`) — no artifacts, no
//! Python, no PJRT.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::ModelConfig;
use crate::dso::backend::{check_segments, ComputeBackend, HistHandle, KernelStats, SegmentBind};
use crate::error::{Error, Result};
use crate::metrics::Recorder;
use crate::util::rng::Rng;

use super::{choose_block, Variant};

/// Additive mask bias (mirror of `kernels/ref.py::NEG_BIAS`); large
/// enough that `exp(s + NEG_BIAS - max)` underflows to exactly `+0.0`.
const NEG_BIAS: f32 = -1e9;

/// Gating fusion runs over at most this many blocks (stack-allocated
/// per-row gate buffer; every scenario uses 2).
const MAX_BLOCKS: usize = 8;

// ---------------------------------------------------------------------------
// shared elementwise math (one implementation for all variants, so the
// ladder can never diverge on transcendental rounding)
// ---------------------------------------------------------------------------

/// erf via Abramowitz–Stegun 7.1.26 (|error| ≤ 1.5e-7) — the offline
/// toolchain has no libm erf.
#[inline]
fn erf(x: f32) -> f32 {
    let sign = if x < 0.0 { -1.0f32 } else { 1.0f32 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly =
        ((((1.061_405_429 * t - 1.453_152_027) * t + 1.421_413_741) * t - 0.284_496_736) * t
            + 0.254_829_592)
            * t;
    sign * (1.0 - poly * (-x * x).exp())
}

/// Exact (erf-form) GELU, matching `jax.nn.gelu(approximate=False)`.
#[inline]
fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + erf(x * std::f32::consts::FRAC_1_SQRT_2))
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// LayerNorm one row (eps 1e-6, mirror of `ref.layernorm`).
#[inline]
fn ln_row(x: &[f32], scale: &[f32], bias: &[f32], out: &mut [f32]) {
    let d = x.len();
    let mut mean = 0.0f32;
    for &v in x {
        mean += v;
    }
    mean /= d as f32;
    let mut var = 0.0f32;
    for &v in x {
        let c = v - mean;
        var += c * c;
    }
    var /= d as f32;
    let inv = 1.0 / (var + 1e-6).sqrt();
    for i in 0..d {
        out[i] = (x[i] - mean) * inv * scale[i] + bias[i];
    }
}

/// `out = a @ w + bias` for one row: `a` is `[k]`, `w` row-major
/// `[k, n]`, `out`/`bias` `[n]`. `ikj` form — the inner loop is
/// unit-stride over both `w`'s row and `out`, so it vectorizes without
/// float reassociation, and the per-element accumulation order
/// (ascending `k`, bias added after the sum) is identical to the naive
/// `ijk` dot product — bit-for-bit.
#[inline]
fn matvec_row(a: &[f32], w: &[f32], bias: &[f32], out: &mut [f32]) {
    let n = out.len();
    out.iter_mut().for_each(|o| *o = 0.0);
    for (kk, &av) in a.iter().enumerate() {
        let wrow = &w[kk * n..(kk + 1) * n];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += av * wv;
        }
    }
    for (o, &bv) in out.iter_mut().zip(bias) {
        *o += bv;
    }
}

/// Textbook naive GEMM, the ONNX-export loop order: `out[i][j] =
/// Σ_k a[i][k] * w[k*stride + off + j] + bias[off + j]`. The inner `k`
/// contraction strides `w` by its full row width — cache-hostile and a
/// scalar reduction chain the compiler cannot vectorize — but the
/// per-element accumulation order (ascending `k`, bias last) is
/// identical to [`matvec_row`], so the naive variant stays numerically
/// aligned with the deliberate graphs.
#[allow(clippy::too_many_arguments)]
fn gemm_naive(
    threads: usize,
    a: &[f32],
    k: usize,
    w: &[f32],
    stride: usize,
    off: usize,
    bias: &[f32],
    out: &mut [f32],
    ncols: usize,
) {
    par_rows(threads, out, ncols, |i, out_row| {
        for (j, o) in out_row.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a[i * k + kk] * w[kk * stride + off + j];
            }
            *o = acc + bias[off + j];
        }
    });
}

/// SUMI visibility (mirror of `ref.sumi_mask`): token `i` may attend
/// `j` in a `[hist(lb); cands]` sequence.
#[inline]
fn visible(i: usize, j: usize, lb: usize) -> bool {
    if i < lb {
        j <= i
    } else {
        j < lb || j == i
    }
}

/// Bit-wise gating fusion + normalization for one candidate row
/// (mirror of `model_ref`'s head tail before the expert MLP). Shared by
/// all variants.
#[inline]
fn gate_fuse_row(nb: usize, d: usize, logits: &[f32], block_rows: &[&[f32]], out: &mut [f32]) {
    debug_assert!(nb <= MAX_BLOCKS);
    let mut e = [0.0f32; MAX_BLOCKS];
    for d2 in 0..d {
        let mut mx = f32::NEG_INFINITY;
        for b in 0..nb {
            let l = logits[b * d + d2];
            if l > mx {
                mx = l;
            }
        }
        let mut denom = 0.0f32;
        for b in 0..nb {
            let ev = (logits[b * d + d2] - mx).exp();
            e[b] = ev;
            denom += ev;
        }
        let mut acc = 0.0f32;
        for b in 0..nb {
            acc += (e[b] / denom) * block_rows[b][d2];
        }
        out[d2] = acc;
    }
}

// ---------------------------------------------------------------------------
// row-parallel execution helper
// ---------------------------------------------------------------------------

/// Run `f(row_index, row)` over every `row_len`-wide row of `buf`,
/// partitioned into contiguous chunks across up to `threads` scoped
/// worker threads. `mk` builds one scratch value per worker. Rows are
/// computed independently with identical per-row op order, so the
/// thread count never changes a single output bit.
fn par_rows_scratch<S, MK, F>(threads: usize, buf: &mut [f32], row_len: usize, mk: MK, f: F)
where
    MK: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &mut [f32]) + Sync,
{
    debug_assert!(row_len > 0 && buf.len() % row_len == 0);
    let rows = buf.len() / row_len;
    if rows == 0 {
        return;
    }
    if threads <= 1 || rows == 1 {
        let mut s = mk();
        for (i, r) in buf.chunks_mut(row_len).enumerate() {
            f(&mut s, i, r);
        }
        return;
    }
    let chunk_rows = rows.div_ceil(threads.min(rows));
    std::thread::scope(|scope| {
        for (ci, chunk) in buf.chunks_mut(chunk_rows * row_len).enumerate() {
            let f = &f;
            let mk = &mk;
            scope.spawn(move || {
                let mut s = mk();
                for (ri, r) in chunk.chunks_mut(row_len).enumerate() {
                    f(&mut s, ci * chunk_rows + ri, r);
                }
            });
        }
    });
}

fn par_rows<F>(threads: usize, buf: &mut [f32], row_len: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    par_rows_scratch(threads, buf, row_len, || (), |_, i, r| f(i, r));
}

// ---------------------------------------------------------------------------
// weights
// ---------------------------------------------------------------------------

struct LayerWeights {
    qkv_w: Vec<f32>, // [D, 3D]
    qkv_b: Vec<f32>, // [3D]
    out_w: Vec<f32>, // [D, D]
    out_b: Vec<f32>, // [D]
    ln1_s: Vec<f32>, // [D]
    ln1_b: Vec<f32>, // [D]
    ln2_s: Vec<f32>, // [D]
    ln2_b: Vec<f32>, // [D]
    ffn_w1: Vec<f32>, // [D, F]
    ffn_b1: Vec<f32>, // [F]
    ffn_w2: Vec<f32>, // [F, D]
    ffn_b2: Vec<f32>, // [D]
    temp: f32,
}

/// Seeded in-process weight set for one scenario, shared (`Arc`) across
/// the scenario's per-profile [`CpuEngine`]s and across variants — the
/// analogue of TensorRT engines sharing device weight memory. Matmul
/// weights ~ N(0, 1/sqrt(fan_in)), biases zero, LN scales one, adaptive
/// temperatures near one (same init family as `python/compile/params.py`,
/// different RNG — bit parity with the JAX weights is a non-goal).
pub struct CpuModel {
    pub cfg: ModelConfig,
    /// Transformer layers executed per block. Benches cap this below
    /// `cfg.layers_per_block` to bound absolute launch cost: every layer
    /// is identical work, so the naive/api/fused *ratios* — the thing
    /// Table 4 measures — are depth-invariant.
    pub depth: usize,
    pub seed: u64,
    blocks: Vec<Vec<LayerWeights>>,
    gate_w: Vec<f32>, // [nb*D, nb*D]
    gate_b: Vec<f32>, // [nb*D]
    exp_w1: Vec<f32>, // [D, F]
    exp_b1: Vec<f32>, // [F]
    exp_w2: Vec<f32>, // [F, T]
    exp_b2: Vec<f32>, // [T]
}

impl CpuModel {
    /// Full-depth model (`cfg.layers_per_block` layers per block).
    pub fn new(cfg: &ModelConfig, seed: u64) -> Result<Arc<CpuModel>> {
        Self::with_depth(cfg, seed, cfg.layers_per_block)
    }

    /// Model with an explicit per-block layer count (see [`CpuModel::depth`]).
    pub fn with_depth(cfg: &ModelConfig, seed: u64, depth: usize) -> Result<Arc<CpuModel>> {
        cfg.validate()?;
        if depth == 0 {
            return Err(Error::Config("cpu model needs depth >= 1".into()));
        }
        if cfg.n_blocks > MAX_BLOCKS {
            return Err(Error::Config(format!(
                "cpu model supports at most {MAX_BLOCKS} blocks (got {})",
                cfg.n_blocks
            )));
        }
        let (d, f) = (cfg.d_model, cfg.d_ff());
        let mut rng = Rng::new(seed);
        fn draw(rng: &mut Rng, fan_in: usize, len: usize) -> Vec<f32> {
            let inv = 1.0 / (fan_in as f32).sqrt();
            (0..len).map(|_| rng.normal_f32() * inv).collect()
        }
        let mut blocks = Vec::with_capacity(cfg.n_blocks);
        for _ in 0..cfg.n_blocks {
            let mut layers = Vec::with_capacity(depth);
            for _ in 0..depth {
                layers.push(LayerWeights {
                    qkv_w: draw(&mut rng, d, d * 3 * d),
                    qkv_b: vec![0.0; 3 * d],
                    out_w: draw(&mut rng, d, d * d),
                    out_b: vec![0.0; d],
                    ln1_s: vec![1.0; d],
                    ln1_b: vec![0.0; d],
                    ln2_s: vec![1.0; d],
                    ln2_b: vec![0.0; d],
                    ffn_w1: draw(&mut rng, d, d * f),
                    ffn_b1: vec![0.0; f],
                    ffn_w2: draw(&mut rng, f, f * d),
                    ffn_b2: vec![0.0; d],
                    temp: 1.0 + 0.05 * rng.normal_f32(),
                });
            }
            blocks.push(layers);
        }
        let nbd = cfg.n_blocks * d;
        Ok(Arc::new(CpuModel {
            cfg: cfg.clone(),
            depth,
            seed,
            gate_w: draw(&mut rng, nbd, nbd * nbd),
            gate_b: vec![0.0; nbd],
            exp_w1: draw(&mut rng, d, d * f),
            exp_b1: vec![0.0; f],
            exp_w2: draw(&mut rng, f, f * cfg.n_tasks),
            exp_b2: vec![0.0; cfg.n_tasks],
            blocks,
        }))
    }

    /// Stable per-scenario weight seed (hash of the scenario name), so
    /// `flame serve --backend cpu` scores are reproducible across runs
    /// and replicas without artifacts.
    pub fn seed_for(scenario: &str) -> u64 {
        let mut s = 0x46_4B_45_u64; // "FKE"
        for &b in scenario.as_bytes() {
            s = crate::util::rng::splitmix64(&mut s) ^ (b as u64);
        }
        crate::util::rng::splitmix64(&mut s)
    }
}

// ---------------------------------------------------------------------------
// attention tile schedule (fused variant)
// ---------------------------------------------------------------------------

/// Visited k-tile ranges per q-tile for one `[hist(lb); cands]`
/// sequence — the execution-side twin of
/// [`super::attention_tile_stats`]'s visit rule, generalized to
/// non-divisible shapes (packed segments have arbitrary row counts).
struct TilePlan {
    tile: usize,
    /// Per q-tile: merged, ascending `[j0, j1)` key ranges to compute.
    visit: Vec<Vec<(usize, usize)>>,
    visited: u64,
    skipped: u64,
}

impl TilePlan {
    fn build(lb: usize, n: usize, tile: usize) -> TilePlan {
        let nq = n.div_ceil(tile);
        let mut visit = Vec::with_capacity(nq);
        let (mut visited, mut skipped) = (0u64, 0u64);
        for qt in 0..nq {
            let q0 = qt * tile;
            let q1 = (q0 + tile).min(n) - 1; // inclusive
            let mut ranges: Vec<(usize, usize)> = Vec::new();
            for kt in 0..nq {
                let k0 = kt * tile;
                let k1 = (k0 + tile).min(n) - 1; // inclusive
                // history keys: candidates see all of them; history rows
                // see them causally (some i in the tile with j0 <= i)
                let hist_leg = k0 < lb && (q1 >= lb || k0 <= q1.min(lb - 1));
                // candidate keys: visible only on the self diagonal
                let diag_leg = q0.max(k0).max(lb) <= q1.min(k1);
                if hist_leg || diag_leg {
                    visited += 1;
                    match ranges.last_mut() {
                        Some(last) if last.1 == k0 => last.1 = k1 + 1,
                        _ => ranges.push((k0, k1 + 1)),
                    }
                } else {
                    skipped += 1;
                }
            }
            visit.push(ranges);
        }
        TilePlan { tile, visit, visited, skipped }
    }
}

// ---------------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------------

/// Construction knobs for one [`CpuEngine`].
#[derive(Clone, Debug)]
pub struct CpuEngineConfig {
    pub variant: Variant,
    /// Worker threads per launch; 0 = auto (available parallelism,
    /// capped at 8). Thread count never changes output bits.
    pub threads: usize,
}

impl Default for CpuEngineConfig {
    fn default() -> Self {
        CpuEngineConfig { variant: Variant::Fused, threads: 0 }
    }
}

/// Reusable per-launch scratch arenas (api/fused variants). Sized once
/// for the engine's largest sequence; reallocation-free across layers.
struct FastScratch {
    /// `[n, 3D]` fused QKV panel.
    qkv: Vec<f32>,
    /// `[D, n]` transposed key panel.
    kt: Vec<f32>,
    /// `[n, F]` activation panel / head stages 1 and 3.
    a: Vec<f32>,
    /// `[n, D]` LN panel / head stages 2 and 4.
    b: Vec<f32>,
}

/// A native CPU scoring engine with a fixed candidate profile `m`,
/// implementing the row-segmented [`ComputeBackend`] contract.
pub struct CpuEngine {
    model: Arc<CpuModel>,
    m: usize,
    variant: Variant,
    threads: usize,
    launches: AtomicU64,
    flops: AtomicU64,
    tiles_visited: AtomicU64,
    tiles_skipped: AtomicU64,
    recorder: Option<Arc<Recorder>>,
}

impl CpuEngine {
    pub fn new(model: Arc<CpuModel>, m: usize, cfg: &CpuEngineConfig) -> CpuEngine {
        let threads = if cfg.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(8)
        } else {
            cfg.threads
        };
        CpuEngine {
            model,
            m,
            variant: cfg.variant,
            threads,
            launches: AtomicU64::new(0),
            flops: AtomicU64::new(0),
            tiles_visited: AtomicU64::new(0),
            tiles_skipped: AtomicU64::new(0),
            recorder: None,
        }
    }

    /// Mirror per-launch FLOP/tile counters into the serving stack's
    /// recorder (in addition to the engine's own cumulative stats).
    pub fn with_recorder(mut self, recorder: Arc<Recorder>) -> Self {
        self.recorder = Some(recorder);
        self
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    pub fn model(&self) -> &Arc<CpuModel> {
        &self.model
    }

    /// One engine per profile in `model.cfg.m_profiles`, type-erased for
    /// the orchestrator / `StackBuilder::build_from_backends`.
    pub fn profile_set(
        model: &Arc<CpuModel>,
        ecfg: &CpuEngineConfig,
        recorder: Option<Arc<Recorder>>,
    ) -> Vec<Arc<dyn ComputeBackend>> {
        model
            .cfg
            .m_profiles
            .iter()
            .map(|&m| {
                let mut e = CpuEngine::new(Arc::clone(model), m, ecfg);
                if let Some(rec) = &recorder {
                    e = e.with_recorder(Arc::clone(rec));
                }
                Arc::new(e) as Arc<dyn ComputeBackend>
            })
            .collect()
    }

    /// Convenience: upload + single-segment launch (benches, examples).
    pub fn run(&self, hist: &[f32], cands: &[f32]) -> Result<Vec<f32>> {
        let h = self.upload_hist(hist)?;
        self.run_segmented(&[SegmentBind { hist: &h, rows: self.m }], cands)
    }

    /// The fused variant's attention tile edge (q and k tile width).
    pub fn tile(&self) -> usize {
        choose_block(self.model.cfg.block_len(), self.m, 128)
    }

    // -- forward pass -------------------------------------------------------

    /// Score `mr` candidate rows against one history. `out` is
    /// `[mr * n_tasks]`.
    fn forward_segment(
        &self,
        hist: &[f32],
        cands: &[f32],
        mr: usize,
        out: &mut [f32],
        sc: &mut Option<FastScratch>,
        launch: &mut KernelStats,
    ) {
        let cfg = &self.model.cfg;
        let (d, lb, nb) = (cfg.d_model, cfg.block_len(), cfg.n_blocks);
        let n = lb + mr;
        let fused = self.variant == Variant::Fused;
        let plan = fused.then(|| TilePlan::build(lb, n, self.tile()));

        let mut x = vec![0.0f32; n * d];
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(nb);
        let bias = (self.variant == Variant::Naive).then(|| {
            let mut bias = vec![0.0f32; n * n];
            for i in 0..n {
                for j in 0..n {
                    if !visible(i, j, lb) {
                        bias[i * n + j] = NEG_BIAS;
                    }
                }
            }
            bias
        });

        for b in 0..nb {
            x[..lb * d].copy_from_slice(&hist[b * lb * d..(b + 1) * lb * d]);
            x[lb * d..].copy_from_slice(cands);
            for lw in &self.model.blocks[b] {
                match self.variant {
                    // lint: allow(panic) bias is Some for every Naive launch (checked at plan time)
                    Variant::Naive => self.layer_naive(&mut x, n, lw, bias.as_deref().unwrap()),
                    // plan is Some only for the fused variant, so one
                    // call covers both deliberate graphs
                    Variant::Api | Variant::Fused => {
                        // lint: allow(panic) scratch is Some for every Fast launch (checked at plan time)
                        self.layer_fast(&mut x, n, lb, lw, sc.as_mut().unwrap(), plan.as_ref())
                    }
                }
            }
            outs.push(x[lb * d..].to_vec());
        }

        match self.variant {
            Variant::Naive => self.head_naive(&outs, mr, out),
            // lint: allow(panic) scratch is Some for the Api head (checked at plan time)
            Variant::Api => self.head_api(&outs, mr, out, sc.as_mut().unwrap()),
            Variant::Fused => self.head_fused(&outs, mr, out),
        }

        // analytic accounting (GEMM-dominated). The fused variant counts
        // the attention work its schedule actually executes: the score
        // pass costs every key inside a *visited tile* (tile-granular —
        // a diagonal tile scores `tile` keys for 1 visible one), the
        // weighted-V pass only the visible pairs.
        let layers = (nb * self.model.depth) as u64;
        let (du, fu, tu, nu) = (d as u64, cfg.d_ff() as u64, cfg.n_tasks as u64, n as u64);
        let (score_pairs, av_pairs) = match &plan {
            Some(p) => {
                let mut scored = 0u64;
                for (qt, ranges) in p.visit.iter().enumerate() {
                    let q0 = qt * p.tile;
                    let qrows = ((q0 + p.tile).min(n) - q0) as u64;
                    let keys: u64 = ranges.iter().map(|&(j0, j1)| (j1 - j0) as u64).sum();
                    scored += qrows * keys;
                }
                let visible = (lb * (lb + 1) / 2 + mr * (lb + 1)) as u64;
                (scored, visible)
            }
            None => (nu * nu, nu * nu),
        };
        let qkv_flops = 2 * nu * du * 3 * du; // fused QKV (or Q+K+V) GEMM
        let attn_flops = 2 * score_pairs * du + 2 * av_pairs * du;
        let proj_flops = 2 * nu * du * du; // output projection
        let ffn_flops = 4 * nu * du * fu; // FFN up + down
        let nbdu = nb as u64 * du;
        let head = mr as u64 * (2 * nbdu * nbdu + 2 * du * fu + 2 * fu * tu);
        launch.flops += layers * (qkv_flops + attn_flops + proj_flops + ffn_flops) + head;
        match &plan {
            Some(p) => {
                launch.tiles_visited += layers * p.visited;
                launch.tiles_skipped += layers * p.skipped;
            }
            None => {
                let nq = n.div_ceil(self.tile()) as u64;
                launch.tiles_visited += layers * nq * nq;
            }
        }
    }

    /// One pre-LN transformer layer, deliberate-graph form (api/fused).
    fn layer_fast(
        &self,
        x: &mut [f32],
        n: usize,
        lb: usize,
        lw: &LayerWeights,
        sc: &mut FastScratch,
        plan: Option<&TilePlan>,
    ) {
        let cfg = &self.model.cfg;
        let (d, f, nh) = (cfg.d_model, cfg.d_ff(), cfg.n_heads);
        let hd = d / nh;
        let d3 = 3 * d;
        let threads = self.threads;

        // phase A — fused LN1 + QKV GEMM, one pass per row
        {
            let qkv = &mut sc.qkv[..n * d3];
            let xr: &[f32] = x;
            par_rows_scratch(
                threads,
                qkv,
                d3,
                || vec![0.0f32; d],
                |lnr, i, qkv_row| {
                    ln_row(&xr[i * d..(i + 1) * d], &lw.ln1_s, &lw.ln1_b, lnr);
                    matvec_row(lnr, &lw.qkv_w, &lw.qkv_b, qkv_row);
                },
            );
        }

        // phase B — transposed key panel [D, n] (unit-stride score loops)
        {
            let qkv: &[f32] = &sc.qkv[..n * d3];
            let kt = &mut sc.kt[..d * n];
            par_rows(threads, kt, n, |c, ktrow| {
                for (j, kv) in ktrow.iter_mut().enumerate() {
                    *kv = qkv[j * d3 + d + c];
                }
            });
        }

        // phase C — attention (streamed per query row, no [n, n] buffer)
        // + output projection + residual
        {
            let qkv: &[f32] = &sc.qkv[..n * d3];
            let kt: &[f32] = &sc.kt[..d * n];
            let scale = lw.temp / (hd as f32).sqrt();
            par_rows_scratch(
                threads,
                &mut x[..n * d],
                d,
                || (vec![0.0f32; n], vec![0.0f32; d], vec![0.0f32; d]),
                |(srow, attn, proj), i, x_row| {
                    attn.iter_mut().for_each(|v| *v = 0.0);
                    for h in 0..nh {
                        let ho = h * hd;
                        let q = &qkv[i * d3 + ho..i * d3 + ho + hd];
                        match plan {
                            None => {
                                // dense: all keys, additive bias on masked
                                srow.iter_mut().for_each(|v| *v = 0.0);
                                for (kk, &qk) in q.iter().enumerate() {
                                    let ktrow = &kt[(ho + kk) * n..(ho + kk + 1) * n];
                                    for (sj, &kv) in srow.iter_mut().zip(ktrow) {
                                        *sj += qk * kv;
                                    }
                                }
                                let mut mx = f32::NEG_INFINITY;
                                for (j, sj) in srow.iter_mut().enumerate() {
                                    let mut sv = *sj * scale;
                                    if !visible(i, j, lb) {
                                        sv += NEG_BIAS;
                                    }
                                    *sj = sv;
                                    if sv > mx {
                                        mx = sv;
                                    }
                                }
                                let mut denom = 0.0f32;
                                for sj in srow.iter_mut() {
                                    let e = (*sj - mx).exp();
                                    *sj = e;
                                    denom += e;
                                }
                                for sj in srow.iter_mut() {
                                    *sj /= denom;
                                }
                                let out_h = &mut attn[ho..ho + hd];
                                for (j, &p) in srow.iter().enumerate() {
                                    let vrow =
                                        &qkv[j * d3 + 2 * d + ho..j * d3 + 2 * d + ho + hd];
                                    for (o, &vv) in out_h.iter_mut().zip(vrow) {
                                        *o += p * vv;
                                    }
                                }
                            }
                            Some(plan) => {
                                // mask-aware: only visited tiles touched;
                                // masked keys inside a visited tile are
                                // dropped at softmax (their dense-path
                                // contribution is an exact +0.0, so the
                                // bits match the api variant)
                                let ranges = &plan.visit[i / plan.tile];
                                for &(j0, j1) in ranges {
                                    srow[j0..j1].iter_mut().for_each(|v| *v = 0.0);
                                }
                                for (kk, &qk) in q.iter().enumerate() {
                                    let ktrow = &kt[(ho + kk) * n..(ho + kk + 1) * n];
                                    for &(j0, j1) in ranges {
                                        for (sj, &kv) in
                                            srow[j0..j1].iter_mut().zip(&ktrow[j0..j1])
                                        {
                                            *sj += qk * kv;
                                        }
                                    }
                                }
                                let mut mx = f32::NEG_INFINITY;
                                for &(j0, j1) in ranges {
                                    for j in j0..j1 {
                                        if visible(i, j, lb) {
                                            let sv = srow[j] * scale;
                                            srow[j] = sv;
                                            if sv > mx {
                                                mx = sv;
                                            }
                                        }
                                    }
                                }
                                let mut denom = 0.0f32;
                                for &(j0, j1) in ranges {
                                    for j in j0..j1 {
                                        if visible(i, j, lb) {
                                            let e = (srow[j] - mx).exp();
                                            srow[j] = e;
                                            denom += e;
                                        }
                                    }
                                }
                                let out_h = &mut attn[ho..ho + hd];
                                for &(j0, j1) in ranges {
                                    for j in j0..j1 {
                                        if visible(i, j, lb) {
                                            let p = srow[j] / denom;
                                            let vrow = &qkv
                                                [j * d3 + 2 * d + ho..j * d3 + 2 * d + ho + hd];
                                            for (o, &vv) in out_h.iter_mut().zip(vrow) {
                                                *o += p * vv;
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                    matvec_row(attn, &lw.out_w, &lw.out_b, proj);
                    for (xv, &pv) in x_row.iter_mut().zip(proj.iter()) {
                        *xv += pv;
                    }
                },
            );
        }

        // phase D — pre-LN FFN + residual
        if plan.is_some() {
            // fused: LN2 → up-proj → GELU → down-proj → residual in one
            // pass per row tile; no [n, F] activation panel exists
            par_rows_scratch(
                threads,
                &mut x[..n * d],
                d,
                || (vec![0.0f32; d], vec![0.0f32; f]),
                |(lnr, act), _i, x_row| {
                    ln_row(x_row, &lw.ln2_s, &lw.ln2_b, lnr);
                    matvec_row(lnr, &lw.ffn_w1, &lw.ffn_b1, act);
                    act.iter_mut().for_each(|v| *v = gelu(*v));
                    matvec_row(act, &lw.ffn_w2, &lw.ffn_b2, lnr); // lnr = delta
                    for (xv, &dv) in x_row.iter_mut().zip(lnr.iter()) {
                        *xv += dv;
                    }
                },
            );
        } else {
            // api: staged through the scratch arenas (LN panel + [n, F]
            // activation panel), fast GEMM loops — deliberate graph,
            // no per-op allocation, but the panels are real traffic
            let FastScratch { a, b, .. } = sc;
            {
                let xr: &[f32] = x;
                par_rows(threads, &mut b[..n * d], d, |i, lnr| {
                    ln_row(&xr[i * d..(i + 1) * d], &lw.ln2_s, &lw.ln2_b, lnr);
                });
            }
            {
                let ln_all: &[f32] = &b[..n * d];
                par_rows(threads, &mut a[..n * f], f, |i, act| {
                    matvec_row(&ln_all[i * d..(i + 1) * d], &lw.ffn_w1, &lw.ffn_b1, act);
                    act.iter_mut().for_each(|v| *v = gelu(*v));
                });
            }
            {
                let act_all: &[f32] = &a[..n * f];
                par_rows_scratch(
                    threads,
                    &mut x[..n * d],
                    d,
                    || vec![0.0f32; d],
                    |delta, i, x_row| {
                        matvec_row(&act_all[i * f..(i + 1) * f], &lw.ffn_w2, &lw.ffn_b2, delta);
                        for (xv, &dv) in x_row.iter_mut().zip(delta.iter()) {
                            *xv += dv;
                        }
                    },
                );
            }
        }
    }

    /// One pre-LN transformer layer, mechanically-exported form:
    /// per-op loops, fresh buffers, separate Q/K/V GEMMs, materialized
    /// mask bias and per-head score matrices, `ijk` GEMM loops whose
    /// inner contraction strides the weight matrix row width.
    fn layer_naive(&self, x: &mut [f32], n: usize, lw: &LayerWeights, bias: &[f32]) {
        let cfg = &self.model.cfg;
        let (d, f, nh) = (cfg.d_model, cfg.d_ff(), cfg.n_heads);
        let hd = d / nh;
        let threads = self.threads;

        let mut ln1 = vec![0.0f32; n * d];
        {
            let xr: &[f32] = x;
            par_rows(threads, &mut ln1, d, |i, out| {
                ln_row(&xr[i * d..(i + 1) * d], &lw.ln1_s, &lw.ln1_b, out);
            });
        }
        // separate Q, K, V projections (three passes over ln1)
        let mut q = vec![0.0f32; n * d];
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        gemm_naive(threads, &ln1, d, &lw.qkv_w, 3 * d, 0, &lw.qkv_b, &mut q, d);
        gemm_naive(threads, &ln1, d, &lw.qkv_w, 3 * d, d, &lw.qkv_b, &mut k, d);
        gemm_naive(threads, &ln1, d, &lw.qkv_w, 3 * d, 2 * d, &lw.qkv_b, &mut v, d);

        let scale = lw.temp / (hd as f32).sqrt();
        let mut attn = vec![0.0f32; n * d];
        for h in 0..nh {
            let ho = h * hd;
            // materialized per-head score matrix, masked additively
            let mut scores = vec![0.0f32; n * n];
            {
                let (qr, kr): (&[f32], &[f32]) = (&q, &k);
                par_rows(threads, &mut scores, n, |i, srow| {
                    for (j, sj) in srow.iter_mut().enumerate() {
                        let mut acc = 0.0f32;
                        for kk in 0..hd {
                            acc += qr[i * d + ho + kk] * kr[j * d + ho + kk];
                        }
                        *sj = acc * scale + bias[i * n + j];
                    }
                });
            }
            // full softmax rows (masked entries underflow to exact 0)
            par_rows(threads, &mut scores, n, |_i, srow| {
                let mut mx = f32::NEG_INFINITY;
                for &sv in srow.iter() {
                    if sv > mx {
                        mx = sv;
                    }
                }
                let mut denom = 0.0f32;
                for sj in srow.iter_mut() {
                    let e = (*sj - mx).exp();
                    *sj = e;
                    denom += e;
                }
                for sj in srow.iter_mut() {
                    *sj /= denom;
                }
            });
            // probs @ V, materialized
            {
                let (pr, vr): (&[f32], &[f32]) = (&scores, &v);
                par_rows(threads, &mut attn, d, move |i, arow| {
                    for (d2, o) in arow[ho..ho + hd].iter_mut().enumerate() {
                        let mut acc = 0.0f32;
                        for j in 0..n {
                            acc += pr[i * n + j] * vr[j * d + ho + d2];
                        }
                        *o = acc;
                    }
                });
            }
        }
        let mut proj = vec![0.0f32; n * d];
        gemm_naive(threads, &attn, d, &lw.out_w, d, 0, &lw.out_b, &mut proj, d);
        for (xv, &pv) in x.iter_mut().zip(&proj) {
            *xv += pv;
        }

        let mut ln2 = vec![0.0f32; n * d];
        {
            let xr: &[f32] = x;
            par_rows(threads, &mut ln2, d, |i, out| {
                ln_row(&xr[i * d..(i + 1) * d], &lw.ln2_s, &lw.ln2_b, out);
            });
        }
        let mut h1 = vec![0.0f32; n * f];
        gemm_naive(threads, &ln2, d, &lw.ffn_w1, f, 0, &lw.ffn_b1, &mut h1, f);
        h1.iter_mut().for_each(|v| *v = gelu(*v));
        let mut h2 = vec![0.0f32; n * d];
        gemm_naive(threads, &h1, f, &lw.ffn_w2, d, 0, &lw.ffn_b2, &mut h2, d);
        for (xv, &hv) in x.iter_mut().zip(&h2) {
            *xv += hv;
        }
    }

    /// Gating + expert head, fused: score + reduce in one pass per
    /// candidate row — no cat/logits/activation panels.
    fn head_fused(&self, outs: &[Vec<f32>], mr: usize, out: &mut [f32]) {
        let m = &self.model;
        let cfg = &m.cfg;
        let (d, f, nb, t) = (cfg.d_model, cfg.d_ff(), cfg.n_blocks, cfg.n_tasks);
        let nbd = nb * d;
        par_rows_scratch(
            self.threads,
            &mut out[..mr * t],
            t,
            || (vec![0.0f32; nbd], vec![0.0f32; nbd], vec![0.0f32; d], vec![0.0f32; f]),
            |(cat, logits, fo, act), r, out_row| {
                let mut rows: [&[f32]; MAX_BLOCKS] = [&[]; MAX_BLOCKS];
                for (b, o) in outs.iter().enumerate() {
                    cat[b * d..(b + 1) * d].copy_from_slice(&o[r * d..(r + 1) * d]);
                    rows[b] = &o[r * d..(r + 1) * d];
                }
                matvec_row(cat, &m.gate_w, &m.gate_b, logits);
                gate_fuse_row(nb, d, logits, &rows[..nb], fo);
                matvec_row(fo, &m.exp_w1, &m.exp_b1, act);
                act.iter_mut().for_each(|v| *v = gelu(*v));
                matvec_row(act, &m.exp_w2, &m.exp_b2, out_row);
                out_row.iter_mut().for_each(|v| *v = sigmoid(*v));
            },
        );
    }

    /// Gating + expert head, api form: staged through the scratch
    /// arenas (cat → logits → fused → activations → scores), fast GEMM
    /// loops, no fresh allocation.
    fn head_api(&self, outs: &[Vec<f32>], mr: usize, out: &mut [f32], sc: &mut FastScratch) {
        let m = &self.model;
        let cfg = &m.cfg;
        let (d, f, nb, t) = (cfg.d_model, cfg.d_ff(), cfg.n_blocks, cfg.n_tasks);
        let nbd = nb * d;
        let threads = self.threads;
        let FastScratch { a, b, .. } = sc;
        // stage 1: cat rows into a
        par_rows(threads, &mut a[..mr * nbd], nbd, |r, cat| {
            for (bi, o) in outs.iter().enumerate() {
                cat[bi * d..(bi + 1) * d].copy_from_slice(&o[r * d..(r + 1) * d]);
            }
        });
        // stage 2: gate logits into b
        {
            let cat_all: &[f32] = &a[..mr * nbd];
            par_rows(threads, &mut b[..mr * nbd], nbd, |r, logits| {
                matvec_row(&cat_all[r * nbd..(r + 1) * nbd], &m.gate_w, &m.gate_b, logits);
            });
        }
        // stage 3: gated fusion into a (cat is dead)
        {
            let logits_all: &[f32] = &b[..mr * nbd];
            par_rows(threads, &mut a[..mr * d], d, |r, fo| {
                let mut rows: [&[f32]; MAX_BLOCKS] = [&[]; MAX_BLOCKS];
                for (bi, o) in outs.iter().enumerate() {
                    rows[bi] = &o[r * d..(r + 1) * d];
                }
                gate_fuse_row(nb, d, &logits_all[r * nbd..(r + 1) * nbd], &rows[..nb], fo);
            });
        }
        // stage 4: expert activations into b (logits are dead)
        {
            let fo_all: &[f32] = &a[..mr * d];
            par_rows(threads, &mut b[..mr * f], f, |r, act| {
                matvec_row(&fo_all[r * d..(r + 1) * d], &m.exp_w1, &m.exp_b1, act);
                act.iter_mut().for_each(|v| *v = gelu(*v));
            });
        }
        // stage 5: task scores
        {
            let act_all: &[f32] = &b[..mr * f];
            par_rows(threads, &mut out[..mr * t], t, |r, out_row| {
                matvec_row(&act_all[r * f..(r + 1) * f], &m.exp_w2, &m.exp_b2, out_row);
                out_row.iter_mut().for_each(|v| *v = sigmoid(*v));
            });
        }
    }

    /// Gating + expert head, naive form: materialized stages with naive
    /// GEMM loops and fresh buffers.
    fn head_naive(&self, outs: &[Vec<f32>], mr: usize, out: &mut [f32]) {
        let m = &self.model;
        let cfg = &m.cfg;
        let (d, f, nb, t) = (cfg.d_model, cfg.d_ff(), cfg.n_blocks, cfg.n_tasks);
        let nbd = nb * d;
        let threads = self.threads;
        let mut cat = vec![0.0f32; mr * nbd];
        for r in 0..mr {
            for (bi, o) in outs.iter().enumerate() {
                cat[r * nbd + bi * d..r * nbd + (bi + 1) * d]
                    .copy_from_slice(&o[r * d..(r + 1) * d]);
            }
        }
        let mut logits = vec![0.0f32; mr * nbd];
        gemm_naive(threads, &cat, nbd, &m.gate_w, nbd, 0, &m.gate_b, &mut logits, nbd);
        let mut fo = vec![0.0f32; mr * d];
        {
            let logits_all: &[f32] = &logits;
            par_rows(threads, &mut fo, d, |r, fo_row| {
                let mut rows: [&[f32]; MAX_BLOCKS] = [&[]; MAX_BLOCKS];
                for (bi, o) in outs.iter().enumerate() {
                    rows[bi] = &o[r * d..(r + 1) * d];
                }
                gate_fuse_row(nb, d, &logits_all[r * nbd..(r + 1) * nbd], &rows[..nb], fo_row);
            });
        }
        let mut h1 = vec![0.0f32; mr * f];
        gemm_naive(threads, &fo, d, &m.exp_w1, f, 0, &m.exp_b1, &mut h1, f);
        h1.iter_mut().for_each(|v| *v = gelu(*v));
        gemm_naive(threads, &h1, f, &m.exp_w2, t, 0, &m.exp_b2, &mut out[..mr * t], t);
        out[..mr * t].iter_mut().for_each(|v| *v = sigmoid(*v));
    }
}

impl ComputeBackend for CpuEngine {
    fn m(&self) -> usize {
        self.m
    }

    fn n_tasks(&self) -> usize {
        self.model.cfg.n_tasks
    }

    fn d_model(&self) -> usize {
        self.model.cfg.d_model
    }

    fn hist_len(&self) -> usize {
        self.model.cfg.seq_len * self.model.cfg.d_model
    }

    fn upload_hist(&self, hist: &[f32]) -> Result<HistHandle> {
        if hist.len() != self.hist_len() {
            return Err(Error::Internal(format!(
                "{}: hist length {} != expected {}",
                self.label(),
                hist.len(),
                self.hist_len()
            )));
        }
        Ok(HistHandle::Raw(hist.to_vec()))
    }

    fn run_segmented(&self, segments: &[SegmentBind<'_>], cands: &[f32]) -> Result<Vec<f32>> {
        let (m, d, nt) = (self.m, self.model.cfg.d_model, self.model.cfg.n_tasks);
        check_segments(&self.label(), segments, cands.len(), m, d)?;
        let mut sc = match self.variant {
            Variant::Naive => None,
            Variant::Api | Variant::Fused => {
                let cfg = &self.model.cfg;
                let n_max = cfg.block_len() + m;
                let (f, nbd) = (cfg.d_ff(), cfg.n_blocks * cfg.d_model);
                Some(FastScratch {
                    qkv: vec![0.0; n_max * 3 * cfg.d_model],
                    kt: vec![0.0; cfg.d_model * n_max],
                    a: vec![0.0; (n_max * f).max(m * nbd)],
                    b: vec![0.0; (n_max * cfg.d_model).max(m * nbd).max(m * f)],
                })
            }
        };
        let mut out = vec![0.0f32; m * nt];
        let mut launch = KernelStats { launches: 1, ..KernelStats::default() };
        let mut off = 0usize;
        for seg in segments {
            let hist = match seg.hist {
                HistHandle::Raw(h) => h,
                HistHandle::Host(_) | HistHandle::Device(_) => {
                    return Err(Error::Internal(format!(
                        "{}: foreign hist handle passed to the cpu engine",
                        self.label()
                    )))
                }
            };
            if seg.rows == 0 {
                continue;
            }
            self.forward_segment(
                hist,
                &cands[off * d..(off + seg.rows) * d],
                seg.rows,
                &mut out[off * nt..(off + seg.rows) * nt],
                &mut sc,
                &mut launch,
            );
            off += seg.rows;
        }
        self.launches.fetch_add(1, Ordering::Relaxed);
        self.flops.fetch_add(launch.flops, Ordering::Relaxed);
        self.tiles_visited.fetch_add(launch.tiles_visited, Ordering::Relaxed);
        self.tiles_skipped.fetch_add(launch.tiles_skipped, Ordering::Relaxed);
        if let Some(rec) = &self.recorder {
            rec.record_fke_launch(launch.flops, launch.tiles_visited, launch.tiles_skipped);
        }
        Ok(out)
    }

    // Native per-row segmentation: a packed batch of S segments is one
    // real launch over M rows — the trait default (`m()`) is exactly
    // right, unlike the PJRT per-history replay (`m * S`).

    fn label(&self) -> String {
        format!("cpu/{}/m{}", self.variant.name(), self.m)
    }

    fn kernel_stats(&self) -> KernelStats {
        KernelStats {
            launches: self.launches.load(Ordering::Relaxed),
            flops: self.flops.load(Ordering::Relaxed),
            tiles_visited: self.tiles_visited.load(Ordering::Relaxed),
            tiles_skipped: self.tiles_skipped.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fke::attention_tile_stats;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "cputest".into(),
            seq_len: 16,
            n_blocks: 2,
            layers_per_block: 2,
            d_model: 16,
            n_heads: 2,
            n_tasks: 3,
            m_profiles: vec![4, 8],
            native_m: 8,
        }
    }

    fn inputs(cfg: &ModelConfig, m: usize, salt: u64) -> (Vec<f32>, Vec<f32>) {
        let hist: Vec<f32> = (0..cfg.seq_len * cfg.d_model)
            .map(|i| (((i as u64 + salt) * 31 % 113) as f32 / 113.0) - 0.5)
            .collect();
        let cands: Vec<f32> = (0..m * cfg.d_model)
            .map(|i| (((i as u64 + salt) * 17 % 127) as f32 / 127.0) - 0.5)
            .collect();
        (hist, cands)
    }

    fn engine(cfg: &ModelConfig, m: usize, variant: Variant, threads: usize) -> CpuEngine {
        let model = CpuModel::new(cfg, 7).unwrap();
        CpuEngine::new(model, m, &CpuEngineConfig { variant, threads })
    }

    #[test]
    fn erf_reference_values() {
        assert_eq!(erf(0.0), 0.0);
        assert!((erf(1.0) - 0.842_700_8).abs() < 1e-5, "{}", erf(1.0));
        assert!((erf(-1.0) + 0.842_700_8).abs() < 1e-5);
        assert!((erf(3.0) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn visibility_matches_sumi_mask() {
        let lb = 4;
        // history causal
        assert!(visible(2, 1, lb) && visible(2, 2, lb) && !visible(2, 3, lb));
        // candidates see all history + self only
        assert!(visible(5, 0, lb) && visible(5, 3, lb) && visible(5, 5, lb));
        assert!(!visible(5, 4, lb) && !visible(5, 6, lb));
        // history never sees candidates
        assert!(!visible(3, 4, lb));
    }

    #[test]
    fn weights_deterministic_per_seed() {
        let cfg = tiny_cfg();
        let a = CpuModel::new(&cfg, 11).unwrap();
        let b = CpuModel::new(&cfg, 11).unwrap();
        let c = CpuModel::new(&cfg, 12).unwrap();
        assert_eq!(a.blocks[0][0].qkv_w, b.blocks[0][0].qkv_w);
        assert_eq!(a.gate_w, b.gate_w);
        assert_ne!(a.blocks[0][0].qkv_w, c.blocks[0][0].qkv_w);
        assert!(a.blocks[0][0].temp > 0.5 && a.blocks[0][0].temp < 1.5);
    }

    #[test]
    fn scores_shape_and_range() {
        let cfg = tiny_cfg();
        for variant in Variant::all() {
            let e = engine(&cfg, 8, variant, 1);
            let (hist, cands) = inputs(&cfg, 8, 5);
            let out = e.run(&hist, &cands).unwrap();
            assert_eq!(out.len(), 8 * 3);
            assert!(
                out.iter().all(|s| s.is_finite() && (0.0..=1.0).contains(s)),
                "{variant:?}: {out:?}"
            );
            assert_eq!(e.kernel_stats().launches, 1);
            assert!(e.kernel_stats().flops > 0);
        }
    }

    #[test]
    fn thread_count_never_changes_bits() {
        let cfg = tiny_cfg();
        let (hist, cands) = inputs(&cfg, 8, 21);
        for variant in Variant::all() {
            let solo = engine(&cfg, 8, variant, 1).run(&hist, &cands).unwrap();
            let multi = engine(&cfg, 8, variant, 4).run(&hist, &cands).unwrap();
            assert_eq!(solo, multi, "{variant:?} diverged under threading");
        }
    }

    #[test]
    fn fused_tile_counters_match_analytic_registry() {
        // divisible solo shape: the execution-side tile schedule must
        // agree exactly with the analytic fke registry
        let cfg = tiny_cfg(); // block_len 8
        let m = 4;
        let e = engine(&cfg, m, Variant::Fused, 1);
        let (hist, cands) = inputs(&cfg, m, 3);
        e.run(&hist, &cands).unwrap();
        let expect = attention_tile_stats(cfg.block_len(), m);
        assert_eq!(e.tile(), expect.block);
        let layers = (cfg.n_blocks * cfg.layers_per_block) as u64;
        let ks = e.kernel_stats();
        assert_eq!(ks.tiles_visited, layers * expect.visited_tiles as u64);
        assert_eq!(
            ks.tiles_visited + ks.tiles_skipped,
            layers * expect.total_tiles as u64
        );
        assert!(ks.tile_skip_fraction() > 0.0);
    }

    #[test]
    fn fused_counts_fewer_flops_than_api() {
        let cfg = tiny_cfg();
        let (hist, cands) = inputs(&cfg, 8, 9);
        let api = engine(&cfg, 8, Variant::Api, 1);
        let fused = engine(&cfg, 8, Variant::Fused, 1);
        api.run(&hist, &cands).unwrap();
        fused.run(&hist, &cands).unwrap();
        assert!(
            fused.kernel_stats().flops < api.kernel_stats().flops,
            "mask-aware schedule must cut analytic FLOPs: {} vs {}",
            fused.kernel_stats().flops,
            api.kernel_stats().flops
        );
    }

    #[test]
    fn tile_plan_covers_exactly_the_visible_pairs() {
        // the union of visited tiles must contain every visible (i, j)
        // and every visited tile must contain at least one visible pair
        for (lb, n, tile) in [(8usize, 12usize, 4usize), (8, 11, 4), (6, 10, 4), (16, 24, 8)] {
            let plan = TilePlan::build(lb, n, tile);
            let nq = n.div_ceil(tile);
            assert_eq!(plan.visited + plan.skipped, (nq * nq) as u64);
            for i in 0..n {
                let ranges = &plan.visit[i / tile];
                for j in 0..n {
                    let in_plan = ranges.iter().any(|&(j0, j1)| j >= j0 && j < j1);
                    if visible(i, j, lb) {
                        assert!(in_plan, "visible ({i},{j}) missing from plan lb={lb} n={n}");
                    }
                }
            }
            for (qt, ranges) in plan.visit.iter().enumerate() {
                for &(j0, j1) in ranges {
                    let any = (qt * tile..((qt + 1) * tile).min(n))
                        .any(|i| (j0..j1).any(|j| visible(i, j, lb)));
                    assert!(any, "empty visited range qt={qt} [{j0},{j1}) lb={lb} n={n}");
                }
            }
        }
    }

    #[test]
    fn segmented_launch_is_bit_identical_to_solo_launches() {
        let cfg = tiny_cfg();
        for variant in Variant::all() {
            let e = engine(&cfg, 8, variant, 2);
            let (hist_a, _) = inputs(&cfg, 8, 100);
            let (hist_b, _) = inputs(&cfg, 8, 200);
            let ha = e.upload_hist(&hist_a).unwrap();
            let hb = e.upload_hist(&hist_b).unwrap();
            let (_, ca) = inputs(&cfg, 3, 101); // request A: 3 rows
            let (_, cb) = inputs(&cfg, 5, 201); // request B: 5 rows

            let mut packed = ca.clone();
            packed.extend_from_slice(&cb);
            let out = e
                .run_segmented(
                    &[SegmentBind { hist: &ha, rows: 3 }, SegmentBind { hist: &hb, rows: 5 }],
                    &packed,
                )
                .unwrap();

            let mut solo_a = ca.clone();
            solo_a.extend_from_slice(&inputs(&cfg, 5, 999).1);
            let sa = e.run_segmented(&[SegmentBind { hist: &ha, rows: 8 }], &solo_a).unwrap();
            let mut solo_b = cb.clone();
            solo_b.extend_from_slice(&inputs(&cfg, 3, 998).1);
            let sb = e.run_segmented(&[SegmentBind { hist: &hb, rows: 8 }], &solo_b).unwrap();

            assert_eq!(&out[..3 * 3], &sa[..3 * 3], "{variant:?}: A rows diverged");
            assert_eq!(&out[3 * 3..], &sb[..5 * 3], "{variant:?}: B rows diverged");
            // native segmentation: 2 segments still execute m rows once
            assert_eq!(e.executed_rows_for(2), 8);
        }
    }

    #[test]
    fn rejects_foreign_handles_and_bad_shapes() {
        let cfg = tiny_cfg();
        let e = engine(&cfg, 8, Variant::Fused, 1);
        assert!(e.upload_hist(&[0.0; 7]).is_err());
        let (hist, cands) = inputs(&cfg, 8, 1);
        let h = e.upload_hist(&hist).unwrap();
        assert!(e.run_segmented(&[SegmentBind { hist: &h, rows: 5 }], &cands).is_err());
        let host = HistHandle::Host(vec![0.0; cfg.d_model]);
        assert!(e.run_segmented(&[SegmentBind { hist: &host, rows: 8 }], &cands).is_err());
    }

    #[test]
    fn seed_for_is_stable_and_scenario_dependent() {
        assert_eq!(CpuModel::seed_for("base"), CpuModel::seed_for("base"));
        assert_ne!(CpuModel::seed_for("base"), CpuModel::seed_for("long"));
    }
}
