//! FKE — Fused Kernel Engine registry (paper §3.2) and the native CPU
//! engine implementing it.
//!
//! The lowered-kernel path lives at L1/L2 (`python/compile/kernels`,
//! AOT-lowered at build time); at serve time the FKE is the *engine
//! variant* axis: which engine construction a stack runs. This module
//! names the ablation levels, maps them onto manifest entries, computes
//! the analytic efficiency numbers (mask-aware FLOP savings, VMEM
//! budgets) reported in EXPERIMENTS.md — and, in [`cpu`], implements
//! the ladder as a real multithreaded CPU compute backend
//! ([`cpu::CpuEngine`]) so every tier of the stack executes genuine
//! FLOPs on a bare checkout, no artifacts or PJRT required.

pub mod cpu;

use crate::config::ModelConfig;
use crate::error::{Error, Result};

/// The three engine-construction levels of Table 4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// "ONNX Model Conversion": mechanically exported graph.
    Naive,
    /// "TensorRT API Impl.": deliberately constructed graph.
    Api,
    /// "+ Kernel Fusion": api graph + the L1 pallas plug-ins.
    Fused,
}

impl Variant {
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Naive => "naive",
            Variant::Api => "api",
            Variant::Fused => "fused",
        }
    }

    /// Paper row label (Table 4).
    pub fn paper_label(&self) -> &'static str {
        match self {
            Variant::Naive => "ONNX Model Conversion",
            Variant::Api => "TensorRT API Impl.",
            Variant::Fused => "TensorRT API Impl. + Kernel Fusion",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "naive" | "onnx" => Ok(Variant::Naive),
            "api" => Ok(Variant::Api),
            "fused" => Ok(Variant::Fused),
            o => Err(Error::Config(format!("unknown variant '{o}'"))),
        }
    }

    pub fn all() -> [Variant; 3] {
        [Variant::Naive, Variant::Api, Variant::Fused]
    }
}

/// Analytic tile accounting of the mask-aware flash-attention kernel —
/// mirror of `python/compile/kernels/flash_attention.py::attention_tile_stats`
/// (same greedy block choice, same visit rule).
#[derive(Clone, Copy, Debug)]
pub struct TileStats {
    pub block: usize,
    pub visited_tiles: usize,
    pub total_tiles: usize,
}

impl TileStats {
    /// Score-FLOP fraction vs dense attention.
    pub fn flop_fraction(&self) -> f64 {
        self.visited_tiles as f64 / self.total_tiles as f64
    }
}

/// Largest power-of-two block <= cap dividing both lengths.
pub fn choose_block(hist_len: usize, m: usize, cap: usize) -> usize {
    let mut b = 1;
    while b * 2 <= cap && hist_len % (b * 2) == 0 && m % (b * 2) == 0 {
        b *= 2;
    }
    b
}

/// Tile accounting for one block's attention at (hist_len, m).
pub fn attention_tile_stats(hist_len: usize, m: usize) -> TileStats {
    let block = choose_block(hist_len, m, 128);
    let nq = (hist_len + m) / block;
    let nh = hist_len / block;
    let mut visited = 0usize;
    for qi in 0..nq {
        visited += if qi < nh { qi + 1 } else { nh + 1 };
    }
    TileStats { block, visited_tiles: visited, total_tiles: nq * nq }
}

/// Per-grid-step VMEM bytes of the flash kernel (q tile + resident K/V +
/// accumulators) — the §Perf budget check (≤ ~16 MB on TPU).
pub fn attention_vmem_bytes(cfg: &ModelConfig, m: usize) -> usize {
    let n = cfg.n_tokens(m);
    let hd = cfg.d_model / cfg.n_heads;
    let block = choose_block(cfg.block_len(), m, 128);
    // f32: q tile, k, v, acc, m/l vectors
    4 * (block * hd + 2 * n * hd + block * hd + 2 * block)
}

/// Per-grid-step VMEM bytes of the fused LN+FFN kernel (mirror of
/// `fused_ffn.py::ffn_vmem_bytes`).
pub fn ffn_vmem_bytes(cfg: &ModelConfig, m: usize) -> usize {
    let n = cfg.n_tokens(m);
    let d = cfg.d_model;
    let f = cfg.d_ff();
    let mut block_n = 1;
    while block_n * 2 <= 128 && n % (block_n * 2) == 0 {
        block_n *= 2;
    }
    let weights = d * f + f + f * d + d + 2 * d;
    let tile = block_n * d * 2;
    let act = block_n * f;
    4 * (weights + tile + act)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;

    #[test]
    fn variant_parse_roundtrip() {
        for v in Variant::all() {
            assert_eq!(Variant::parse(v.name()).unwrap(), v);
        }
        assert_eq!(Variant::parse("onnx").unwrap(), Variant::Naive);
        assert!(Variant::parse("xxx").is_err());
    }

    #[test]
    fn tile_stats_match_python_tiny() {
        // python attention_tile_stats(16, 4) == block 4, 15/25 visited
        let s = attention_tile_stats(16, 4);
        assert_eq!(s.block, 4);
        assert_eq!(s.visited_tiles, 15);
        assert_eq!(s.total_tiles, 25);
        assert!((s.flop_fraction() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn mask_aware_saving_grows_with_m() {
        // long scenario: block_len 512; more candidates -> bigger dead
        // candidate x candidate region -> lower visited fraction
        let f128 = attention_tile_stats(512, 128).flop_fraction();
        let f512 = attention_tile_stats(512, 512).flop_fraction();
        let f1024 = attention_tile_stats(512, 1024).flop_fraction();
        assert!(f512 < f128, "{f512} !< {f128}");
        assert!(f1024 < f512);
        // at m = block_len the saving is roughly 2x on scores
        assert!(f512 < 0.55, "{f512}");
    }

    #[test]
    fn vmem_budgets_within_tpu_limits() {
        for s in [Scenario::Base, Scenario::Long] {
            let c = s.config();
            for &m in &c.m_profiles {
                let a = attention_vmem_bytes(&c, m);
                let f = ffn_vmem_bytes(&c, m);
                assert!(a < 16 << 20, "{}/m{m}: attn VMEM {a}", c.name);
                assert!(f < 16 << 20, "{}/m{m}: ffn VMEM {f}", c.name);
            }
        }
    }

    #[test]
    fn block_divides_both() {
        for (h, m) in [(512usize, 128usize), (512, 512), (16, 4), (64, 16)] {
            let b = choose_block(h, m, 128);
            assert_eq!(h % b, 0);
            assert_eq!(m % b, 0);
            assert!(b <= 128);
        }
    }
}
