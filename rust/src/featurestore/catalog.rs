//! Synthetic item catalog + user base — the production-data substitute
//! (DESIGN.md §Environment substitutions).
//!
//! Items carry Zipf-distributed popularity (rank 0 = hottest); users
//! carry deterministic interaction histories drawn from that popularity,
//! which is exactly the structure that makes the paper's *item-side*
//! feature cache pay off (§3.1: "caching on the core hot items side
//! offers greater benefits compared to caching on the user side").

use crate::util::rng::{Rng, Zipf};

/// The item catalog: ids are popularity ranks under a permutation so the
/// hot set isn't a contiguous prefix (more realistic cache keys).
pub struct Catalog {
    size: u64,
    zipf: Zipf,
    /// multiplicative hash constant permuting rank -> item id space
    perm: u64,
}

impl Catalog {
    pub fn new(size: u64, theta: f64) -> Self {
        assert!(size > 0);
        Catalog { size, zipf: Zipf::new(size, theta), perm: 0x9E37_79B9_7F4A_7C15 }
    }

    pub fn size(&self) -> u64 {
        self.size
    }

    /// Map a popularity rank to a stable item id.
    pub fn id_of_rank(&self, rank: u64) -> u64 {
        rank.wrapping_mul(self.perm) % self.size
    }

    /// Draw one item id by popularity.
    pub fn sample_item(&self, rng: &mut Rng) -> u64 {
        self.id_of_rank(self.zipf.sample(rng))
    }

    /// Draw n distinct-ish candidate items (duplicates allowed across
    /// requests, deduped within one request like an upstream retriever).
    pub fn sample_candidates(&self, rng: &mut Rng, n: usize) -> Vec<u64> {
        let mut out = Vec::with_capacity(n);
        let mut tries = 0;
        while out.len() < n {
            let id = self.sample_item(rng);
            tries += 1;
            if !out.contains(&id) || tries > 4 * n {
                out.push(id);
            }
        }
        out
    }
}

/// Synthetic user base with deterministic per-user histories.
pub struct UserBase {
    n_users: u64,
    seed: u64,
}

impl UserBase {
    pub fn new(n_users: u64, seed: u64) -> Self {
        assert!(n_users > 0);
        UserBase { n_users, seed }
    }

    pub fn n_users(&self) -> u64 {
        self.n_users
    }

    /// A user's interaction history (item ids), deterministic per user.
    /// Drawn by popularity so histories share hot items.
    pub fn history(&self, catalog: &Catalog, user_id: u64, len: usize) -> Vec<u64> {
        let mut rng = Rng::new(self.seed ^ user_id.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        (0..len).map(|_| catalog.sample_item(&mut rng)).collect()
    }

    /// Draw a random user id (uniform — every user is equally likely,
    /// which is why user-side caching has poor hit rates, per the paper's
    /// limitation discussion).
    pub fn sample_user(&self, rng: &mut Rng) -> u64 {
        rng.below(self.n_users)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_within_catalog() {
        let c = Catalog::new(1000, 0.99);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert!(c.sample_item(&mut rng) < 1000);
        }
    }

    #[test]
    fn rank_permutation_is_stable_and_spread() {
        let c = Catalog::new(1_000_000, 0.99);
        let a = c.id_of_rank(0);
        assert_eq!(a, c.id_of_rank(0));
        // the top ranks should not be contiguous ids
        let ids: Vec<u64> = (0..4).map(|r| c.id_of_rank(r)).collect();
        let contiguous = ids.windows(2).all(|w| w[1] == w[0] + 1);
        assert!(!contiguous, "{ids:?}");
    }

    #[test]
    fn candidates_mostly_unique() {
        let c = Catalog::new(100_000, 0.9);
        let mut rng = Rng::new(3);
        let cands = c.sample_candidates(&mut rng, 64);
        assert_eq!(cands.len(), 64);
        let mut uniq = cands.clone();
        uniq.sort();
        uniq.dedup();
        assert!(uniq.len() >= 56, "only {} unique", uniq.len());
    }

    #[test]
    fn history_deterministic_per_user() {
        let c = Catalog::new(10_000, 0.99);
        let u = UserBase::new(1000, 5);
        assert_eq!(u.history(&c, 7, 32), u.history(&c, 7, 32));
        assert_ne!(u.history(&c, 7, 32), u.history(&c, 8, 32));
    }

    #[test]
    fn histories_share_hot_items() {
        // Zipf skew: many users' histories should intersect on hot items.
        let c = Catalog::new(100_000, 1.1);
        let u = UserBase::new(100, 5);
        let h1 = u.history(&c, 1, 64);
        let h2 = u.history(&c, 2, 64);
        let inter = h1.iter().filter(|id| h2.contains(id)).count();
        assert!(inter > 0, "no shared hot items");
    }
}
