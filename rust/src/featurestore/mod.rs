//! Simulated remote feature service (the paper's "remote feature query
//! service" that FLAME's PDA sits in front of).
//!
//! Features are generated deterministically from ids (seeded hashing), so
//! the store needs no real storage yet returns stable values — the cache
//! layers above can be validated for *correctness* (same bytes with and
//! without cache) while the `netsim::Link` makes the *cost* of a remote
//! query real.

pub mod catalog;

use std::sync::Arc;
use std::time::Duration;

use crate::chaos::{ChaosSlot, FaultPlan, StoreFault};
use crate::netsim::Link;
use crate::util::rng::Rng;

/// Schema of one item's feature payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FeatureSchema {
    /// Dense feature values per item ("a dozen pieces of side info").
    pub dense_dims: usize,
    /// Bytes of overhead per item on the wire (keys, proto framing).
    pub wire_overhead: usize,
}

impl Default for FeatureSchema {
    fn default() -> Self {
        FeatureSchema { dense_dims: 16, wire_overhead: 24 }
    }
}

impl FeatureSchema {
    /// Wire bytes for a batch of n items.
    pub fn wire_bytes(&self, n: usize) -> usize {
        n * (self.dense_dims * 4 + self.wire_overhead)
    }
}

/// One item's fetched features.
///
/// `dense` is shared (`Arc<[f32]>`): cloning features out of the cache
/// costs a refcount bump, not a row copy, and the miss-default zero row
/// is one shared allocation per schema rather than a fresh `Vec` per
/// missing item.
#[derive(Clone, Debug, PartialEq)]
pub struct ItemFeatures {
    pub item_id: u64,
    pub dense: Arc<[f32]>,
    /// Version counter — bumped when the store "updates" the item, used
    /// to observe staleness in async-cache tests.
    pub version: u64,
}

/// The remote store: deterministic feature synthesis behind a simulated
/// network link.
pub struct RemoteStore {
    schema: FeatureSchema,
    link: Arc<Link>,
    seed: u64,
    /// Global version epoch; bumping simulates upstream feature updates.
    epoch: std::sync::atomic::AtomicU64,
    /// Server-side processing time per query batch (fixed part).
    proc_time: Duration,
    /// Server-side cost per item in the batch (multiget fan-out, storage
    /// reads, serialization) — this is what makes cache hits cut *latency*
    /// and not just bytes.
    per_item: Duration,
    /// Fault-injection point: the armed plan can delay, fail, or time
    /// out remote batches (`chaos` module docs).
    chaos: ChaosSlot,
}

impl RemoteStore {
    pub fn new(schema: FeatureSchema, link: Arc<Link>, seed: u64) -> Self {
        RemoteStore {
            schema,
            link,
            seed,
            epoch: std::sync::atomic::AtomicU64::new(0),
            proc_time: Duration::from_micros(50),
            per_item: Duration::from_micros(40),
            chaos: ChaosSlot::new(),
        }
    }

    /// Arm the store's fault-injection point with a chaos plan.
    pub fn arm_chaos(&self, plan: Arc<FaultPlan>) {
        self.chaos.arm(plan);
    }

    /// Override the server-side cost model (tests/benches).
    pub fn with_costs(mut self, proc_time: Duration, per_item: Duration) -> Self {
        self.proc_time = proc_time;
        self.per_item = per_item;
        self
    }

    pub fn schema(&self) -> FeatureSchema {
        self.schema
    }

    pub fn link(&self) -> &Arc<Link> {
        &self.link
    }

    /// Simulate an upstream feature refresh (e.g. hourly stats rebuild).
    pub fn bump_epoch(&self) {
        self.epoch.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    pub fn epoch(&self) -> u64 {
        self.epoch.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Deterministic feature synthesis — stable per (seed, item, epoch).
    fn synthesize(&self, item_id: u64) -> ItemFeatures {
        let epoch = self.epoch();
        let mut rng = Rng::new(
            self.seed ^ item_id.wrapping_mul(0xD6E8_FEB8_6659_FD93) ^ (epoch << 48),
        );
        let dense: Arc<[f32]> =
            (0..self.schema.dense_dims).map(|_| rng.normal_f32()).collect::<Vec<f32>>().into();
        ItemFeatures { item_id, dense, version: epoch }
    }

    /// Fetch a batch of item features over the simulated link (one RTT +
    /// serialization for the whole batch — batching is already the
    /// baseline practice the paper assumes).
    pub fn fetch_batch(&self, item_ids: &[u64]) -> Vec<ItemFeatures> {
        let bytes = self.schema.wire_bytes(item_ids.len());
        self.link.transfer(bytes);
        crate::util::timeutil::precise_wait(
            self.proc_time + self.per_item * item_ids.len() as u32,
        );
        item_ids.iter().map(|&id| self.synthesize(id)).collect()
    }

    /// Failure-aware fetch: a link timeout costs the full timeout wait
    /// and yields no features (the caller decides how to degrade —
    /// `pda::QueryEngine` falls back to stale/default values).
    pub fn try_fetch_batch(
        &self,
        item_ids: &[u64],
    ) -> Result<Vec<ItemFeatures>, crate::netsim::TransferTimeout> {
        if let Some(plan) = self.chaos.get() {
            match plan.store_fault() {
                StoreFault::None => {}
                StoreFault::Delay(us) => {
                    crate::util::timeutil::precise_wait(Duration::from_micros(us));
                }
                StoreFault::Error => return Err(crate::netsim::TransferTimeout),
                StoreFault::Timeout => {
                    // like a real link timeout, the caller burns 3x the
                    // healthy service time before giving up
                    let healthy =
                        self.proc_time + self.per_item * item_ids.len() as u32;
                    crate::util::timeutil::precise_wait(healthy * 3);
                    return Err(crate::netsim::TransferTimeout);
                }
            }
        }
        let bytes = self.schema.wire_bytes(item_ids.len());
        match self.link.try_transfer(bytes) {
            Ok(_) => {
                crate::util::timeutil::precise_wait(
                    self.proc_time + self.per_item * item_ids.len() as u32,
                );
                Ok(item_ids.iter().map(|&id| self.synthesize(id)).collect())
            }
            Err((t, _)) => Err(t),
        }
    }

    /// Fetch a single item (used by the async refresh workers).
    pub fn fetch_one(&self, item_id: u64) -> ItemFeatures {
        self.fetch_batch(std::slice::from_ref(&item_id)).pop().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{Link, LinkConfig};

    fn store() -> RemoteStore {
        let link = Arc::new(Link::new(LinkConfig {
            rtt: Duration::from_micros(100),
            bandwidth_bps: 1e9,
            jitter: 0.0,
            fail_rate: 0.0,
        }));
        RemoteStore::new(FeatureSchema::default(), link, 7)
    }

    #[test]
    fn deterministic_per_item() {
        let s = store();
        let a = s.fetch_one(42);
        let b = s.fetch_one(42);
        assert_eq!(a, b);
        let c = s.fetch_one(43);
        assert_ne!(a.dense, c.dense);
    }

    #[test]
    fn epoch_changes_features() {
        let s = store();
        let a = s.fetch_one(42);
        s.bump_epoch();
        let b = s.fetch_one(42);
        assert_ne!(a.dense, b.dense);
        assert_eq!(b.version, 1);
    }

    #[test]
    fn batch_counts_wire_bytes_once() {
        let s = store();
        let before = s.link.bytes_total();
        s.fetch_batch(&[1, 2, 3, 4]);
        let bytes = s.link.bytes_total() - before;
        assert_eq!(bytes as usize, s.schema.wire_bytes(4));
        assert_eq!(s.link.queries_total(), 1);
    }

    #[test]
    fn dense_dims_respected() {
        let s = store();
        assert_eq!(s.fetch_one(5).dense.len(), s.schema().dense_dims);
    }

    #[test]
    fn chaos_plan_fails_fallible_batches_only() {
        let s = store();
        s.arm_chaos(Arc::new(crate::chaos::FaultPlan::parse("store_error:p=1", 1).unwrap()));
        assert!(s.try_fetch_batch(&[1, 2]).is_err());
        assert!(s.try_fetch_batch(&[3]).is_err());
        // the infallible path (async refresh workers) is not faulted
        assert_eq!(s.fetch_batch(&[1]).len(), 1);
    }

    #[test]
    fn chaos_timeout_burns_a_penalty() {
        let s = store();
        let t0 = std::time::Instant::now();
        let ok = s.try_fetch_batch(&[1, 2, 3]);
        let healthy = t0.elapsed();
        assert!(ok.is_ok());
        s.arm_chaos(Arc::new(
            crate::chaos::FaultPlan::parse("store_timeout:p=1", 1).unwrap(),
        ));
        let t1 = std::time::Instant::now();
        assert!(s.try_fetch_batch(&[1, 2, 3]).is_err());
        assert!(t1.elapsed() > healthy / 2, "injected timeout must not be free");
    }
}
