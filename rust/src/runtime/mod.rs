//! The PJRT runtime: loads AOT-lowered HLO text artifacts and executes
//! them on the request path.
//!
//! Responsibilities:
//! * one [`Runtime`] per process — wraps `xla::PjRtClient::cpu()`;
//! * [`WeightSet`] — a scenario's weights uploaded to the device **once**
//!   and shared (Arc) by every engine variant/profile of that scenario
//!   (the analogue of TensorRT engine weights resident in GPU memory);
//! * [`Engine`] — one compiled executable for a fixed (scenario, variant,
//!   M-profile); per-request work is exactly two host→device input
//!   transfers + `execute_b` + one device→host read.
//!
//! Threading: `xla`'s wrapper types hold raw pointers and are therefore
//! `!Send`. The PJRT CPU client is thread-safe for compilation, buffer
//! upload, and execution (each call synchronizes internally; the CPU
//! plugin serializes where required), so we wrap them in `SendSync`
//! newtypes with that documented justification. Engines are still used
//! single-threaded-per-executor by the DSO (one executor = one thread),
//! matching the paper's one-stream-per-executor design.

pub mod engine;

pub use engine::{Engine, EngineStats, HistBuffer};

use std::path::Path;
use std::sync::Arc;

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::manifest::Manifest;

/// Identifies one lowered engine: (scenario, variant, M-profile).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct EngineKey {
    pub scenario: String,
    pub variant: String,
    pub m: usize,
}

impl EngineKey {
    pub fn new(scenario: &str, variant: &str, m: usize) -> Self {
        EngineKey { scenario: scenario.into(), variant: variant.into(), m }
    }

    pub fn label(&self) -> String {
        format!("{}/{}/m{}", self.scenario, self.variant, self.m)
    }
}

/// `Send + Sync` wrapper for xla handle types (see module docs).
pub(crate) struct SendSync<T>(pub T);

// The PJRT CPU client (tfrt_cpu_pjrt_client) is documented thread-safe
// for compile/execute/transfer; the raw pointers inside the xla
// wrappers are only non-Send because bindgen cannot know that.
// SAFETY: all mutation happens behind PJRT's own synchronization.
unsafe impl<T> Send for SendSync<T> {}
// SAFETY: same argument as Send — PJRT synchronizes internally, so
// shared references across threads are sound.
unsafe impl<T> Sync for SendSync<T> {}

/// A scenario's device-resident weights (uploaded once, shared by all
/// engines of that scenario).
pub struct WeightSet {
    pub scenario: String,
    pub(crate) buffers: Vec<SendSync<xla::PjRtBuffer>>,
    pub total_bytes: usize,
    pub n_tensors: usize,
}

/// Process-wide PJRT runtime.
pub struct Runtime {
    pub(crate) client: Arc<SendSync<xla::PjRtClient>>,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client: Arc::new(SendSync(client)) })
    }

    pub fn platform(&self) -> String {
        self.client.0.platform_name()
    }

    /// Upload a scenario's weights from `weights_<scenario>.bin` to the
    /// device. One call per scenario per process.
    pub fn upload_weights(&self, manifest: &Manifest, scenario: &str) -> Result<Arc<WeightSet>> {
        let tensors = manifest.load_weights(scenario)?;
        let mut buffers = Vec::with_capacity(tensors.len());
        let mut total_bytes = 0usize;
        for (spec, data) in &tensors {
            total_bytes += data.len() * 4;
            let buf = self
                .client
                .0
                .buffer_from_host_buffer::<f32>(data, &spec.shape, None)?;
            buffers.push(SendSync(buf));
        }
        Ok(Arc::new(WeightSet {
            scenario: scenario.to_string(),
            n_tensors: buffers.len(),
            buffers,
            total_bytes,
        }))
    }

    /// Compile one HLO-text artifact into an executable engine, wiring in
    /// the scenario's device-resident weights.
    pub fn load_engine_with_weights(
        &self,
        manifest: &Manifest,
        key: &EngineKey,
        weights: Arc<WeightSet>,
    ) -> Result<Engine> {
        if weights.scenario != key.scenario {
            return Err(Error::Internal(format!(
                "weight set for {} used with engine {}",
                weights.scenario,
                key.label()
            )));
        }
        let entry = manifest.find(&key.scenario, &key.variant, key.m)?;
        let sa = manifest.scenario(&key.scenario)?;
        if entry.n_weight_inputs != weights.n_tensors {
            return Err(Error::Manifest(format!(
                "{}: engine expects {} weight inputs, weight set has {}",
                key.label(),
                entry.n_weight_inputs,
                weights.n_tensors
            )));
        }
        let path = manifest.path_of(&entry.path);
        let exe = self.compile_hlo(&path)?;
        Ok(Engine::new(
            key.clone(),
            sa.config.clone(),
            entry.flops,
            exe,
            weights,
            Arc::clone(&self.client),
        ))
    }

    /// Convenience: upload weights + load a single engine.
    pub fn load_engine(&self, manifest: &Manifest, key: &EngineKey) -> Result<Engine> {
        let w = self.upload_weights(manifest, &key.scenario)?;
        self.load_engine_with_weights(manifest, key, w)
    }

    /// Load one engine per available M-profile of (scenario, variant) —
    /// the DSO's explicit-shape executor set. Weights are shared.
    pub fn load_profile_set(
        &self,
        manifest: &Manifest,
        scenario: &str,
        variant: &str,
    ) -> Result<Vec<Engine>> {
        let profiles = manifest.profiles_for(scenario, variant);
        if profiles.is_empty() {
            return Err(Error::UnknownEngine(format!("{scenario}/{variant} has no profiles")));
        }
        let weights = self.upload_weights(manifest, scenario)?;
        profiles
            .into_iter()
            .map(|m| {
                self.load_engine_with_weights(
                    manifest,
                    &EngineKey::new(scenario, variant, m),
                    Arc::clone(&weights),
                )
            })
            .collect()
    }

    /// HLO text -> compiled PJRT executable.
    fn compile_hlo(&self, path: &Path) -> Result<SendSync<xla::PjRtLoadedExecutable>> {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.0.compile(&comp)?;
        Ok(SendSync(exe))
    }

    /// Expose a ModelConfig for a manifest scenario (serve-time source of
    /// truth).
    pub fn scenario_config(manifest: &Manifest, scenario: &str) -> Result<ModelConfig> {
        Ok(manifest.scenario(scenario)?.config.clone())
    }
}
