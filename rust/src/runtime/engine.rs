//! One compiled engine: fixed (scenario, variant, M) shape, device-
//! resident weights, and the per-request execute hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::runtime::{EngineKey, SendSync, WeightSet};

/// A device-resident history tensor, shareable across the chunk
/// executions of one request (and across engines of the same runtime —
/// PJRT buffers are client-scoped, not executable-scoped).
pub struct HistBuffer {
    pub(crate) buf: SendSync<xla::PjRtBuffer>,
    pub(crate) len: usize,
}

/// Cumulative execution statistics for one engine.
#[derive(Default)]
pub struct EngineStats {
    pub executions: AtomicU64,
    pub compute_us: AtomicU64,
    pub upload_us: AtomicU64,
    pub download_us: AtomicU64,
}

impl EngineStats {
    pub fn mean_compute_ms(&self) -> f64 {
        let n = self.executions.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.compute_us.load(Ordering::Relaxed) as f64 / n as f64 / 1e3
    }
}

/// A compiled PJRT executable with fixed input shapes.
///
/// Per-request path: upload `hist` [L, D] and `cands` [M, D], call
/// `execute_b` with the device-resident weight buffers + the two inputs,
/// read back scores [M, n_tasks]. No allocation besides the two input
/// buffers and the output literal.
pub struct Engine {
    pub key: EngineKey,
    pub config: ModelConfig,
    /// Analytic FLOPs per request (dense forward) — for MFU reporting.
    pub flops: u64,
    exe: SendSync<xla::PjRtLoadedExecutable>,
    weights: Arc<WeightSet>,
    client: Arc<SendSync<xla::PjRtClient>>,
    pub stats: EngineStats,
}

impl Engine {
    pub(crate) fn new(
        key: EngineKey,
        config: ModelConfig,
        flops: u64,
        exe: SendSync<xla::PjRtLoadedExecutable>,
        weights: Arc<WeightSet>,
        client: Arc<SendSync<xla::PjRtClient>>,
    ) -> Self {
        Engine { key, config, flops, exe, weights, client, stats: EngineStats::default() }
    }

    /// This engine's fixed candidate count.
    pub fn m(&self) -> usize {
        self.key.m
    }

    /// Expected input lengths (f32 elements).
    pub fn hist_len(&self) -> usize {
        self.config.seq_len * self.config.d_model
    }

    pub fn cands_len(&self) -> usize {
        self.key.m * self.config.d_model
    }

    /// Output length: M x n_tasks.
    pub fn out_len(&self) -> usize {
        self.key.m * self.config.n_tasks
    }

    /// Upload a history tensor once for reuse across several executions
    /// (the DSO splits one request across profile engines; all chunks
    /// share the same [L, D] history — uploading it per chunk would
    /// multiply the host→device traffic by the chunk count).
    pub fn upload_hist(&self, hist: &[f32]) -> Result<HistBuffer> {
        if hist.len() != self.hist_len() {
            return Err(Error::Internal(format!(
                "{}: hist length {} != expected {}",
                self.key.label(),
                hist.len(),
                self.hist_len()
            )));
        }
        let buf = self.client.0.buffer_from_host_buffer::<f32>(
            hist,
            &[self.config.seq_len, self.config.d_model],
            None,
        )?;
        Ok(HistBuffer { buf: SendSync(buf), len: hist.len() })
    }

    /// Execute one request. `hist` is [L*D] and `cands` [M*D], row-major.
    pub fn run(&self, hist: &[f32], cands: &[f32]) -> Result<Vec<f32>> {
        let hist_buf = self.upload_hist(hist)?;
        self.run_with_hist(&hist_buf, cands)
    }

    /// Execute with a pre-uploaded (device-resident) history buffer.
    pub fn run_with_hist(&self, hist: &HistBuffer, cands: &[f32]) -> Result<Vec<f32>> {
        if hist.len != self.hist_len() || cands.len() != self.cands_len() {
            return Err(Error::Internal(format!(
                "{}: input lengths (hist {}, cands {}) != expected ({}, {})",
                self.key.label(),
                hist.len,
                cands.len(),
                self.hist_len(),
                self.cands_len()
            )));
        }
        let d = self.config.d_model;

        // host -> device (the pinned-transfer analogue: callers hand us
        // contiguous staging slices; one transfer per tensor).
        let t0 = Instant::now();
        let cands_buf =
            self.client.0.buffer_from_host_buffer::<f32>(cands, &[self.key.m, d], None)?;
        let upload_us = t0.elapsed().as_micros() as u64;

        // compute
        let t1 = Instant::now();
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.weights.buffers.len() + 2);
        for w in &self.weights.buffers {
            args.push(&w.0);
        }
        args.push(&hist.buf.0);
        args.push(&cands_buf);
        let result = self.exe.0.execute_b(&args)?;
        let compute_us = t1.elapsed().as_micros() as u64;

        // device -> host
        let t2 = Instant::now();
        let out = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Internal("empty execute output".into()))?;
        let literal = out.to_literal_sync()?;
        let scores = literal.to_tuple1()?.to_vec::<f32>()?;
        let download_us = t2.elapsed().as_micros() as u64;

        if scores.len() != self.out_len() {
            return Err(Error::Internal(format!(
                "{}: output length {} != expected {}",
                self.key.label(),
                scores.len(),
                self.out_len()
            )));
        }

        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats.compute_us.fetch_add(compute_us, Ordering::Relaxed);
        self.stats.upload_us.fetch_add(upload_us, Ordering::Relaxed);
        self.stats.download_us.fetch_add(download_us, Ordering::Relaxed);
        Ok(scores)
    }

    /// Model FLOP utilization estimate against a given peak (GFLOP/s).
    pub fn mfu(&self, peak_gflops: f64) -> f64 {
        let mean_s = self.stats.mean_compute_ms() / 1e3;
        if mean_s <= 0.0 {
            return 0.0;
        }
        (self.flops as f64 / mean_s) / (peak_gflops * 1e9)
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("key", &self.key.label())
            .field("flops", &self.flops)
            .field("executions", &self.stats.executions.load(Ordering::Relaxed))
            .finish()
    }
}
