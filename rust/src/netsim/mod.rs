//! Network simulation for the remote feature service.
//!
//! The paper's Table 3 economics hinge on the bandwidth hierarchy of
//! Fig 3: network ≈ 1.25 GB/s with RTTs in the milliseconds, versus
//! hundreds-of-GB/s local memory. We model a remote feature store link as
//! RTT + size/bandwidth service time with a global token-bucket for
//! shared-bandwidth contention, and *actually wait* that long — so cache
//! hit rates translate into real measured latency/throughput deltas, the
//! same mechanism the paper measures on bypass traffic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::timeutil::precise_wait;

/// Link model parameters.
#[derive(Clone, Debug)]
pub struct LinkConfig {
    /// Round-trip latency per query batch.
    pub rtt: Duration,
    /// Shared link bandwidth (bytes/sec) — Fig 3's "network ≈ 1.25 GB/s",
    /// scaled down by default to reflect the feature service's share.
    pub bandwidth_bps: f64,
    /// RTT jitter fraction (uniform ±).
    pub jitter: f64,
    /// Failure injection: probability a transfer times out (deterministic
    /// per transfer sequence number; 0.0 disables).
    pub fail_rate: f64,
}

impl Default for LinkConfig {
    fn default() -> Self {
        LinkConfig {
            rtt: Duration::from_micros(1500),
            bandwidth_bps: 200e6, // 200 MB/s share of the NIC
            jitter: 0.2,
            fail_rate: 0.0,
        }
    }
}

/// A failed (timed-out) transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransferTimeout;

/// A simulated shared network link. Thread-safe; all feature-store
/// traffic passes through one instance so concurrent requests contend
/// for bandwidth like they would on a real NIC.
pub struct Link {
    cfg: LinkConfig,
    /// Virtual time (ns since start) until which the link is busy.
    busy_until: Mutex<u64>,
    start: Instant,
    bytes_total: AtomicU64,
    queries_total: AtomicU64,
    seq: AtomicU64,
}

impl Link {
    pub fn new(cfg: LinkConfig) -> Self {
        Link {
            cfg,
            busy_until: Mutex::new(0),
            start: Instant::now(),
            bytes_total: AtomicU64::new(0),
            queries_total: AtomicU64::new(0),
            seq: AtomicU64::new(0),
        }
    }

    /// Perform a simulated transfer of `bytes`: blocks the calling thread
    /// for RTT + serialization time, accounting for link contention.
    /// Returns the modeled service duration.
    pub fn transfer(&self, bytes: usize) -> Duration {
        match self.try_transfer(bytes) {
            Ok(d) | Err((TransferTimeout, d)) => d,
        }
    }

    /// Transfer with failure injection: a failing transfer still burns
    /// the full timeout (that's what makes remote flakiness expensive),
    /// then reports `TransferTimeout`.
    pub fn try_transfer(&self, bytes: usize) -> Result<Duration, (TransferTimeout, Duration)> {
        self.bytes_total.fetch_add(bytes as u64, Ordering::Relaxed);
        self.queries_total.fetch_add(1, Ordering::Relaxed);

        let ser_ns = (bytes as f64 / self.cfg.bandwidth_bps * 1e9) as u64;
        // deterministic jitter from a counter hash (no global rng lock)
        let n = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut h = n.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        h ^= h >> 33;
        let fail = self.cfg.fail_rate > 0.0
            && ((h >> 16) & 0xFFFF) as f64 / 65536.0 < self.cfg.fail_rate;
        let frac = (h & 0xFFFF) as f64 / 65536.0; // [0,1)
        let rtt_ns = self.cfg.rtt.as_nanos() as f64 * (1.0 + self.cfg.jitter * (2.0 * frac - 1.0));

        // serialize on the shared link: reserve [busy, busy+ser] in
        // virtual time, then sleep until reservation end + rtt.
        let now_ns = self.start.elapsed().as_nanos() as u64;
        let end_ns = {
            let mut busy = self.busy_until.lock().unwrap();
            let begin = (*busy).max(now_ns);
            let end = begin + ser_ns;
            *busy = end;
            end
        };
        let wake_ns = end_ns + rtt_ns as u64;
        let wait = Duration::from_nanos(wake_ns.saturating_sub(now_ns));
        if fail {
            // a timeout costs 3x the healthy service time before the
            // caller gives up
            let penalty = wait * 3;
            precise_wait(penalty);
            return Err((TransferTimeout, penalty));
        }
        precise_wait(wait);
        Ok(wait)
    }

    /// Total bytes that crossed the link.
    pub fn bytes_total(&self) -> u64 {
        self.bytes_total.load(Ordering::Relaxed)
    }

    pub fn queries_total(&self) -> u64 {
        self.queries_total.load(Ordering::Relaxed)
    }

    /// Mean utilization since start, MB/s (Table 3 column 4).
    pub fn utilization_mb_per_s(&self) -> f64 {
        let el = self.start.elapsed().as_secs_f64().max(1e-9);
        self.bytes_total() as f64 / 1e6 / el
    }

    pub fn config(&self) -> &LinkConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_link() -> Link {
        Link::new(LinkConfig {
            rtt: Duration::from_micros(200),
            bandwidth_bps: 100e6,
            jitter: 0.0,
            fail_rate: 0.0,
        })
    }

    #[test]
    fn transfer_waits_at_least_rtt() {
        let link = fast_link();
        let t = Instant::now();
        link.transfer(0);
        assert!(t.elapsed() >= Duration::from_micros(180));
    }

    #[test]
    fn serialization_time_scales_with_bytes() {
        let link = fast_link();
        // 1 MB at 100 MB/s = 10 ms
        let d = link.transfer(1_000_000);
        assert!(d >= Duration::from_millis(9), "{d:?}");
    }

    #[test]
    fn accounting() {
        let link = fast_link();
        link.transfer(100);
        link.transfer(200);
        assert_eq!(link.bytes_total(), 300);
        assert_eq!(link.queries_total(), 2);
        assert!(link.utilization_mb_per_s() > 0.0);
    }

    #[test]
    fn contention_serializes() {
        // Two concurrent 0.5 MB transfers on a 100 MB/s link cannot both
        // finish in ~5 ms; the second must see queueing delay.
        let link = std::sync::Arc::new(fast_link());
        let t0 = Instant::now();
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let l = std::sync::Arc::clone(&link);
                std::thread::spawn(move || l.transfer(500_000))
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        // total wall >= 2 * 5ms serialization (minus epsilon)
        assert!(t0.elapsed() >= Duration::from_millis(9), "{:?}", t0.elapsed());
    }
}
