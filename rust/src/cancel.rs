//! Request-scoped cooperative cancellation.
//!
//! FLAME's envelope only pays for compute that can still make its
//! deadline: a request that has already expired, whose client hung up,
//! or that lost its hedge race is pure waste — every FLOP it burns is
//! capacity stolen from a request that could still make SLA. Admission
//! control (PR 8/9) gates the front door; this module builds the *leave*
//! half: a [`CancelToken`] is stamped on every admitted request and
//! checked at each stage boundary (intake pop, handoff pop, coalescer
//! slot, pre-launch, fetch-ticket wait, hedge completion), so doomed
//! work is dropped at the earliest cheap point with a typed
//! [`crate::Error::Cancelled`] reply — never silently, never leaking
//! pooled state.
//!
//! The token is a shared atomic *cause cell*: zero means live, and the
//! first cancellation cause to land wins (compare-and-swap), so a
//! request observed as cancelled always reports one stable cause.
//! Deadline expiry is *lazy*: nothing fires a timer per request;
//! instead each stage boundary calls [`CancelToken::poll`], which
//! stamps [`CancelCause::Expired`] if the token carries a deadline that
//! has passed. Tokens created without a deadline (`cancel` knob off)
//! never self-expire — only explicit fires (`ClientGone`, `HedgeLoser`,
//! `Shutdown`) are honored, which keeps the knob opt-in without a
//! second code path.
//!
//! Every drop site is counted exactly once per token fire through
//! [`crate::metrics::Recorder::record_cancelled`] under a
//! `{cause, stage}` label pair plus a saved-work estimate (user-item
//! pairs that were *not* computed), so the goodput win is measurable.
//!
//! Deep shared paths (the PDA fetch coalescer) cannot thread a token
//! parameter through every signature; like [`crate::obs::current_trace`]
//! they read a thread-local set by the owning stage worker
//! ([`set_current`] / [`current`]).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Why a request was cancelled. The first cause to land on a token
/// wins; later fires are ignored so the reported cause is stable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelCause {
    /// The request's deadline passed before the work completed.
    Expired = 1,
    /// The TCP front observed the client disconnect mid-request.
    ClientGone = 2,
    /// The other arm of a hedged dispatch won the race.
    HedgeLoser = 3,
    /// The serving process is draining for shutdown.
    Shutdown = 4,
}

/// Number of causes (first dimension of the recorder's cancel matrix).
pub const N_CAUSES: usize = 4;

impl CancelCause {
    /// Stable 0-based index into the recorder's cancel matrix.
    pub fn index(self) -> usize {
        self as usize - 1
    }

    pub fn from_index(i: usize) -> Option<CancelCause> {
        CancelCause::from_u8(i as u8 + 1)
    }

    fn from_u8(v: u8) -> Option<CancelCause> {
        match v {
            1 => Some(CancelCause::Expired),
            2 => Some(CancelCause::ClientGone),
            3 => Some(CancelCause::HedgeLoser),
            4 => Some(CancelCause::Shutdown),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            CancelCause::Expired => "expired",
            CancelCause::ClientGone => "client_gone",
            CancelCause::HedgeLoser => "hedge_loser",
            CancelCause::Shutdown => "shutdown",
        }
    }
}

/// Stage boundary at which a cancelled request was actually dropped
/// (the earliest cheap point that observed the fired token).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelStage {
    /// Purged from the pipeline intake queue before feature work.
    Intake,
    /// Purged from the feature->compute handoff queue (arena returned).
    Handoff,
    /// Evicted from a still-open DSO pending batch (rows re-packed).
    Coalescer,
    /// Dropped immediately before an engine launch.
    Launch,
    /// A fetch-coalescer rider abandoned its ticket wait.
    Fetch,
    /// A hedge dispatch abandoned after the other arm won.
    Hedge,
    /// The TCP front discarded a completed response (client gone).
    Frontend,
}

/// Number of stages (second dimension of the recorder's cancel matrix).
pub const N_STAGES: usize = 7;

impl CancelStage {
    /// Stable 0-based index into the recorder's cancel matrix.
    pub fn index(self) -> usize {
        match self {
            CancelStage::Intake => 0,
            CancelStage::Handoff => 1,
            CancelStage::Coalescer => 2,
            CancelStage::Launch => 3,
            CancelStage::Fetch => 4,
            CancelStage::Hedge => 5,
            CancelStage::Frontend => 6,
        }
    }

    pub fn from_index(i: usize) -> Option<CancelStage> {
        match i {
            0 => Some(CancelStage::Intake),
            1 => Some(CancelStage::Handoff),
            2 => Some(CancelStage::Coalescer),
            3 => Some(CancelStage::Launch),
            4 => Some(CancelStage::Fetch),
            5 => Some(CancelStage::Hedge),
            6 => Some(CancelStage::Frontend),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            CancelStage::Intake => "intake",
            CancelStage::Handoff => "handoff",
            CancelStage::Coalescer => "coalescer",
            CancelStage::Launch => "launch",
            CancelStage::Fetch => "fetch",
            CancelStage::Hedge => "hedge",
            CancelStage::Frontend => "frontend",
        }
    }
}

struct Inner {
    /// 0 = live; otherwise the discriminant of the winning cause.
    cause: AtomicU8,
    /// Lazy-expiry deadline; `None` means the token never self-expires
    /// (the `cancel` knob is off, or the caller manages expiry itself).
    deadline: Option<Instant>,
}

/// Shared per-request cancellation cell. Cloning shares the cell:
/// every plane holding a clone observes the same fired cause.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CancelToken").field("cause", &self.cause()).finish()
    }
}

impl Default for CancelToken {
    fn default() -> CancelToken {
        CancelToken::new()
    }
}

impl CancelToken {
    /// A live token that never self-expires (explicit fires only).
    pub fn new() -> CancelToken {
        CancelToken { inner: Arc::new(Inner { cause: AtomicU8::new(0), deadline: None }) }
    }

    /// A live token that [`poll`](Self::poll) lazily expires once
    /// `deadline` passes.
    pub fn with_deadline(deadline: Instant) -> CancelToken {
        CancelToken {
            inner: Arc::new(Inner { cause: AtomicU8::new(0), deadline: Some(deadline) }),
        }
    }

    /// Fire `cause` into the cell. Returns `true` iff this call won the
    /// race (the token was live); the first cause to land is final.
    // lint: no_alloc — fired from hot stage boundaries
    pub fn cancel(&self, cause: CancelCause) -> bool {
        self.inner
            .cause
            .compare_exchange(0, cause as u8, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// The winning cause, if the token has fired.
    // lint: no_alloc — read at every stage boundary
    pub fn cause(&self) -> Option<CancelCause> {
        CancelCause::from_u8(self.inner.cause.load(Ordering::Acquire))
    }

    // lint: no_alloc — read at every stage boundary
    pub fn is_cancelled(&self) -> bool {
        self.inner.cause.load(Ordering::Acquire) != 0
    }

    /// Stage-boundary check: lazily stamps [`CancelCause::Expired`] if
    /// the token carries a deadline that has passed, then returns the
    /// current cause (`None` = still live, keep working).
    // lint: no_alloc — the per-stage token check on the serve hot path
    pub fn poll(&self) -> Option<CancelCause> {
        if self.inner.cause.load(Ordering::Acquire) == 0 {
            if let Some(d) = self.inner.deadline {
                if Instant::now() >= d {
                    self.cancel(CancelCause::Expired);
                }
            }
        }
        self.cause()
    }
}

// ---- thread-local current token (deep shared paths) ----

thread_local! {
    static CURRENT: RefCell<Option<CancelToken>> = const { RefCell::new(None) };
}

/// Mark the token the calling thread is currently working for (`None`
/// to clear). Stage workers set this around assembly, mirroring
/// [`crate::obs::set_current_trace`], so the fetch coalescer's rider
/// wait can observe cancellation without a threaded parameter.
pub fn set_current(token: Option<CancelToken>) {
    CURRENT.with(|c| *c.borrow_mut() = token);
}

/// Clone of the calling thread's current token, if any.
pub fn current() -> Option<CancelToken> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Whether the calling thread's current token (if any) has fired or
/// expired. `false` when no token is set.
pub fn current_cancelled() -> bool {
    CURRENT.with(|c| c.borrow().as_ref().map_or(false, |t| t.poll().is_some()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn first_cause_wins() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        assert_eq!(t.poll(), None);
        assert!(t.cancel(CancelCause::ClientGone));
        assert!(!t.cancel(CancelCause::Shutdown), "second fire must lose");
        assert_eq!(t.cause(), Some(CancelCause::ClientGone));
        assert_eq!(t.poll(), Some(CancelCause::ClientGone));
    }

    #[test]
    fn clones_share_the_cell() {
        let t = CancelToken::new();
        let u = t.clone();
        assert!(u.cancel(CancelCause::HedgeLoser));
        assert_eq!(t.cause(), Some(CancelCause::HedgeLoser));
    }

    #[test]
    fn poll_lazily_expires_past_deadline() {
        let t = CancelToken::with_deadline(Instant::now() - Duration::from_millis(1));
        assert_eq!(t.poll(), Some(CancelCause::Expired));
        let live = CancelToken::with_deadline(Instant::now() + Duration::from_secs(60));
        assert_eq!(live.poll(), None);
    }

    #[test]
    fn deadline_free_token_never_self_expires() {
        let t = CancelToken::new();
        assert_eq!(t.poll(), None);
        assert!(!t.is_cancelled());
    }

    #[test]
    fn explicit_fire_beats_later_expiry() {
        let t = CancelToken::with_deadline(Instant::now() - Duration::from_millis(1));
        assert!(t.cancel(CancelCause::Shutdown));
        assert_eq!(t.poll(), Some(CancelCause::Shutdown));
    }

    #[test]
    fn cause_and_stage_indices_roundtrip() {
        for i in 0..N_CAUSES {
            let c = CancelCause::from_index(i).expect("cause index");
            assert_eq!(c.index(), i);
            assert!(!c.as_str().is_empty());
        }
        for i in 0..N_STAGES {
            let s = CancelStage::from_index(i).expect("stage index");
            assert_eq!(s.index(), i);
            assert!(!s.as_str().is_empty());
        }
    }

    #[test]
    fn thread_local_current_token() {
        assert!(current().is_none());
        assert!(!current_cancelled());
        let t = CancelToken::new();
        set_current(Some(t.clone()));
        assert!(!current_cancelled());
        t.cancel(CancelCause::ClientGone);
        assert!(current_cancelled());
        let other = std::thread::spawn(current_cancelled).join().expect("join");
        assert!(!other, "current token must be thread-local");
        set_current(None);
        assert!(!current_cancelled());
    }
}
