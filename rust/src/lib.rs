//! # FLAME — serving system for large-scale generative recommendation
//!
//! Reproduction of *"FLAME: A Serving System Optimized for Large-Scale
//! Generative Recommendation with Efficiency"* (Guo et al., Netease Cloud
//! Music, 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the FLAME coordinator: the PDA feature pipeline
//!   (cached feature queries, NUMA binding, staging transfers), the DSO
//!   dynamic stream orchestrator (explicit-shape executor pools + descending
//!   batch-split routing), the dynamic batcher, and the request server.
//! * **L2/L1 (`python/compile`)** — the Climber-like GR model in JAX with
//!   mask-aware flash-attention and fused LN+FFN Pallas kernels, AOT-lowered
//!   to HLO text at build time (`make artifacts`).
//! * **Runtime (`runtime`)** — loads the HLO artifacts through the PJRT C
//!   API (`xla` crate) and executes them on the request path with
//!   device-resident weights. Python never runs at serve time.
//!
//! See `DESIGN.md` for the paper→module map and `EXPERIMENTS.md` for the
//! reproduced tables/figures.
//!
//! ## Cluster tier
//!
//! A single `ServingStack` stops well short of the paper's 1e10..1e12
//! requests/day envelope. The [`cluster`] module scales the system
//! horizontally: a [`cluster::ClusterRouter`] fronts N replicas with
//! pluggable placement (round-robin, least-loaded power-of-two-choices,
//! and cache-affinity consistent hashing on `user_id` that keeps each
//! replica's PDA feature cache warm for returning users),
//! deadline-aware admission control (service-time estimates from each
//! replica's rolling latency histogram; requests that cannot make their
//! SLA are re-routed or shed, counted in `shed_total` /
//! `sla_miss_total`), and consecutive-error replica ejection with timed
//! re-admission. The TCP front can bind either a single stack
//! (`server::tcp::TcpServer::start`) or a router
//! (`TcpServer::start_cluster`); `benches/bench_cluster.rs` compares
//! the policies under the paper's non-uniform candidate mix using the
//! artifact-free `cluster::SimReplica` backend.
//!
//! ## Result cache tier
//!
//! The PDA never fetches the same feature bytes twice; the analogous
//! cluster-tier waste is re-*scoring* an identical (user, candidate
//! set) that a replica just answered — the paper's non-uniform upstream
//! re-issues near-identical candidate sets within seconds. The router
//! therefore fronts placement/admission with a request-level result
//! cache ([`cluster::ResultCache`]): responses are cached under a short
//! TTL, keyed on the canonicalized (sorted) candidate ids plus user and
//! history, so a permuted duplicate still hits and has its score rows
//! remapped to its own candidate order. Concurrent identical misses are
//! **single-flight coalesced**: the first becomes the leader and
//! computes, duplicates wait (bounded by their deadline budget) and
//! share the result, and a failed leader wakes them to fall back to
//! their own dispatch. Hits and coalesced requests never touch a
//! replica; `result_hits` / `result_misses` / `result_coalesced` flow
//! through the [`metrics::Recorder`], `ClusterSnapshot`, the `flame
//! cluster` CLI report (`--result-cache-cap`, `--result-ttl-ms`,
//! `--no-coalesce`, `--dup-rate`), and the `bench_cluster` ablation
//! (off / cache / cache+single-flight under duplicate-burst traffic).
//!
//! ## DSO coalescing
//!
//! The DSO's explicit-shape splitting removes the pad-to-max waste, but
//! each request still executes alone: under the paper's non-uniform
//! upstream a 1-candidate request pads an entire smallest-profile launch
//! (127/128 rows wasted at the paper's scale) and every concurrent small
//! request pays its own engine launch. With `DsoConfig::coalesce` on,
//! the orchestrator's unit of execution becomes a *packed multi-request
//! batch*: per-profile pending slots collect the tail remainders of
//! concurrent requests, filling one profile-shaped launch with real rows
//! from several requests; the batch dispatches when full or when its
//! `coalesce_wait_us` deadline expires, so added latency stays bounded
//! inside the < 50 ms envelope. Engines expose a row-segmented interface
//! ([`dso::ComputeBackend::run_segmented`]) binding one history per
//! request segment, and executors demux each launch's score rows back to
//! the originating requests' reply channels — scores are bit-identical
//! to solo execution, in each request's own candidate order (property-
//! tested over random m-mixes and interleavings with the deterministic
//! [`dso::SimEngine`] backend). Chunk buffers are pooled, padding and
//! occupancy are tracked (`coalesced_rows`, occupancy histogram through
//! [`metrics::Recorder`]), and the ablation lives in `benches/bench_dso`
//! (`--m-dist uniform|bimodal|zipf`; CLI: `flame serve --coalesce
//! --coalesce-wait-us N --m-dist D`).
//!
//! ## Decoupled pipeline
//!
//! FLAME's headline architectural claim is CPU-GPU decoupling: feature
//! pre-processing runs concurrently with model computation so neither
//! stage idles the other (§3.1). With `ServerConfig::pipeline` on,
//! `ServingStack::spawn_pipeline` splits the serve path into two thread
//! pools around a bounded handoff queue ([`server::stages`]): N
//! feature-stage workers assemble requests into arenas drawn from a
//! shared [`pda::ArenaPool`] and hand them to M compute-stage submitters
//! that drive the DSO orchestrator — request B's feature fetch overlaps
//! request A's engine launch, and an arena returns to the pool only
//! after the orchestrator has consumed its views. Backpressure is a
//! chain of bounded resources: slow compute fills the handoff queue,
//! `push_blocking` stalls the feature workers, and the bounded intake
//! then sheds at admission. The stage wait is visible per response
//! (`Response::handoff_us`) and aggregated (`MetricsSnapshot::handoff_*`),
//! and arenas never grow in steady state (`arena_growths`, asserted in
//! tests). On the PDA side, `PdaConfig::fetch_coalesce` adds the
//! feature-miss coalescer ([`pda::fetch_coalescer`]): concurrent
//! requests' sync-mode cache misses single-flight per item id and pack
//! into shared remote multiget batches (per-shard pending slots, a
//! bounded `fetch_wait_us` deadline, and cross-shard merging at flush),
//! so K in-flight requests missing the same hot Zipf id pay one `Link`
//! round-trip instead of K. Scores are bit-identical to the synchronous
//! path in both modes (property-tested over random interleavings in
//! `tests/pipeline_stage.rs`); the sync/pipelined/pipelined+coalesce
//! ablation lives in `benches/bench_pipeline.rs` and emits
//! machine-readable `BENCH_pipeline.json` (CLI: `flame serve --pipeline
//! --feature-workers N --fetch-coalesce --fetch-wait-us T`).
//!
//! ## Native CPU FKE
//!
//! The Fused Kernel Engine is the paper's single largest win (4.6–6.1x
//! compute speedup, Table 4), and it is now a *real* compute backend,
//! not just an analytic registry: [`fke::cpu::CpuEngine`] executes the
//! Climber-like GR forward (per-block pre-LN transformer over
//! `[hist; candidates]` with the SUMI mask, gating fusion, expert head)
//! natively and multithreaded on the CPU, with the three Table-4
//! engine-construction levels selectable at runtime — `naive`
//! (per-op loops, materialized intermediates, cache-hostile GEMM order),
//! `api` (fused QKV, blocked vectorizable GEMM loops, scratch arenas,
//! no score-matrix materialization), and `fused` (mask-aware attention
//! tile skipping on the [`fke::attention_tile_stats`] schedule, fused
//! per-row LN+FFN tiles, one-pass score+reduce head). All variants run
//! the same math in the same per-element order, so `fused` is bit-exact
//! with `api` and within 1e-5 of `naive`. Crucially the engine is
//! **natively segmented**: `run_segmented` binds one history per row
//! segment inside a single launch, so a coalescer-packed mixed batch of
//! M rows executes M rows once (`executed_rows_for == M`) with scores
//! bit-identical to solo launches — closing the per-history replay gap
//! the PJRT emulation pays. Wired end to end: `flame serve|bind|cluster
//! --backend cpu --variant naive|api|fused --threads N` builds
//! artifact-free stacks (`--backend sim` for the queueing sim), engine
//! FLOP/tile counters flow through [`metrics::Recorder`]
//! (`fke_flops`, `fke_tiles_*`) into the serve report, and
//! `benches/bench_fke.rs` reproduces Table 4 as a
//! naive/api/fused × {base,long} × {solo, coalesced-mixed} ablation
//! emitting `BENCH_fke.json` (CI gates the fused-vs-naive ordering via
//! `--smoke`).
//!
//! ## Observability
//!
//! The aggregate [`metrics::Recorder`] cannot answer "why was *this*
//! request slow" once PRs 3–5 made the serve path asynchronous and
//! cross-request-entangled — a request's compute may run inside another
//! request's coalesced launch, and its feature fetch or whole response
//! may ride a single-flight leader. The [`obs`] module adds
//! request-scoped tracing kept off the hot path: when a
//! [`obs::Tracer`] is attached (`flame serve|cluster --trace-out
//! trace.json`, sampling via `--trace-sample-n` /
//! `ServerConfig::trace_sample_n`), every admitted request is stamped
//! with a [`obs::TraceContext`] at admission and per-stage spans
//! (queue / feature / handoff / compute / cache) are recorded through
//! the pipeline workers. Shared work emits *shared spans* with causal
//! links: a coalesced DSO/FKE launch records one launch span naming
//! every rider's trace id, and each rider's compute span links back to
//! the launch span id — even riders head sampling dropped stay on the
//! launch's member list. Completed traces land in bounded sharded
//! rings (newest win) with tail retention of SLA-miss and top-k-slowest
//! exemplars, each carrying an attribution verdict (the stage that
//! consumed the largest budget share) mirrored into
//! `MetricsSnapshot::sla_miss_*`. Export is twofold: Chrome
//! trace-event / Perfetto JSON ([`obs::export`], validated by `flame
//! trace-check`) with flow arrows for the cross-request links, and a
//! Prometheus-style text exposition of the live snapshot
//! ([`obs::prom`]) served by `--metrics-addr` and the TCP stats op.
//! With tracing off (`trace_sample_n = 0`) the request path sees one
//! `OnceLock::get` returning `None` — zero allocations, asserted by a
//! regression test.
//!
//! ## Robustness: chaos plane, degradation ladder, supervised recovery
//!
//! The paper's envelope — accurate results in tens of milliseconds over
//! 1e10..1e12 requests/day — only means something if it holds while
//! replicas brown out, feature stores stall, and workers die. The
//! [`chaos`] module is a crate-wide fault-injection plane: a seeded,
//! deterministic [`chaos::FaultPlan`] (CLI: `--chaos
//! "store_timeout:p=0.05,brownout:replica=1,x=8"`) that the feature
//! store, the sim replicas, the DSO executors, and the pipeline stages
//! consult through cheap armed-`OnceLock` injection points
//! ([`chaos::ChaosSlot`] — one `OnceLock::get` when unarmed, mirroring
//! the tracing hook). On top of it sit three behaviours:
//!
//! * **Degradation ladder** — every response carries a
//!   [`chaos::ServeQuality`] (Full → StaleFeatures → TruncatedCandidates
//!   → CachedResult → Shed): a store timeout serves stale/default
//!   features instead of erroring (the existing §3.1 stance, now
//!   surfaced per request), an over-budget request truncates its
//!   candidate set to the top-K that fit the remaining deadline, and
//!   the cluster tier adds budget-aware retry-with-backoff plus one
//!   hedged re-dispatch to a second replica when the picked one is
//!   browned out. Qualities, retries, and hedges are counted in the
//!   [`metrics::Recorder`] and stamped into traces.
//! * **Supervised recovery** — pipeline stage workers and DSO executors
//!   run each request under a supervisor (`catch_unwind` sites tagged
//!   `// lint: supervisor`, enforced by `flame lint`): a panic fails
//!   the in-flight request with a typed [`Error::WorkerPanic`] instead
//!   of wedging its reply channel, the worker body restarts
//!   (`worker_restarts` in the recorder), and replica re-admission is a
//!   half-open probe — one canary must succeed before full traffic.
//! * **No lost requests** — `tests/chaos.rs` drives the sim-backed
//!   stack through seeded fault storms (store timeouts + brownout +
//!   crash + injected worker panics) asserting that every submitted
//!   request resolves with a response or a typed error before its
//!   deadline-plus-grace, and that post-storm throughput recovers.
//!
//! ## Overload control: tenancy, feedback admission, storm scenarios
//!
//! The chaos plane breaks the system; the storm plane breaks the
//! *traffic*. Production recommendation traffic is multi-tenant (app
//! surfaces, partner integrations, backfill jobs) and its overloads are
//! correlated — flash crowds on a hot candidate set, feature-update
//! invalidation storms, diurnal swells. Three pieces make the cluster
//! tier survive them:
//!
//! * **Tenancy** — every [`workload::Request`] carries a
//!   [`workload::TenantId`]; [`cluster::TenantSet`] (CLI: `--tenants
//!   "t0:w=2,sla_ms=20,t1:w=1"`) gives each tenant a fair-share weight
//!   and an SLA override that admission and the pipeline intake apply
//!   per request. The [`metrics::Recorder`] keeps per-tenant
//!   requests/sheds/misses/latency/quality views
//!   ([`metrics::TenantCounts`]), surfaced in the cluster report, the
//!   serve report, and the Prometheus text endpoint.
//! * **Feedback-controlled admission** — the static admission estimate
//!   becomes a closed loop: [`cluster::OverloadController`] (CLI:
//!   `--controller`) runs a per-tenant AIMD at 50 ms ticks fed by each
//!   tenant's observed SLA-miss rate and the replica queue depth.
//!   Misses additively raise that tenant's p99-vs-mean blend in the
//!   admission estimator (pessimism where it is earned); a tenant over
//!   its weighted fair share under queue pressure takes gate
//!   degradation — candidate truncation first, then sheds — while clean
//!   windows decay both levels multiplicatively back to baseline
//!   (brownout recovery). The gate fns are `// lint: no_alloc` and a
//!   registry in the lint keeps them tagged.
//! * **Storm engine** — [`workload::storm::StormSpec`] (CLI: `--storm
//!   "flash:tenant=1,at_s=2,for_s=2,x=9,hot=64"`) deterministically
//!   expands diurnal/flash/invalidation/mix clauses into a timed event
//!   timeline (arrivals + `invalidate_user` calls) that the open-loop
//!   driver replays against a live cluster, or that `flame trace-gen`
//!   records as a versioned v2 trace for byte-identical A/B replay.
//!   `tests/storm.rs` enforces the isolation invariant on a seeded
//!   flash crowd: the quiet tenant's miss rate stays near its baseline
//!   while the flash tenant pays at the gate, the controller-off arm is
//!   measurably worse for the bystander, and the shed level decays to
//!   zero post-storm. `benches/bench_storm.rs` tracks the per-tenant
//!   cost A/B in `BENCH_storm.json`; see `EXPERIMENTS.md` § "Storm
//!   runbook".
//!
//! ## Deadline propagation and cooperative cancellation
//!
//! Admission control gates the front door, but until this layer a
//! `PipelineJob` that made it into the intake ran to completion no
//! matter what — a request whose deadline had already passed, whose
//! client hung up, or that lost its hedge race kept burning FLOPs that
//! a live request could have used (the classic goodput collapse under
//! overload). The [`cancel`] module threads a request-scoped
//! [`cancel::CancelToken`] — an `Arc`'d atomic cause cell
//! (`Expired | ClientGone | HedgeLoser | Shutdown`, first fire wins) —
//! from admission through every plane, checked at each stage boundary
//! so doomed work is dropped at the earliest cheap point with a typed
//! [`Error::Cancelled`]`(cause, stage)` reply, never silently:
//!
//! * **Intake / handoff** (`server::stages`): pops lazily purge
//!   expired/cancelled jobs before feature or compute work starts,
//!   returning staging arenas to the pool with exact accounting.
//! * **DSO** (`dso::coalescer` / `dso::orchestrator`): a cancelled
//!   rider's rows are evicted from a still-open pending batch (later
//!   rows shift down, admission units released one per evicted
//!   segment), and executors re-check tokens immediately before launch
//!   (an all-cancelled job skips the engine entirely). Riders already
//!   inside a flushed launch complete — score identity is untouched.
//! * **PDA** (`pda::fetch_coalescer`): a cancelled rider abandons its
//!   ticket wait (degrading to stale/default features) without
//!   disturbing leader/waiter semantics — tickets still resolve and the
//!   single-flight table never leaks entries.
//! * **Cluster** (`cluster`): the hedge loser is cancelled the moment
//!   the winner lands, so its late completion no longer pollutes the
//!   rolling sojourn estimator admission reads; remaining budget is
//!   checked before every retry re-dispatch.
//! * **TCP front** (`server::tcp`): detects client disconnect
//!   mid-request (`ClientGone`), rejects oversized frames with a typed
//!   error, applies a per-connection idle timeout, and drains
//!   gracefully (listener closed, in-flight requests finish).
//!
//! Expiry is *lazy* — no timers; each boundary calls
//! [`cancel::CancelToken::poll`], which stamps `Expired` once the
//! token's deadline passes. The knob is opt-in (`ServerConfig::cancel`,
//! `--cancel`): without it tokens carry no deadline and only explicit
//! fires are honored. Every drop is counted exactly once under
//! `cancelled_total{cause, stage}` plus a saved-work estimate
//! ([`metrics::Recorder::record_cancelled`], Prometheus
//! `flame_cancelled_total`), and `tests/cancel.rs` proves the headline
//! invariant: under a seeded flash crowd at ~2x capacity the
//! cancellation arm beats the no-cancel arm on completed-within-SLA
//! goodput, with zero leaked arenas or waiter entries.
//!
//! ## Concurrency invariants
//!
//! The serve path's concurrency is hand-rolled, and its correctness
//! rests on a small set of invariants that are *statically enforced* by
//! the self-hosted analyzer in [`lint`] (`flame lint`, run as a CI
//! gate). The invariants, and the checker that owns each:
//!
//! * **Lock order** (`lock-order`): a DSO coalescer per-profile slot
//!   lock is never held while taking the flusher `signal` mutex, and
//!   slot locks never nest ([`dso::coalescer`] module docs); likewise
//!   for the PDA fetch coalescer's per-shard locks vs its `signal`
//!   ([`pda::fetch_coalescer`]); cache shard locks never nest
//!   ([`cache`]). The flusher direction — `signal` held while scanning
//!   slots — is the allowed one. `flame lint --graph` dumps the
//!   inferred acquisition graph.
//! * **Condvar discipline** (`condvar`): every `Condvar::wait` /
//!   `wait_timeout` sits in a `while`/`loop` re-checking its predicate
//!   (spurious wakeups, racing notifies).
//! * **No-alloc hot path** (`no-alloc`): functions annotated
//!   `// lint: no_alloc` — the trace-off serve path that
//!   `tests/obs_zero_alloc.rs` guards at runtime, plus cache-hit
//!   paths — must not reach an allocating construct, directly or via
//!   same-crate callees.
//! * **Panic policy** (`panic`): `unwrap`/`expect`/`panic!` in
//!   `server/`, `dso/`, `pda/`, `cluster/`, `fke/` non-test code needs
//!   a `// lint: allow(panic) <reason>` tag; lock-guard unwraps prefer
//!   poison-tolerant `unwrap_or_else(|e| e.into_inner())` so one
//!   panicking worker cannot cascade into a hung flusher.
//! * **Unsafe hygiene** (`unsafe`): every `unsafe` carries a
//!   `// SAFETY:` comment stating the invariant it relies on.
//!
//! ## Quick start
//!
//! ```no_run
//! use flame::manifest::Manifest;
//! use flame::runtime::{Runtime, EngineKey};
//!
//! let manifest = Manifest::load("artifacts").unwrap();
//! let rt = Runtime::new().unwrap();
//! let engine = rt
//!     .load_engine(&manifest, &EngineKey::new("tiny", "fused", 8))
//!     .unwrap();
//! let hist = vec![0.0f32; 32 * 32];
//! let cands = vec![0.0f32; 8 * 32];
//! let scores = engine.run(&hist, &cands).unwrap();
//! assert_eq!(scores.len(), 8 * 3); // M x n_tasks
//! ```

// Curated crate-wide clippy allowances (everything else is `-D warnings`
// in CI): serving-config constructors legitimately take many knobs, and
// the channel/slot plumbing trades in honest-but-busy types.
#![allow(clippy::too_many_arguments, clippy::type_complexity)]

pub mod batching;
pub mod benchkit;
pub mod cache;
pub mod cancel;
pub mod chaos;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod dso;
pub mod embedding;
pub mod error;
pub mod featurestore;
pub mod fke;
pub mod lint;
pub mod manifest;
pub mod metrics;
pub mod netsim;
pub mod obs;
pub mod pda;
pub mod runtime;
pub mod server;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
