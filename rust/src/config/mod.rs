//! Configuration system: model/scenario specs (the rust mirror of
//! `python/compile/config.py`), serving-stack knobs (PDA, DSO, server,
//! workload), analytic FLOPs, and JSON config-file loading with flag
//! overrides.

pub mod flops;
pub mod model;
pub mod serving;

pub use model::{ModelConfig, Scenario};
pub use serving::{CacheMode, DsoMode, PdaConfig, DsoConfig, ServerConfig, WorkloadConfig, StackConfig};
