//! Serving-stack configuration: PDA, DSO, server, and workload knobs.
//! Each struct has paper-faithful defaults and can be loaded from a JSON
//! file (`StackConfig::from_json`) with per-field overrides — the ablation
//! arms in the benches are expressed as these configs.

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Feature-query caching mode (PDA §3.1, Fig 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheMode {
    /// No cache — every query goes to the remote store (Table 3 row 1).
    Off,
    /// Async stale-while-revalidate: expired/missing entries return
    /// immediately (stale or empty) and refresh in the background.
    Async,
    /// Sync: miss/expired blocks on the remote query (accuracy-preserving).
    Sync,
}

impl CacheMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(CacheMode::Off),
            "async" => Ok(CacheMode::Async),
            "sync" => Ok(CacheMode::Sync),
            o => Err(Error::Config(format!("unknown cache mode '{o}'"))),
        }
    }
}

/// DSO execution mode (§3.3, Table 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DsoMode {
    /// Implicit shape: one max-profile engine; every request is padded to
    /// the largest batch dimension (the runtime-dynamic baseline).
    ImplicitPad,
    /// Explicit shape: per-profile executors + descending batch splitting.
    Explicit,
}

impl DsoMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "implicit" | "pad" => Ok(DsoMode::ImplicitPad),
            "explicit" | "dso" => Ok(DsoMode::Explicit),
            o => Err(Error::Config(format!("unknown dso mode '{o}'"))),
        }
    }
}

/// PDA module configuration (§3.1).
#[derive(Clone, Debug)]
pub struct PdaConfig {
    pub cache_mode: CacheMode,
    /// LRU capacity in items (item-side cache, per the paper's choice).
    pub cache_capacity: usize,
    /// Cache shard (bucket) count — reduces write-lock collisions.
    pub cache_shards: usize,
    /// TTL for cached item features, in milliseconds.
    pub cache_ttl_ms: u64,
    /// Background refresh worker threads (async mode).
    pub refresh_workers: usize,
    /// NUMA-affinity core binding for pipeline workers ("Mem Opt" half 1).
    pub numa_binding: bool,
    /// Preallocated staging arenas for input assembly ("Mem Opt" half 2 —
    /// the pinned-memory analogue: batch many small feature copies into
    /// one contiguous transfer buffer).
    pub staging_arenas: bool,
    /// Cross-request feature-miss coalescing (sync cache mode): misses
    /// single-flight per item id and pack into shared remote multiget
    /// batches, so K concurrent requests missing the same hot id pay one
    /// round-trip instead of K.
    pub fetch_coalesce: bool,
    /// Upper bound (µs) a partially-filled miss batch waits for more ids
    /// before it is flushed — the added feature-latency bound per request.
    pub fetch_wait_us: u64,
}

impl Default for PdaConfig {
    fn default() -> Self {
        PdaConfig {
            cache_mode: CacheMode::Async,
            cache_capacity: 200_000,
            cache_shards: 16,
            cache_ttl_ms: 5_000,
            refresh_workers: 2,
            numa_binding: true,
            staging_arenas: true,
            fetch_coalesce: false,
            fetch_wait_us: 150,
        }
    }
}

impl PdaConfig {
    /// The Table 3 baseline: no cache, no memory optimizations.
    pub fn baseline() -> Self {
        PdaConfig {
            cache_mode: CacheMode::Off,
            numa_binding: false,
            staging_arenas: false,
            ..PdaConfig::default()
        }
    }

    /// The Table 3 middle arm: +Cache, -Mem Opt.
    pub fn cache_only() -> Self {
        PdaConfig { numa_binding: false, staging_arenas: false, ..PdaConfig::default() }
    }
}

/// DSO module configuration (§3.3).
#[derive(Clone, Debug)]
pub struct DsoConfig {
    pub mode: DsoMode,
    /// Executors per profile (the paper's "multiple CUDA streams per
    /// profile"); total executor threads = profiles x this.
    pub executors_per_profile: usize,
    /// Queue capacity before admission control sheds load.
    pub queue_capacity: usize,
    /// Cross-request batch coalescing: a request's tail remainder fills
    /// with real rows from other concurrent requests' remainders instead
    /// of padding, sharing one engine launch.
    pub coalesce: bool,
    /// Upper bound (µs) a partially-filled coalesce batch waits for more
    /// rows before it is flushed — the added-latency bound per request.
    pub coalesce_wait_us: u64,
}

impl Default for DsoConfig {
    fn default() -> Self {
        DsoConfig {
            mode: DsoMode::Explicit,
            executors_per_profile: 1,
            queue_capacity: 1024,
            coalesce: false,
            coalesce_wait_us: 200,
        }
    }
}

/// Server / pipeline configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Worker threads. Synchronous mode: each runs the whole request
    /// (feature + compute). Decoupled mode (`pipeline`): this is the
    /// compute-stage submitter count M.
    pub pipeline_workers: usize,
    /// Decoupled two-stage serving: feature-stage workers hand staged
    /// inputs over a bounded queue to compute-stage submitters, so one
    /// request's PDA work overlaps another's engine launch (the paper's
    /// CPU-GPU decoupling, §3.1).
    pub pipeline: bool,
    /// Feature-stage workers N (decoupled mode only).
    pub feature_workers: usize,
    /// Bounded handoff-queue depth between the stages; when it fills,
    /// feature workers stall and backpressure reaches intake admission.
    pub handoff_capacity: usize,
    /// Deadline-closest-first intake (decoupled mode): feature workers
    /// pop the queued request with the nearest deadline instead of FIFO,
    /// so a tight-deadline request overtakes slack ones under load.
    pub deadline_first: bool,
    /// TCP bind address for the network front (None = in-process only).
    pub bind_addr: Option<String>,
    /// Per-request deadline in ms (paper envelope: < 50 ms end-to-end).
    pub deadline_ms: u64,
    /// Head-sampling rate for request-scoped tracing: record full span
    /// timelines for 1-in-N admitted requests (0 = tracing disabled, the
    /// default — the hot path then allocates nothing for observability).
    /// SLA-miss exemplars are retained regardless of the sampling draw.
    pub trace_sample_n: u64,
    /// Degradation ladder (decoupled mode): a request whose remaining
    /// deadline cannot fit its full candidate set is truncated to the
    /// prefix that fits (`ServeQuality::TruncatedCandidates`) instead of
    /// missing its SLA with the full set. Off by default — callers that
    /// prefer late-but-complete answers keep them.
    pub truncate_over_budget: bool,
    /// Per-tenant deadline overrides (ms), indexed by `TenantId`; a
    /// tenant beyond the list (or a 0 entry) keeps `deadline_ms`. Empty
    /// by default: single-tenant behavior is byte-identical.
    pub tenant_deadline_ms: Vec<u64>,
    /// Cooperative cancellation: stamp each request's `CancelToken`
    /// with its deadline so stage boundaries lazily expire and purge
    /// doomed work (`Error::Cancelled`). Off by default — admitted
    /// requests then always run to completion; explicit fires
    /// (client-gone / hedge-loser / shutdown) are honored regardless.
    pub cancel: bool,
}

impl ServerConfig {
    /// Deadline budget (µs) for `tenant` — the per-tenant override when
    /// one is configured, the server default otherwise.
    pub fn tenant_budget_us(&self, tenant: crate::workload::TenantId) -> u64 {
        match self.tenant_deadline_ms.get(tenant.index()) {
            Some(&ms) if ms > 0 => ms.saturating_mul(1_000),
            _ => self.deadline_ms.saturating_mul(1_000),
        }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            pipeline_workers: 4,
            pipeline: false,
            feature_workers: 2,
            handoff_capacity: 8,
            deadline_first: false,
            bind_addr: None,
            deadline_ms: 50,
            trace_sample_n: 0,
            truncate_over_budget: false,
            tenant_deadline_ms: Vec::new(),
            cancel: false,
        }
    }
}

/// Synthetic-workload configuration (the production-traffic substitute;
/// DESIGN.md §Environment substitutions).
#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    /// Item catalog size.
    pub catalog_size: u64,
    /// Zipf exponent for item popularity (hot-item skew).
    pub zipf_theta: f64,
    /// User population.
    pub n_users: u64,
    /// Candidate-count mix: (m, weight) pairs. Uniform over the long
    /// profiles reproduces the paper's Table 5 mixed traffic.
    pub candidate_mix: Vec<(usize, f64)>,
    /// Open-loop arrival rate (requests/s); None = closed loop.
    pub arrival_rate: Option<f64>,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            catalog_size: 1_000_000,
            zipf_theta: 0.99,
            n_users: 100_000,
            candidate_mix: vec![(32, 1.0)],
            arrival_rate: None,
            seed: 42,
        }
    }
}

impl WorkloadConfig {
    /// Table 5's mixed traffic: M uniform over the scenario's profiles.
    pub fn uniform_mix(profiles: &[usize]) -> Vec<(usize, f64)> {
        profiles.iter().map(|&m| (m, 1.0)).collect()
    }
}

/// Top-level bundle loaded by the CLI / examples.
#[derive(Clone, Debug, Default)]
pub struct StackConfig {
    pub pda: PdaConfig,
    pub dso: DsoConfig,
    pub server: ServerConfig,
    pub workload: WorkloadConfig,
}

impl StackConfig {
    /// Parse from a JSON document; absent fields keep defaults.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = StackConfig::default();
        if let Some(p) = j.opt("pda") {
            if let Some(v) = p.opt("cache_mode") {
                c.pda.cache_mode = CacheMode::parse(v.as_str()?)?;
            }
            if let Some(v) = p.opt("cache_capacity") {
                c.pda.cache_capacity = v.as_usize()?;
            }
            if let Some(v) = p.opt("cache_shards") {
                c.pda.cache_shards = v.as_usize()?;
            }
            if let Some(v) = p.opt("cache_ttl_ms") {
                c.pda.cache_ttl_ms = v.as_u64()?;
            }
            if let Some(v) = p.opt("refresh_workers") {
                c.pda.refresh_workers = v.as_usize()?;
            }
            if let Some(v) = p.opt("numa_binding") {
                c.pda.numa_binding = v.as_bool()?;
            }
            if let Some(v) = p.opt("staging_arenas") {
                c.pda.staging_arenas = v.as_bool()?;
            }
            if let Some(v) = p.opt("fetch_coalesce") {
                c.pda.fetch_coalesce = v.as_bool()?;
            }
            if let Some(v) = p.opt("fetch_wait_us") {
                c.pda.fetch_wait_us = v.as_u64()?;
            }
        }
        if let Some(d) = j.opt("dso") {
            if let Some(v) = d.opt("mode") {
                c.dso.mode = DsoMode::parse(v.as_str()?)?;
            }
            if let Some(v) = d.opt("executors_per_profile") {
                c.dso.executors_per_profile = v.as_usize()?;
            }
            if let Some(v) = d.opt("queue_capacity") {
                c.dso.queue_capacity = v.as_usize()?;
            }
            if let Some(v) = d.opt("coalesce") {
                c.dso.coalesce = v.as_bool()?;
            }
            if let Some(v) = d.opt("coalesce_wait_us") {
                c.dso.coalesce_wait_us = v.as_u64()?;
            }
        }
        if let Some(s) = j.opt("server") {
            if let Some(v) = s.opt("pipeline_workers") {
                c.server.pipeline_workers = v.as_usize()?;
            }
            if let Some(v) = s.opt("pipeline") {
                c.server.pipeline = v.as_bool()?;
            }
            if let Some(v) = s.opt("feature_workers") {
                c.server.feature_workers = v.as_usize()?;
            }
            if let Some(v) = s.opt("handoff_capacity") {
                c.server.handoff_capacity = v.as_usize()?;
            }
            if let Some(v) = s.opt("deadline_first") {
                c.server.deadline_first = v.as_bool()?;
            }
            if let Some(v) = s.opt("bind_addr") {
                c.server.bind_addr = Some(v.as_str()?.to_string());
            }
            if let Some(v) = s.opt("deadline_ms") {
                c.server.deadline_ms = v.as_u64()?;
            }
            if let Some(v) = s.opt("trace_sample_n") {
                c.server.trace_sample_n = v.as_u64()?;
            }
            if let Some(v) = s.opt("truncate_over_budget") {
                c.server.truncate_over_budget = v.as_bool()?;
            }
            if let Some(v) = s.opt("tenant_deadline_ms") {
                let mut out = Vec::new();
                for e in v.as_arr()? {
                    out.push(e.as_u64()?);
                }
                c.server.tenant_deadline_ms = out;
            }
            if let Some(v) = s.opt("cancel") {
                c.server.cancel = v.as_bool()?;
            }
        }
        if let Some(w) = j.opt("workload") {
            if let Some(v) = w.opt("catalog_size") {
                c.workload.catalog_size = v.as_u64()?;
            }
            if let Some(v) = w.opt("zipf_theta") {
                c.workload.zipf_theta = v.as_f64()?;
            }
            if let Some(v) = w.opt("n_users") {
                c.workload.n_users = v.as_u64()?;
            }
            if let Some(v) = w.opt("arrival_rate") {
                c.workload.arrival_rate = Some(v.as_f64()?);
            }
            if let Some(v) = w.opt("seed") {
                c.workload.seed = v.as_u64()?;
            }
            if let Some(v) = w.opt("candidate_mix") {
                let mut mix = Vec::new();
                for e in v.as_arr()? {
                    let arr = e.as_arr()?;
                    if arr.len() != 2 {
                        return Err(Error::Config("candidate_mix entries are [m, weight]".into()));
                    }
                    mix.push((arr[0].as_usize()?, arr[1].as_f64()?));
                }
                c.workload.candidate_mix = mix;
            }
        }
        Ok(c)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(crate::error::io_err(path.display().to_string()))?;
        Self::from_json(&crate::util::json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn defaults_reasonable() {
        let c = StackConfig::default();
        assert_eq!(c.pda.cache_mode, CacheMode::Async);
        assert!(c.pda.numa_binding);
        assert!(!c.pda.fetch_coalesce, "miss coalescing is opt-in");
        assert!(c.pda.fetch_wait_us < 50_000, "fetch wait within the paper envelope");
        assert_eq!(c.dso.mode, DsoMode::Explicit);
        assert!(!c.dso.coalesce, "coalescing is opt-in");
        assert!(c.dso.coalesce_wait_us < 50_000, "wait bound within the paper envelope");
        assert!(!c.server.pipeline, "decoupled pipeline is opt-in");
        assert!(!c.server.deadline_first, "deadline-first intake is opt-in");
        assert!(!c.server.truncate_over_budget, "candidate truncation is opt-in");
        assert!(c.server.feature_workers >= 1);
        assert!(c.server.handoff_capacity >= 1);
        assert_eq!(c.server.deadline_ms, 50); // paper envelope
        assert_eq!(c.server.trace_sample_n, 0, "tracing is opt-in");
        assert!(c.server.tenant_deadline_ms.is_empty(), "tenant overrides are opt-in");
        assert!(!c.server.cancel, "cooperative cancellation is opt-in");
    }

    #[test]
    fn tenant_budget_overrides_and_falls_back() {
        use crate::workload::TenantId;
        let mut c = ServerConfig::default();
        assert_eq!(c.tenant_budget_us(TenantId(0)), 50_000);
        c.tenant_deadline_ms = vec![20, 0, 80];
        assert_eq!(c.tenant_budget_us(TenantId(0)), 20_000);
        assert_eq!(c.tenant_budget_us(TenantId(1)), 50_000, "0 entry keeps the default");
        assert_eq!(c.tenant_budget_us(TenantId(2)), 80_000);
        assert_eq!(c.tenant_budget_us(TenantId(5)), 50_000, "beyond the list = default");
    }

    #[test]
    fn ablation_arms() {
        assert_eq!(PdaConfig::baseline().cache_mode, CacheMode::Off);
        assert!(!PdaConfig::baseline().staging_arenas);
        let mid = PdaConfig::cache_only();
        assert_eq!(mid.cache_mode, CacheMode::Async);
        assert!(!mid.numa_binding);
    }

    #[test]
    fn json_overrides() {
        let j = parse(
            r#"{
            "pda": {"cache_mode": "sync", "cache_capacity": 10, "numa_binding": false,
                    "fetch_coalesce": true, "fetch_wait_us": 250},
            "dso": {"mode": "implicit", "executors_per_profile": 3,
                    "coalesce": true, "coalesce_wait_us": 500},
            "server": {"pipeline_workers": 8, "bind_addr": "127.0.0.1:7070",
                       "pipeline": true, "feature_workers": 3, "handoff_capacity": 16,
                       "deadline_first": true, "trace_sample_n": 4,
                       "tenant_deadline_ms": [20, 0, 80], "cancel": true},
            "workload": {"zipf_theta": 0.8, "candidate_mix": [[128, 1.0], [256, 1.0]]}
        }"#,
        )
        .unwrap();
        let c = StackConfig::from_json(&j).unwrap();
        assert_eq!(c.pda.cache_mode, CacheMode::Sync);
        assert_eq!(c.pda.cache_capacity, 10);
        assert!(!c.pda.numa_binding);
        assert!(c.pda.fetch_coalesce);
        assert_eq!(c.pda.fetch_wait_us, 250);
        assert_eq!(c.dso.mode, DsoMode::ImplicitPad);
        assert_eq!(c.dso.executors_per_profile, 3);
        assert!(c.dso.coalesce);
        assert_eq!(c.dso.coalesce_wait_us, 500);
        assert_eq!(c.server.pipeline_workers, 8);
        assert!(c.server.pipeline);
        assert_eq!(c.server.feature_workers, 3);
        assert_eq!(c.server.handoff_capacity, 16);
        assert!(c.server.deadline_first);
        assert_eq!(c.server.bind_addr.as_deref(), Some("127.0.0.1:7070"));
        assert_eq!(c.server.trace_sample_n, 4);
        assert_eq!(c.server.tenant_deadline_ms, vec![20, 0, 80]);
        assert!(c.server.cancel);
        assert_eq!(c.workload.candidate_mix, vec![(128, 1.0), (256, 1.0)]);
    }

    #[test]
    fn bad_modes_rejected() {
        assert!(CacheMode::parse("nope").is_err());
        assert!(DsoMode::parse("nope").is_err());
        let j = parse(r#"{"pda": {"cache_mode": "bogus"}}"#).unwrap();
        assert!(StackConfig::from_json(&j).is_err());
    }

    #[test]
    fn uniform_mix_builder() {
        let mix = WorkloadConfig::uniform_mix(&[128, 256, 512, 1024]);
        assert_eq!(mix.len(), 4);
        assert!(mix.iter().all(|&(_, w)| w == 1.0));
    }
}
