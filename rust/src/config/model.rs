//! Model/scenario configuration — the rust mirror of
//! `python/compile/config.py`. The authoritative copy of a scenario's
//! numbers at serve time is the artifact manifest (written by aot.py);
//! the built-in table here exists for tools that run before artifacts
//! are built (`flame info`) and is cross-checked against the manifest in
//! tests.

use crate::error::{Error, Result};

/// Static architecture + scenario parameters of one served model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Total user-history length L (split across blocks).
    pub seq_len: usize,
    /// Independent Transformer blocks N_b (Climber's sub-sequences).
    pub n_blocks: usize,
    pub layers_per_block: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_tasks: usize,
    /// Candidate-count profiles exported for DSO routing (ascending).
    pub m_profiles: Vec<usize>,
    /// Paper-native candidate count (Table 2 column).
    pub native_m: usize,
}

impl ModelConfig {
    /// History tokens per block (L / N_b).
    pub fn block_len(&self) -> usize {
        self.seq_len / self.n_blocks
    }

    /// FFN inner dimension (4x).
    pub fn d_ff(&self) -> usize {
        4 * self.d_model
    }

    /// Per-block sequence length for M candidates.
    pub fn n_tokens(&self, m: usize) -> usize {
        self.block_len() + m
    }

    /// Largest profile (the pad-to-max baseline's fixed shape).
    pub fn max_m(&self) -> usize {
        *self.m_profiles.iter().max().expect("non-empty profiles")
    }

    pub fn validate(&self) -> Result<()> {
        let check = |ok: bool, msg: &str| {
            if ok {
                Ok(())
            } else {
                Err(Error::Config(format!("{}: {msg}", self.name)))
            }
        };
        check(self.seq_len % self.n_blocks == 0, "seq_len % n_blocks != 0")?;
        check(self.d_model % self.n_heads == 0, "d_model % n_heads != 0")?;
        check(!self.m_profiles.is_empty(), "empty m_profiles")?;
        check(self.m_profiles.contains(&self.native_m), "native_m not in profiles")?;
        check(
            self.m_profiles.windows(2).all(|w| w[0] < w[1]),
            "m_profiles not strictly ascending",
        )?;
        Ok(())
    }
}

/// The four scenario tiers (see DESIGN.md §3 / paper Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scenario {
    Tiny,
    Bench,
    Base,
    Long,
}

impl Scenario {
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Tiny => "tiny",
            Scenario::Bench => "bench",
            Scenario::Base => "base",
            Scenario::Long => "long",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "tiny" => Ok(Scenario::Tiny),
            "bench" => Ok(Scenario::Bench),
            "base" => Ok(Scenario::Base),
            "long" => Ok(Scenario::Long),
            other => Err(Error::Config(format!("unknown scenario '{other}'"))),
        }
    }

    pub fn all() -> [Scenario; 4] {
        [Scenario::Tiny, Scenario::Bench, Scenario::Base, Scenario::Long]
    }

    /// Built-in spec table (mirror of python SCENARIOS).
    pub fn config(&self) -> ModelConfig {
        match self {
            Scenario::Tiny => ModelConfig {
                name: "tiny".into(),
                seq_len: 32,
                n_blocks: 2,
                layers_per_block: 2,
                d_model: 32,
                n_heads: 2,
                n_tasks: 3,
                m_profiles: vec![4, 8],
                native_m: 8,
            },
            Scenario::Bench => ModelConfig {
                name: "bench".into(),
                seq_len: 128,
                n_blocks: 2,
                layers_per_block: 3,
                d_model: 64,
                n_heads: 4,
                n_tasks: 3,
                m_profiles: vec![16, 32, 64, 128],
                native_m: 32,
            },
            Scenario::Base => ModelConfig {
                name: "base".into(),
                seq_len: 512,
                n_blocks: 2,
                layers_per_block: 12,
                d_model: 128,
                n_heads: 8,
                n_tasks: 3,
                m_profiles: vec![32, 64, 128],
                native_m: 128,
            },
            Scenario::Long => ModelConfig {
                name: "long".into(),
                seq_len: 1024,
                n_blocks: 2,
                layers_per_block: 12,
                d_model: 128,
                n_heads: 8,
                n_tasks: 3,
                m_profiles: vec![128, 256, 512, 1024],
                native_m: 512,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_builtin_configs_valid() {
        for s in Scenario::all() {
            let c = s.config();
            c.validate().unwrap();
            assert_eq!(c.name, s.name());
        }
    }

    #[test]
    fn parse_roundtrip() {
        for s in Scenario::all() {
            assert_eq!(Scenario::parse(s.name()).unwrap(), s);
        }
        assert!(Scenario::parse("huge").is_err());
    }

    #[test]
    fn derived_dims() {
        let c = Scenario::Long.config();
        assert_eq!(c.block_len(), 512);
        assert_eq!(c.d_ff(), 512);
        assert_eq!(c.n_tokens(512), 1024);
        assert_eq!(c.max_m(), 1024);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut c = Scenario::Tiny.config();
        c.seq_len = 33;
        assert!(c.validate().is_err());

        let mut c = Scenario::Tiny.config();
        c.native_m = 5;
        assert!(c.validate().is_err());

        let mut c = Scenario::Tiny.config();
        c.m_profiles = vec![8, 4];
        assert!(c.validate().is_err());
    }
}
