//! Analytic FLOP accounting — the rust mirror of
//! `python/compile/config.py`'s counters. The manifest carries the
//! python-computed numbers; tests assert both sides agree exactly, so a
//! drift in either copy of the formula fails CI.

use super::ModelConfig;

/// Dense-attention FLOPs for one layer over n tokens, hidden d:
/// QKV projection + QK^T scores + AV + output projection.
pub fn attention_flops(n: u64, d: u64) -> u64 {
    2 * n * d * 3 * d + 2 * n * n * d + 2 * n * n * d + 2 * n * d * d
}

/// FFN FLOPs for one layer: two GEMMs through d_ff = f.
pub fn ffn_flops(n: u64, d: u64, f: u64) -> u64 {
    2 * n * d * f + 2 * n * f * d
}

/// Analytic per-request FLOPs of the dense forward with M candidates —
/// the paper's Table 2 "FLOPS" column.
pub fn model_flops(cfg: &ModelConfig, m: usize) -> u64 {
    let n = cfg.n_tokens(m) as u64;
    let (d, f, t) = (cfg.d_model as u64, cfg.d_ff() as u64, cfg.n_tasks as u64);
    let m = m as u64;
    let nb = cfg.n_blocks as u64;
    let per_layer = attention_flops(n, d) + ffn_flops(n, d, f);
    let mut total = nb * cfg.layers_per_block as u64 * per_layer;
    total += 2 * m * (nb * d) * (nb * d); // gating fusion GEMM
    total += 2 * m * d * f + 2 * m * f * t; // expert MLP
    total
}

/// Score+AV FLOPs actually needed under the SUMI mask (per layer) — what
/// the mask-aware L1 kernel approaches via tile skipping.
pub fn masked_attention_score_flops(cfg: &ModelConfig, m: usize) -> u64 {
    let (lb, d) = (cfg.block_len() as u64, cfg.d_model as u64);
    let m = m as u64;
    let hist = lb * (lb + 1) / 2;
    let cand = m * (lb + 1);
    4 * (hist + cand) * d
}

/// The paper's Table 1 operating envelope, for `flame info`.
pub fn envelope_summary(cfg: &ModelConfig) -> String {
    let fl = model_flops(cfg, cfg.native_m);
    format!(
        "scenario {}: {:.2e} FLOPs/request at native M={} (paper GR range 1e9..1e11; DLRM range 1e6..1e7)",
        cfg.name, fl as f64, cfg.native_m
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;

    #[test]
    fn tiny_matches_python_constant() {
        // python: model_flops(SCENARIOS['tiny'], 8) == 2_791_424
        // (asserted against the manifest in integration tests too).
        let c = Scenario::Tiny.config();
        assert_eq!(model_flops(&c, 8), 2_791_424);
    }

    #[test]
    fn paper_order_of_magnitude() {
        let base = Scenario::Base.config();
        let long = Scenario::Long.config();
        let fb = model_flops(&base, base.native_m) as f64;
        let fl = model_flops(&long, long.native_m) as f64;
        assert!(fb > 1e9 && fb < 1e10, "base {fb:.2e}");
        assert!(fl > 1e10 && fl < 1e11, "long {fl:.2e}");
        assert!(fl > 3.0 * fb, "long should be several times base");
    }

    #[test]
    fn flops_monotone_in_m() {
        let c = Scenario::Bench.config();
        let mut last = 0;
        for &m in &c.m_profiles {
            let f = model_flops(&c, m);
            assert!(f > last);
            last = f;
        }
    }

    #[test]
    fn masked_fraction_below_dense() {
        let c = Scenario::Long.config();
        let m = 512;
        let n = c.n_tokens(m) as u64;
        let dense_scores = 4 * n * n * c.d_model as u64;
        let masked = masked_attention_score_flops(&c, m);
        let frac = masked as f64 / dense_scores as f64;
        // candidates don't attend to each other: roughly half the tiles die
        assert!(frac < 0.6, "masked fraction {frac}");
        assert!(frac > 0.2, "masked fraction {frac}");
    }
}
