//! The PDA's feature cache (§3.1, Fig 5): a TTL'd LRU, sharded into
//! buckets to reduce write-lock collisions, with hit/stale/miss
//! statistics. The async (stale-while-revalidate) and sync query flows
//! are built on top in `pda::engine`.

pub mod lru;
pub mod sharded;

pub use lru::{Entry, LruCache, Lookup};
pub use sharded::ShardedCache;

/// Cache statistics counters (lock-free).
#[derive(Default)]
pub struct CacheStats {
    pub hits: std::sync::atomic::AtomicU64,
    pub stale_hits: std::sync::atomic::AtomicU64,
    pub misses: std::sync::atomic::AtomicU64,
    pub inserts: std::sync::atomic::AtomicU64,
    pub evictions: std::sync::atomic::AtomicU64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        use std::sync::atomic::Ordering::Relaxed;
        let h = self.hits.load(Relaxed) + self.stale_hits.load(Relaxed);
        let total = h + self.misses.load(Relaxed);
        if total == 0 {
            0.0
        } else {
            h as f64 / total as f64
        }
    }

    pub fn fresh_hit_rate(&self) -> f64 {
        use std::sync::atomic::Ordering::Relaxed;
        let h = self.hits.load(Relaxed);
        let total = h + self.stale_hits.load(Relaxed) + self.misses.load(Relaxed);
        if total == 0 {
            0.0
        } else {
            h as f64 / total as f64
        }
    }

    pub fn snapshot(&self) -> (u64, u64, u64, u64, u64) {
        use std::sync::atomic::Ordering::Relaxed;
        (
            self.hits.load(Relaxed),
            self.stale_hits.load(Relaxed),
            self.misses.load(Relaxed),
            self.inserts.load(Relaxed),
            self.evictions.load(Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::Relaxed;

    #[test]
    fn hit_rate_math() {
        let s = CacheStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        s.hits.store(80, Relaxed);
        s.stale_hits.store(10, Relaxed);
        s.misses.store(10, Relaxed);
        assert!((s.hit_rate() - 0.9).abs() < 1e-12);
        assert!((s.fresh_hit_rate() - 0.8).abs() < 1e-12);
    }
}
