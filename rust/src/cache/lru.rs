//! TTL'd LRU cache over u64 keys — intrusive doubly-linked list over a
//! slab of entries + a HashMap index. O(1) get/insert/evict, no
//! per-operation allocation after warmup (slots are recycled), which
//! keeps the feature-query hot path allocation-free.

use std::collections::HashMap;
use std::time::{Duration, Instant};

const NIL: usize = usize::MAX;

/// A cached value plus its freshness metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry<V> {
    pub value: V,
    pub inserted: Instant,
}

/// Result of a cache lookup with TTL semantics (Fig 5's three arms).
#[derive(Clone, Debug, PartialEq)]
pub enum Lookup<V> {
    /// Unexpired hit — use directly.
    Fresh(V),
    /// Expired hit — the async flow returns it and refreshes in the
    /// background; the sync flow treats it as a miss.
    Stale(V),
    /// Not present.
    Miss,
}

impl<V> Lookup<V> {
    pub fn is_fresh(&self) -> bool {
        matches!(self, Lookup::Fresh(_))
    }
    pub fn is_miss(&self) -> bool {
        matches!(self, Lookup::Miss)
    }
}

struct Slot<V> {
    key: u64,
    /// `None` only while the slot sits on the free list — a removed
    /// entry must not keep its value alive until the slot is recycled.
    value: Option<V>,
    inserted: Instant,
    prev: usize,
    next: usize,
}

/// Single-shard LRU with TTL. Not thread-safe by itself; wrap in
/// `ShardedCache` for concurrent use.
pub struct LruCache<V> {
    map: HashMap<u64, usize>,
    slots: Vec<Slot<V>>,
    free: Vec<usize>,
    head: usize, // most recent
    tail: usize, // least recent
    capacity: usize,
    ttl: Duration,
    pub evictions: u64,
}

impl<V: Clone> LruCache<V> {
    pub fn new(capacity: usize, ttl: Duration) -> Self {
        assert!(capacity > 0);
        LruCache {
            map: HashMap::with_capacity(capacity),
            slots: Vec::with_capacity(capacity.min(4096)),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            capacity,
            ttl,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn detach(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev != NIL {
            self.slots[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next].prev = prev;
        } else {
            self.tail = prev;
        }
        self.slots[i].prev = NIL;
        self.slots[i].next = NIL;
    }

    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Look up with TTL classification; fresh hits are promoted to MRU.
    pub fn get(&mut self, key: u64, now: Instant) -> Lookup<V> {
        match self.map.get(&key).copied() {
            None => Lookup::Miss,
            Some(i) => {
                let age = now.saturating_duration_since(self.slots[i].inserted);
                let v = self.slots[i].value.clone().expect("indexed slot holds a value");
                if age <= self.ttl {
                    self.detach(i);
                    self.push_front(i);
                    Lookup::Fresh(v)
                } else {
                    // stale entries are not promoted: if nothing refreshes
                    // them they age out toward the LRU tail.
                    Lookup::Stale(v)
                }
            }
        }
    }

    /// Fresh-hit zero-clone read: runs `f` on a borrow of the value and
    /// promotes the entry to MRU, without cloning `V`. Returns
    /// `(Some(r), true)` on a fresh hit, `(None, true)` when the key is
    /// present but stale (not promoted, `f` not called — sync-flow
    /// callers treat stale as a miss), and `(None, false)` on a miss.
    /// The copy-into read path `ShardedCache::with_fresh` builds on this
    /// so a hot-row lookup can write straight into a staging arena slice
    /// with zero allocation.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn with_fresh<R>(
        &mut self,
        key: u64,
        now: Instant,
        f: impl FnOnce(&V) -> R,
    ) -> (Option<R>, bool) {
        match self.map.get(&key).copied() {
            None => (None, false),
            Some(i) => {
                let age = now.saturating_duration_since(self.slots[i].inserted);
                if age > self.ttl {
                    return (None, true);
                }
                self.detach(i);
                self.push_front(i);
                let v = self.slots[i].value.as_ref().expect("indexed slot holds a value");
                (Some(f(v)), true)
            }
        }
    }

    /// Insert/update a key (counts as a refresh: TTL restarts).
    pub fn insert(&mut self, key: u64, value: V, now: Instant) {
        if let Some(&i) = self.map.get(&key) {
            self.slots[i].value = Some(value);
            self.slots[i].inserted = now;
            self.detach(i);
            self.push_front(i);
            return;
        }
        // Reclaim fully-expired entries from the LRU tail. Stale reads are
        // never promoted, so dead entries sink toward the tail — but
        // without this sweep a never-refreshed entry would occupy its
        // slot (and pin its value) forever.
        while self.tail != NIL
            && now.saturating_duration_since(self.slots[self.tail].inserted) > self.ttl
        {
            let t = self.tail;
            self.detach(t);
            self.map.remove(&self.slots[t].key);
            self.slots[t].value = None;
            self.free.push(t);
            self.evictions += 1;
        }
        let i = if self.map.len() >= self.capacity {
            // evict LRU tail and reuse its slot
            let victim = self.tail;
            debug_assert_ne!(victim, NIL);
            self.detach(victim);
            self.map.remove(&self.slots[victim].key);
            self.evictions += 1;
            victim
        } else if let Some(i) = self.free.pop() {
            i
        } else {
            self.slots.push(Slot { key: 0, value: None, inserted: now, prev: NIL, next: NIL });
            self.slots.len() - 1
        };
        self.slots[i].key = key;
        self.slots[i].value = Some(value);
        self.slots[i].inserted = now;
        self.push_front(i);
        self.map.insert(key, i);
    }

    /// Remove a key (used by tests and invalidation paths). The value is
    /// dropped immediately — the free list must not park it alive.
    pub fn remove(&mut self, key: u64) -> bool {
        if let Some(i) = self.map.remove(&key) {
            self.detach(i);
            self.slots[i].value = None;
            self.free.push(i);
            true
        } else {
            false
        }
    }

    /// Keys from most- to least-recently-used (diagnostics/tests).
    pub fn keys_mru(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut i = self.head;
        while i != NIL {
            out.push(self.slots[i].key);
            i = self.slots[i].next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn now() -> Instant {
        Instant::now()
    }

    #[test]
    fn insert_get_fresh() {
        let mut c = LruCache::new(4, Duration::from_secs(60));
        let t = now();
        c.insert(1, "a", t);
        assert_eq!(c.get(1, t), Lookup::Fresh("a"));
        assert_eq!(c.get(2, t), Lookup::Miss);
    }

    #[test]
    fn ttl_expiry_returns_stale() {
        let mut c = LruCache::new(4, Duration::from_millis(10));
        let t = now();
        c.insert(1, "a", t);
        let later = t + Duration::from_millis(50);
        assert_eq!(c.get(1, later), Lookup::Stale("a"));
        // refresh restores freshness
        c.insert(1, "b", later);
        assert_eq!(c.get(1, later), Lookup::Fresh("b"));
    }

    #[test]
    fn evicts_lru_order() {
        let mut c = LruCache::new(3, Duration::from_secs(60));
        let t = now();
        c.insert(1, 1, t);
        c.insert(2, 2, t);
        c.insert(3, 3, t);
        // touch 1 so 2 becomes LRU
        let _ = c.get(1, t);
        c.insert(4, 4, t);
        assert_eq!(c.get(2, t), Lookup::Miss);
        assert!(c.get(1, t).is_fresh());
        assert_eq!(c.evictions, 1);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn update_moves_to_front_and_replaces() {
        let mut c = LruCache::new(2, Duration::from_secs(60));
        let t = now();
        c.insert(1, "a", t);
        c.insert(2, "b", t);
        c.insert(1, "a2", t); // update
        c.insert(3, "c", t); // evicts 2 (LRU)
        assert_eq!(c.get(1, t), Lookup::Fresh("a2"));
        assert_eq!(c.get(2, t), Lookup::Miss);
    }

    #[test]
    fn remove_frees_slot() {
        let mut c = LruCache::new(2, Duration::from_secs(60));
        let t = now();
        c.insert(1, 1, t);
        assert!(c.remove(1));
        assert!(!c.remove(1));
        assert_eq!(c.get(1, t), Lookup::Miss);
        assert_eq!(c.len(), 0);
        c.insert(2, 2, t);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut c = LruCache::new(8, Duration::from_secs(60));
        let t = now();
        for k in 0..100 {
            c.insert(k, k, t);
            assert!(c.len() <= 8);
        }
        assert_eq!(c.evictions, 92);
    }

    #[test]
    fn mru_order_tracks_access() {
        let mut c = LruCache::new(4, Duration::from_secs(60));
        let t = now();
        for k in 1..=3 {
            c.insert(k, k, t);
        }
        let _ = c.get(1, t);
        assert_eq!(c.keys_mru(), vec![1, 3, 2]);
    }

    #[test]
    fn insert_reclaims_expired_tails() {
        let mut c = LruCache::new(8, Duration::from_millis(10));
        let t = now();
        for k in 0..4 {
            c.insert(k, k, t);
        }
        assert_eq!(c.len(), 4);
        // all four entries expire; the next insert must sweep them out
        // instead of letting them occupy slots forever
        let later = t + Duration::from_millis(50);
        c.insert(100, 100, later);
        assert_eq!(c.len(), 1, "expired entries still occupy slots");
        assert_eq!(c.evictions, 4);
        for k in 0..4 {
            assert_eq!(c.get(k, later), Lookup::Miss);
        }
        assert!(c.get(100, later).is_fresh());
    }

    #[test]
    fn remove_drops_value_immediately() {
        let v = std::sync::Arc::new(7u8);
        let mut c = LruCache::new(4, Duration::from_secs(60));
        let t = now();
        c.insert(1, std::sync::Arc::clone(&v), t);
        assert_eq!(std::sync::Arc::strong_count(&v), 2);
        assert!(c.remove(1));
        // the free-listed slot must not park the old value alive
        assert_eq!(std::sync::Arc::strong_count(&v), 1, "removed value leaked in free list");
    }

    #[test]
    fn with_fresh_hits_promote_without_clone() {
        let mut c = LruCache::new(3, Duration::from_millis(10));
        let t = now();
        c.insert(1, 7u32, t);
        c.insert(2, 8u32, t);
        let (r, present) = c.with_fresh(1, t, |v| *v * 10);
        assert_eq!((r, present), (Some(70), true));
        assert_eq!(c.keys_mru(), vec![1, 2], "fresh with_fresh promotes to MRU");
        // stale: present but f not run
        let later = t + Duration::from_millis(50);
        let (r, present) = c.with_fresh(1, later, |v| *v);
        assert_eq!((r, present), (None, true));
        // miss
        let (r, present) = c.with_fresh(99, t, |v| *v);
        assert_eq!((r, present), (None, false));
    }

    #[test]
    fn stale_not_promoted() {
        let mut c = LruCache::new(2, Duration::from_millis(1));
        let t = now();
        c.insert(1, 1, t);
        c.insert(2, 2, t);
        let later = t + Duration::from_millis(10);
        // stale read of 1 must not move it ahead of 2
        let _ = c.get(1, later);
        c.insert(3, 3, later); // should evict 1 (still LRU)
        assert_eq!(c.get(1, later), Lookup::Miss);
    }
}
