//! Bucket-sharded concurrent cache — the paper's "divided into multiple
//! buckets to reduce write lock collisions" (§3.1). Each shard is an
//! independently locked `LruCache`; keys hash to shards, so concurrent
//! pipeline workers rarely contend on the same mutex.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::lru::{Lookup, LruCache};
use super::CacheStats;

/// Thread-safe sharded TTL-LRU over u64 keys.
pub struct ShardedCache<V> {
    shards: Vec<Mutex<LruCache<V>>>,
    mask_bits: u32,
    pub stats: CacheStats,
}

impl<V: Clone> ShardedCache<V> {
    /// `capacity` is total across shards; `shards` is rounded up to a
    /// power of two (and down so no shard ends up with zero slots). The
    /// shard capacities sum to exactly `capacity.max(1)`: the division
    /// remainder is spread one slot each over the leading shards rather
    /// than silently dropped, and `capacity < shards` shrinks the shard
    /// count instead of over-allocating a slot per shard.
    pub fn new(capacity: usize, shards: usize, ttl: Duration) -> Self {
        let capacity = capacity.max(1);
        let mut n = shards.max(1).next_power_of_two();
        while n > capacity {
            n /= 2;
        }
        let (base, rem) = (capacity / n, capacity % n);
        let shards = (0..n)
            .map(|i| Mutex::new(LruCache::new(base + usize::from(i < rem), ttl)))
            .collect();
        ShardedCache { shards, mask_bits: n.trailing_zeros(), stats: CacheStats::default() }
    }

    /// Total capacity across shards — exactly the `capacity` given to
    /// [`ShardedCache::new`] (clamped to ≥ 1).
    pub fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().capacity()).sum()
    }

    #[inline]
    // lint: no_alloc — per-request hot path, must stay allocation-free
    fn shard_of(&self, key: u64) -> usize {
        // multiplicative hash; take the high bits for shard selection
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        (h >> (64 - self.mask_bits.max(1))) as usize & (self.shards.len() - 1)
    }

    /// Lookup with stats accounting.
    pub fn get(&self, key: u64) -> Lookup<V> {
        use std::sync::atomic::Ordering::Relaxed;
        let now = Instant::now();
        let mut shard = self.shards[self.shard_of(key)].lock().unwrap();
        let r = shard.get(key, now);
        match &r {
            Lookup::Fresh(_) => self.stats.hits.fetch_add(1, Relaxed),
            Lookup::Stale(_) => self.stats.stale_hits.fetch_add(1, Relaxed),
            Lookup::Miss => self.stats.misses.fetch_add(1, Relaxed),
        };
        r
    }

    /// Fresh-hit zero-clone read path: run `f` on a borrow of the cached
    /// value under the shard lock (promoting it to MRU) and return its
    /// result; `None` on stale/miss. Unlike [`ShardedCache::get`], the
    /// value is never cloned — the hot-row embedding lookup uses this to
    /// copy straight into an arena slice with zero allocation. Stats are
    /// accounted exactly as `get` would (fresh → hit, stale → stale hit,
    /// absent → miss).
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn with_fresh<R>(&self, key: u64, f: impl FnOnce(&V) -> R) -> Option<R> {
        use std::sync::atomic::Ordering::Relaxed;
        let now = Instant::now();
        let mut shard = self.shards[self.shard_of(key)].lock().unwrap();
        let (r, present) = shard.with_fresh(key, now, f);
        drop(shard);
        match (&r, present) {
            (Some(_), _) => self.stats.hits.fetch_add(1, Relaxed),
            (None, true) => self.stats.stale_hits.fetch_add(1, Relaxed),
            (None, false) => self.stats.misses.fetch_add(1, Relaxed),
        };
        r
    }

    pub fn insert(&self, key: u64, value: V) {
        use std::sync::atomic::Ordering::Relaxed;
        let now = Instant::now();
        let mut shard = self.shards[self.shard_of(key)].lock().unwrap();
        let before = shard.evictions;
        shard.insert(key, value, now);
        let evicted = shard.evictions - before;
        drop(shard);
        self.stats.inserts.fetch_add(1, Relaxed);
        if evicted > 0 {
            self.stats.evictions.fetch_add(evicted, Relaxed);
        }
    }

    pub fn remove(&self, key: u64) -> bool {
        self.shards[self.shard_of(key)].lock().unwrap().remove(key)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn basic_get_insert() {
        let c: ShardedCache<u32> = ShardedCache::new(64, 4, Duration::from_secs(60));
        assert!(c.get(1).is_miss());
        c.insert(1, 11);
        assert_eq!(c.get(1), Lookup::Fresh(11));
        let (h, _, m, i, _) = c.stats.snapshot();
        assert_eq!((h, m, i), (1, 1, 1));
    }

    #[test]
    fn shards_rounded_to_pow2() {
        let c: ShardedCache<u32> = ShardedCache::new(64, 5, Duration::from_secs(60));
        assert_eq!(c.n_shards(), 8);
    }

    #[test]
    fn capacity_remainder_distributed_not_lost() {
        // 100/16 = 6 r 4 — four shards get 7 slots, twelve get 6; the
        // old integer division silently served only 96
        let c: ShardedCache<u8> = ShardedCache::new(100, 16, Duration::from_secs(60));
        assert_eq!(c.n_shards(), 16);
        assert_eq!(c.capacity(), 100);
    }

    #[test]
    fn tiny_capacity_never_over_allocates() {
        // capacity < shards used to allocate 1 slot per shard (16 total);
        // the shard count must shrink instead
        let c: ShardedCache<u8> = ShardedCache::new(3, 16, Duration::from_secs(60));
        assert_eq!(c.capacity(), 3);
        assert!(c.n_shards() <= 3, "{} shards for capacity 3", c.n_shards());
    }

    /// Regression for the per-lookup allocation: `get` clones the value
    /// on every hit; `with_fresh` must not clone at all — the embedding
    /// hot path copies rows straight into the arena through it.
    #[test]
    fn with_fresh_never_clones_the_value() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        #[derive(Debug)]
        struct CloneCounter(u32, Arc<AtomicUsize>);
        impl Clone for CloneCounter {
            fn clone(&self) -> Self {
                self.1.fetch_add(1, Ordering::Relaxed);
                CloneCounter(self.0, Arc::clone(&self.1))
            }
        }

        let clones = Arc::new(AtomicUsize::new(0));
        let c: ShardedCache<CloneCounter> = ShardedCache::new(16, 2, Duration::from_secs(60));
        c.insert(1, CloneCounter(42, Arc::clone(&clones)));
        let baseline = clones.load(Ordering::Relaxed);
        for _ in 0..10 {
            assert_eq!(c.with_fresh(1, |v| v.0), Some(42));
        }
        assert_eq!(clones.load(Ordering::Relaxed), baseline, "with_fresh cloned the value");
        assert!(c.with_fresh(2, |v| v.0).is_none());
        let (h, _, m, _, _) = c.stats.snapshot();
        assert_eq!((h, m), (10, 1), "with_fresh must keep stats accounting");
    }

    #[test]
    fn keys_spread_across_shards() {
        let c: ShardedCache<u64> = ShardedCache::new(1 << 16, 16, Duration::from_secs(60));
        let mut used = vec![false; c.n_shards()];
        for k in 0..1000u64 {
            used[c.shard_of(k)] = true;
        }
        assert!(used.iter().all(|&b| b), "some shard never hit: {used:?}");
    }

    #[test]
    fn concurrent_mixed_workload() {
        let c: Arc<ShardedCache<u64>> =
            Arc::new(ShardedCache::new(4096, 16, Duration::from_secs(60)));
        let hs: Vec<_> = (0..8)
            .map(|t| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for i in 0..5_000u64 {
                        let k = (t * 31 + i) % 2048;
                        if i % 3 == 0 {
                            c.insert(k, k * 2);
                        } else if let Lookup::Fresh(v) = c.get(k) {
                            assert_eq!(v, k * 2);
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert!(c.len() <= 4096);
    }

    #[test]
    fn eviction_stats_counted() {
        let c: ShardedCache<u64> = ShardedCache::new(16, 2, Duration::from_secs(60));
        for k in 0..200 {
            c.insert(k, k);
        }
        let (_, _, _, ins, ev) = c.stats.snapshot();
        assert_eq!(ins, 200);
        assert!(ev > 0);
        assert!(c.len() <= 16);
    }
}
