//! The end-to-end request pipeline — FLAME's decoupled architecture in
//! one object:
//!
//! ```text
//! Request ──feature stage (PDA: cached query → embed → staging)──▶
//!          tensors ──compute stage (DSO: split → executors → PJRT)──▶
//!          scores ──response packaging──▶ Response
//! ```
//!
//! `ServingStack::serve` is the synchronous per-request path used by the
//! pipeline workers; `ServingStack::spawn_workers` wires a `RequestQueue`
//! in front (admission + queueing telemetry) for the open-loop mode.

use std::sync::Arc;
use std::time::Instant;

use crate::batching::RequestQueue;
use crate::config::{StackConfig};
use crate::dso::Orchestrator;
use crate::embedding::EmbeddingTable;
use crate::error::Result;
use crate::featurestore::{FeatureSchema, RemoteStore};
use crate::manifest::Manifest;
use crate::metrics::Recorder;
use crate::netsim::{Link, LinkConfig};
use crate::pda::numa::Topology;
use crate::pda::{InputAssembler, QueryEngine, StagingArena};
use crate::runtime::Runtime;
use crate::workload::Request;

/// A scored response.
#[derive(Clone, Debug)]
pub struct Response {
    pub request_id: u64,
    /// [M * n_tasks] task probabilities, request candidate order.
    pub scores: Vec<f32>,
    pub m: usize,
    pub overall_us: u64,
    pub compute_us: u64,
    pub feature_us: u64,
    /// Executor-queue delay before the first DSO chunk started, µs.
    pub queue_us: u64,
}

/// Builder wiring the whole stack from a manifest + config.
pub struct StackBuilder {
    pub config: StackConfig,
    pub scenario: String,
    pub variant: String,
    pub link: Option<Arc<Link>>,
}

impl StackBuilder {
    pub fn new(scenario: &str, variant: &str, config: StackConfig) -> Self {
        StackBuilder { config, scenario: scenario.into(), variant: variant.into(), link: None }
    }

    /// Inject a shared link (benches want to read its byte counters).
    pub fn with_link(mut self, link: Arc<Link>) -> Self {
        self.link = Some(link);
        self
    }

    pub fn build(self, runtime: &Runtime, manifest: &Manifest) -> Result<ServingStack> {
        let sa = manifest.scenario(&self.scenario)?;
        let model_cfg = sa.config.clone();

        // PDA side
        let link = self
            .link
            .unwrap_or_else(|| Arc::new(Link::new(LinkConfig::default())));
        let store = Arc::new(RemoteStore::new(FeatureSchema::default(), Arc::clone(&link), sa.seed));
        let query = Arc::new(QueryEngine::new(&self.config.pda, Arc::clone(&store)));
        let table = Arc::new(EmbeddingTable::new(model_cfg.d_model, sa.seed ^ 0xE5, 64 * 1024));
        let assembler = Arc::new(InputAssembler::new(
            Arc::clone(&table),
            Arc::clone(&query),
            self.config.pda.staging_arenas,
        ));

        // DSO side — the orchestrator mirrors coalescer occupancy into
        // the stack's recorder, so it is created first and shared.
        let metrics = Arc::new(Recorder::new());
        let engines = runtime.load_profile_set(manifest, &self.scenario, &self.variant)?;
        let orchestrator =
            Arc::new(Orchestrator::with_recorder(engines, &self.config.dso, Arc::clone(&metrics))?);

        Ok(ServingStack {
            config: self.config,
            model_cfg,
            assembler,
            query,
            orchestrator,
            link,
            store,
            metrics,
            topology: Topology::detect(),
        })
    }
}

/// The assembled serving stack.
pub struct ServingStack {
    pub config: StackConfig,
    pub model_cfg: crate::config::ModelConfig,
    pub assembler: Arc<InputAssembler>,
    pub query: Arc<QueryEngine>,
    pub orchestrator: Arc<Orchestrator>,
    pub link: Arc<Link>,
    pub store: Arc<RemoteStore>,
    pub metrics: Arc<Recorder>,
    pub topology: Topology,
}

impl ServingStack {
    /// Staging-arena capacity (f32 elements) a serve path needs: the
    /// padded history plus the largest candidate profile. Every caller
    /// that allocates an arena for `serve` must size it with this.
    pub fn arena_capacity(&self) -> usize {
        (self.model_cfg.seq_len + self.orchestrator.max_profile()) * self.model_cfg.d_model
    }

    /// Serve one request synchronously (the per-worker hot path).
    /// `arena` is the calling worker's staging arena (reused).
    pub fn serve(&self, req: &Request, arena: &mut StagingArena) -> Result<Response> {
        thread_local! {
            /// Worker-local scratch for the L-padded history ids — the
            /// hot path must not clone + resize a fresh Vec per request.
            static HIST_SCRATCH: std::cell::RefCell<Vec<u64>> =
                std::cell::RefCell::new(Vec::new());
        }
        let t0 = Instant::now();

        // ---- feature stage (PDA) ----
        let tf = Instant::now();
        let l = self.model_cfg.seq_len;
        let assembled = HIST_SCRATCH.with(|scratch| {
            let mut history = scratch.borrow_mut();
            history.clear();
            history.extend_from_slice(&req.history[..req.history.len().min(l)]);
            history.resize(l, 0); // pad short histories to L
            self.assembler.assemble(&history, &req.candidates, arena)
        });
        let (hist, cands) = assembled.views(arena);
        let feature_us = tf.elapsed().as_micros() as u64;

        // ---- compute stage (DSO) ----
        // the orchestrator uploads hist to the device once and shares the
        // buffer across split chunks (§Perf: no host-side copy either).
        let outcome = self.orchestrator.submit_slice(hist, cands, req.m())?;

        let overall_us = t0.elapsed().as_micros() as u64;
        self.metrics.record_request(overall_us, req.m());
        self.metrics.record_compute(outcome.compute_us);
        self.metrics.record_feature(feature_us);
        // executor-queue delay (Recorder.queueing's definition: delay
        // before an executor picked the job up)
        self.metrics.record_queueing(outcome.queue_us);

        Ok(Response {
            request_id: req.request_id,
            scores: outcome.scores,
            m: req.m(),
            overall_us,
            compute_us: outcome.compute_us,
            feature_us,
            queue_us: outcome.queue_us,
        })
    }

    /// Spawn `n` pipeline workers draining `queue`; each gets its own
    /// staging arena and (optionally) a NUMA-pinned CPU. Returns join
    /// handles; workers exit when the queue closes.
    pub fn spawn_workers(
        self: &Arc<Self>,
        queue: Arc<RequestQueue<Request>>,
        n: usize,
    ) -> Vec<std::thread::JoinHandle<()>> {
        let topo = self.topology.clone();
        (0..n.max(1))
            .map(|i| {
                let stack = Arc::clone(self);
                let queue = Arc::clone(&queue);
                let cpu = topo.cpu_for_worker(i);
                std::thread::Builder::new()
                    .name(format!("pipeline-{i}"))
                    .spawn(move || {
                        if stack.config.pda.numa_binding {
                            let _ = crate::pda::numa::pin_current_thread(cpu);
                        }
                        let mut arena = StagingArena::new(stack.arena_capacity());
                        while let Some((req, qdelay)) = queue.pop() {
                            stack.metrics.record_queueing(qdelay.as_micros() as u64);
                            if let Err(e) = stack.serve(&req, &mut arena) {
                                stack.metrics.record_dropped();
                                log::warn!("request {} failed: {e}", req.request_id);
                            }
                        }
                    })
                    .expect("spawn pipeline worker")
            })
            .collect()
    }

    /// Network utilization snapshot (MB/s since stack start).
    pub fn network_mb_per_s(&self) -> f64 {
        self.link.utilization_mb_per_s()
    }

    /// Closed-loop saturation driver: `concurrency` threads each serve
    /// the next request synchronously (own staging arena, optional NUMA
    /// pin) until `duration` elapses or the list is exhausted. This is
    /// the fair way to probe an arm's max throughput — every thread has
    /// exactly one request in flight, so no queueing noise enters the
    /// latency numbers.
    pub fn drive_closed_loop(
        self: &Arc<Self>,
        requests: &[Request],
        concurrency: usize,
        duration: std::time::Duration,
    ) -> crate::workload::driver::DriveReport {
        use std::sync::atomic::{AtomicU64, Ordering};
        let next = AtomicU64::new(0);
        let completed = AtomicU64::new(0);
        let rejected = AtomicU64::new(0);
        let start = Instant::now();
        let n = requests.len() as u64;
        let topo = self.topology.clone();
        std::thread::scope(|s| {
            for w in 0..concurrency.max(1) {
                let stack = Arc::clone(self);
                let next = &next;
                let completed = &completed;
                let rejected = &rejected;
                let cpu = topo.cpu_for_worker(w);
                s.spawn(move || {
                    if stack.config.pda.numa_binding {
                        let _ = crate::pda::numa::pin_current_thread(cpu);
                    }
                    let mut arena = StagingArena::new(stack.arena_capacity());
                    loop {
                        if start.elapsed() >= duration {
                            return;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            return;
                        }
                        match stack.serve(&requests[i as usize], &mut arena) {
                            Ok(_) => completed.fetch_add(1, Ordering::Relaxed),
                            Err(_) => rejected.fetch_add(1, Ordering::Relaxed),
                        };
                    }
                });
            }
        });
        crate::workload::driver::DriveReport {
            submitted: next.load(Ordering::Relaxed).min(n),
            completed: completed.load(Ordering::Relaxed),
            rejected: rejected.load(Ordering::Relaxed),
            elapsed: start.elapsed(),
        }
    }
}
