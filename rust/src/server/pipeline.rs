//! The end-to-end request pipeline — FLAME's decoupled architecture in
//! one object:
//!
//! ```text
//! Request ──feature stage (PDA: cached query → embed → staging)──▶
//!          tensors ──compute stage (DSO: split → executors → PJRT)──▶
//!          scores ──response packaging──▶ Response
//! ```
//!
//! `ServingStack::serve` is the synchronous per-request path used by the
//! pipeline workers; `ServingStack::spawn_workers` wires a `RequestQueue`
//! in front (admission + queueing telemetry) for the open-loop mode, and
//! `ServingStack::spawn_pipeline` starts the decoupled two-stage mode
//! (see [`super::stages`]) where feature and compute work overlap.

use std::sync::Arc;
use std::time::Instant;

use crate::batching::RequestQueue;
use crate::chaos::{ChaosSlot, FaultPlan, ServeQuality};
use crate::config::{ModelConfig, StackConfig};
use crate::dso::{ComputeBackend, Orchestrator};
use crate::embedding::EmbeddingTable;
use crate::error::{Error, Result};
use crate::featurestore::{FeatureSchema, RemoteStore};
use crate::manifest::Manifest;
use crate::metrics::Recorder;
use crate::netsim::{Link, LinkConfig};
use crate::obs::{self, StageKind};
use crate::pda::numa::Topology;
use crate::pda::{InputAssembler, QueryEngine, StagingArena};
use crate::runtime::Runtime;
use crate::workload::Request;

/// A scored response.
#[derive(Clone, Debug)]
pub struct Response {
    pub request_id: u64,
    /// [M * n_tasks] task probabilities, request candidate order.
    pub scores: Vec<f32>,
    pub m: usize,
    pub overall_us: u64,
    pub compute_us: u64,
    pub feature_us: u64,
    /// Executor-queue delay before the first DSO chunk started, µs.
    pub queue_us: u64,
    /// Decoupled-pipeline stage wait: time the staged input sat in the
    /// handoff queue between the feature stage finishing and a compute
    /// submitter picking it up, µs. Always 0 on the synchronous path —
    /// a nonzero value is the visible cost (and proof) of the two-stage
    /// split; mean/p99 aggregates live in `MetricsSnapshot::handoff_*`.
    pub handoff_us: u64,
    /// Where this response sits on the degradation ladder
    /// ([`ServeQuality::Full`] on a healthy stack). A degraded rung is
    /// an explicit contract with the caller: the scores are usable but
    /// were produced from stale/default features, a truncated candidate
    /// set, or a cached result.
    pub quality: ServeQuality,
}

/// Builder wiring the whole stack from a manifest + config.
pub struct StackBuilder {
    pub config: StackConfig,
    pub scenario: String,
    pub variant: String,
    pub link: Option<Arc<Link>>,
    pub metrics: Option<Arc<Recorder>>,
}

impl StackBuilder {
    pub fn new(scenario: &str, variant: &str, config: StackConfig) -> Self {
        StackBuilder {
            config,
            scenario: scenario.into(),
            variant: variant.into(),
            link: None,
            metrics: None,
        }
    }

    /// Inject a shared link (benches want to read its byte counters).
    pub fn with_link(mut self, link: Arc<Link>) -> Self {
        self.link = Some(link);
        self
    }

    /// Inject a pre-built recorder. Backends that mirror counters into a
    /// recorder at construction time (e.g. `fke::cpu::CpuEngine`) need
    /// the same instance the stack will report from.
    pub fn with_metrics(mut self, metrics: Arc<Recorder>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    pub fn build(self, runtime: &Runtime, manifest: &Manifest) -> Result<ServingStack> {
        let sa = manifest.scenario(&self.scenario)?;
        let model_cfg = sa.config.clone();
        let seed = sa.seed;
        let engines = runtime.load_profile_set(manifest, &self.scenario, &self.variant)?;
        let backends: Vec<Arc<dyn ComputeBackend>> = engines
            .into_iter()
            .map(|e| Arc::new(e) as Arc<dyn ComputeBackend>)
            .collect();
        self.wire(model_cfg, seed, backends)
    }

    /// Artifact-free assembly over explicit compute backends (e.g.
    /// [`crate::dso::SimEngine`]) — identical wiring to [`StackBuilder::build`],
    /// no PJRT runtime or manifest needed. Tests and benches use this to
    /// exercise the full serve path (PDA → handoff → DSO) on a bare
    /// checkout.
    pub fn build_from_backends(
        self,
        model_cfg: ModelConfig,
        seed: u64,
        backends: Vec<Arc<dyn ComputeBackend>>,
    ) -> Result<ServingStack> {
        // every backend must agree with the model config (the
        // orchestrator cross-checks d_model/n_tasks between backends but
        // never hist_len) — a mismatch must be a build-time Config error,
        // not a per-request failure at serve time
        let hist_len = model_cfg.seq_len * model_cfg.d_model;
        for b in &backends {
            if b.d_model() != model_cfg.d_model || b.hist_len() != hist_len {
                return Err(Error::Config(format!(
                    "backend {} shape disagrees with model config (d={}, L={})",
                    b.label(),
                    model_cfg.d_model,
                    model_cfg.seq_len
                )));
            }
        }
        self.wire(model_cfg, seed, backends)
    }

    fn wire(
        self,
        model_cfg: ModelConfig,
        seed: u64,
        backends: Vec<Arc<dyn ComputeBackend>>,
    ) -> Result<ServingStack> {
        // The recorder is shared by all three layers (PDA fetch
        // coalescer, DSO batch coalescer, request accounting), so it is
        // created first — or taken from the builder when the caller
        // already wired backends to one.
        let metrics = self.metrics.unwrap_or_else(|| Arc::new(Recorder::new()));

        // PDA side
        let link = self
            .link
            .unwrap_or_else(|| Arc::new(Link::new(LinkConfig::default())));
        let store = Arc::new(RemoteStore::new(FeatureSchema::default(), Arc::clone(&link), seed));
        let query = Arc::new(QueryEngine::new_with_recorder(
            &self.config.pda,
            Arc::clone(&store),
            Some(Arc::clone(&metrics)),
        ));
        let table = Arc::new(EmbeddingTable::new(model_cfg.d_model, seed ^ 0xE5, 64 * 1024));
        let assembler = Arc::new(InputAssembler::new(
            Arc::clone(&table),
            Arc::clone(&query),
            self.config.pda.staging_arenas,
        ));

        // DSO side
        let orchestrator = Arc::new(Orchestrator::from_backends(
            backends,
            &self.config.dso,
            Some(Arc::clone(&metrics)),
        )?);

        Ok(ServingStack {
            config: self.config,
            model_cfg,
            assembler,
            query,
            orchestrator,
            link,
            store,
            metrics,
            topology: Topology::detect(),
            chaos: ChaosSlot::new(),
            pair_cost_ns: std::sync::atomic::AtomicU64::new(0),
        })
    }
}

/// The assembled serving stack.
pub struct ServingStack {
    pub config: StackConfig,
    pub model_cfg: crate::config::ModelConfig,
    pub assembler: Arc<InputAssembler>,
    pub query: Arc<QueryEngine>,
    pub orchestrator: Arc<Orchestrator>,
    pub link: Arc<Link>,
    pub store: Arc<RemoteStore>,
    pub metrics: Arc<Recorder>,
    pub topology: Topology,
    /// Fault-injection point: worker-panic schedules for the stage
    /// workers plus compute-backend stalls (`chaos` module docs).
    pub(crate) chaos: ChaosSlot,
    /// EWMA of compute cost per user-item pair (ns), fed by finished
    /// compute outcomes — the estimate deadline-aware candidate
    /// truncation divides the remaining budget by (0 = no sample yet).
    pair_cost_ns: std::sync::atomic::AtomicU64,
}

impl ServingStack {
    /// Arm the whole stack's fault-injection points with one plan: the
    /// stage workers (panic schedules), the remote feature store
    /// (delay/error/timeout), and the DSO orchestrator (executor stalls
    /// and panics) all consult the same seeded [`FaultPlan`].
    pub fn arm_chaos(&self, plan: Arc<FaultPlan>) {
        self.store.arm_chaos(Arc::clone(&plan));
        self.orchestrator.arm_chaos(Arc::clone(&plan));
        self.chaos.arm(plan);
    }

    /// Feed one finished compute outcome into the per-pair cost EWMA.
    pub(crate) fn note_pair_cost(&self, compute_us: u64, m: usize) {
        if m == 0 {
            return;
        }
        let sample = compute_us.saturating_mul(1_000) / m as u64;
        use std::sync::atomic::Ordering;
        let _ = self.pair_cost_ns.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |old| {
            Some(if old == 0 { sample } else { (old * 7 + sample) / 8 })
        });
    }

    /// Estimated compute cost per user-item pair, ns (0 = no sample yet).
    pub(crate) fn pair_cost_ns(&self) -> u64 {
        self.pair_cost_ns.load(std::sync::atomic::Ordering::Relaxed)
    }
    /// Staging-arena capacity (f32 elements) a serve path needs: the
    /// padded history plus the largest candidate profile. Every caller
    /// that allocates an arena for `serve` must size it with this.
    pub fn arena_capacity(&self) -> usize {
        (self.model_cfg.seq_len + self.orchestrator.max_profile()) * self.model_cfg.d_model
    }

    /// Serve one request synchronously (the per-worker hot path).
    /// `arena` is the calling worker's staging arena (reused).
    pub fn serve(&self, req: &Request, arena: &mut StagingArena) -> Result<Response> {
        let t0 = Instant::now();
        // tracing costs one OnceLock::get returning None when off
        let mut trace = self
            .metrics
            .trace_begin(req.request_id, self.config.server.deadline_ms * 1_000);
        if let Some(ctx) = trace.as_ref() {
            obs::set_current_trace(ctx.trace_id());
        }

        // ---- feature stage (PDA) ----
        let tf = Instant::now();
        let growth0 = arena.growth_count();
        let assembled =
            self.assembler
                .assemble_request(&req.history, self.model_cfg.seq_len, &req.candidates, arena);
        let grew = arena.growth_count() - growth0;
        if grew > 0 {
            self.metrics.record_arena_growth(grew);
        }
        // stale/default features are still well-formed input, but the
        // response must say so — the first rung of the ladder
        let quality = if assembled.stale + assembled.missing > 0 {
            ServeQuality::StaleFeatures
        } else {
            ServeQuality::Full
        };
        let (hist, cands) = assembled.views(arena);
        let feature_us = tf.elapsed().as_micros() as u64;
        if let Some(ctx) = trace.as_mut() {
            ctx.span_ending_now(StageKind::Feature, feature_us);
            obs::set_current_trace(0);
        }

        // ---- compute stage (DSO) ----
        // the orchestrator uploads hist to the device once and shares the
        // buffer across split chunks (§Perf: no host-side copy either).
        let trace_id = trace.as_ref().map_or(0, |c| c.trace_id());
        let compute_begin = trace.as_ref().map_or(0, |c| c.now_us());
        let outcome = match self.orchestrator.submit_traced(hist, cands, req.m(), trace_id) {
            Ok(o) => o,
            Err(e) => {
                if let Some(ctx) = trace.take() {
                    let sla = ctx.budget_us() > 0 && ctx.elapsed_us() > ctx.budget_us();
                    self.metrics.trace_finish(ctx, sla);
                }
                return Err(e);
            }
        };

        let overall_us = t0.elapsed().as_micros() as u64;
        self.metrics.record_request(overall_us, req.m());
        self.metrics.record_quality(quality);
        self.metrics.record_compute(outcome.compute_us);
        self.metrics.record_feature(feature_us);
        // executor-queue delay (Recorder.queueing's definition: delay
        // before an executor picked the job up)
        self.metrics.record_queueing(outcome.queue_us);
        if let Some(mut ctx) = trace.take() {
            let end = ctx.now_us();
            ctx.span_linked(StageKind::Compute, compute_begin, end, &outcome.launch_ids);
            let sla = ctx.budget_us() > 0 && ctx.elapsed_us() > ctx.budget_us();
            self.metrics.trace_finish(ctx, sla);
        }

        Ok(Response {
            request_id: req.request_id,
            scores: outcome.scores,
            m: req.m(),
            overall_us,
            compute_us: outcome.compute_us,
            feature_us,
            queue_us: outcome.queue_us,
            handoff_us: 0,
            quality,
        })
    }

    /// Spawn `n` pipeline workers draining `queue`; each gets its own
    /// staging arena and (optionally) a NUMA-pinned CPU. Returns join
    /// handles; workers exit when the queue closes.
    pub fn spawn_workers(
        self: &Arc<Self>,
        queue: Arc<RequestQueue<Request>>,
        n: usize,
    ) -> Vec<std::thread::JoinHandle<()>> {
        let topo = self.topology.clone();
        (0..n.max(1))
            .map(|i| {
                let stack = Arc::clone(self);
                let queue = Arc::clone(&queue);
                let cpu = topo.cpu_for_worker(i);
                std::thread::Builder::new()
                    .name(format!("pipeline-{i}"))
                    .spawn(move || {
                        if stack.config.pda.numa_binding {
                            let _ = crate::pda::numa::pin_current_thread(cpu);
                        }
                        let mut arena = StagingArena::new(stack.arena_capacity());
                        while let Some((req, qdelay)) = queue.pop() {
                            stack.metrics.record_queueing(qdelay.as_micros() as u64);
                            // lint: supervisor — a panicking request must
                            // not take the worker (and its queue share)
                            // down with it; fail it and keep draining
                            let served = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| {
                                    if let Some(plan) = stack.chaos.get() {
                                        if plan.panic_due(crate::chaos::PanicSite::Feature) {
                                            // lint: allow(panic) chaos injection, caught by the supervisor above
                                            panic!("chaos: injected pipeline-worker panic");
                                        }
                                    }
                                    stack.serve(&req, &mut arena)
                                }),
                            );
                            match served {
                                Ok(Ok(_)) => {}
                                Ok(Err(e)) => {
                                    stack.metrics.record_dropped();
                                    log::warn!("request {} failed: {e}", req.request_id);
                                }
                                Err(_) => {
                                    stack.metrics.record_worker_restart();
                                    stack.metrics.record_dropped();
                                    log::warn!(
                                        "request {} failed: worker panicked (supervised)",
                                        req.request_id
                                    );
                                }
                            }
                        }
                    })
                    // lint: allow(panic) worker spawn at startup: failing to spawn is unrecoverable
                    .expect("spawn pipeline worker")
            })
            .collect()
    }

    /// Start the decoupled two-stage pipeline (paper §3.1's CPU-GPU
    /// decoupling): `config.server.feature_workers` feature-stage
    /// workers and `config.server.pipeline_workers` compute-stage
    /// submitters around a bounded handoff queue, arenas drawn from a
    /// shared pool. See [`super::stages::PipelineHandle`].
    pub fn spawn_pipeline(self: &Arc<Self>) -> super::stages::PipelineHandle {
        super::stages::PipelineHandle::spawn(Arc::clone(self))
    }

    /// Network utilization snapshot (MB/s since stack start).
    pub fn network_mb_per_s(&self) -> f64 {
        self.link.utilization_mb_per_s()
    }

    /// Closed-loop saturation driver: `concurrency` threads each serve
    /// the next request synchronously (own staging arena, optional NUMA
    /// pin) until `duration` elapses or the list is exhausted. This is
    /// the fair way to probe an arm's max throughput — every thread has
    /// exactly one request in flight, so no queueing noise enters the
    /// latency numbers.
    pub fn drive_closed_loop(
        self: &Arc<Self>,
        requests: &[Request],
        concurrency: usize,
        duration: std::time::Duration,
    ) -> crate::workload::driver::DriveReport {
        use std::sync::atomic::{AtomicU64, Ordering};
        let next = AtomicU64::new(0);
        let completed = AtomicU64::new(0);
        let rejected = AtomicU64::new(0);
        let start = Instant::now();
        let n = requests.len() as u64;
        let topo = self.topology.clone();
        std::thread::scope(|s| {
            for w in 0..concurrency.max(1) {
                let stack = Arc::clone(self);
                let next = &next;
                let completed = &completed;
                let rejected = &rejected;
                let cpu = topo.cpu_for_worker(w);
                s.spawn(move || {
                    if stack.config.pda.numa_binding {
                        let _ = crate::pda::numa::pin_current_thread(cpu);
                    }
                    let mut arena = StagingArena::new(stack.arena_capacity());
                    loop {
                        if start.elapsed() >= duration {
                            return;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            return;
                        }
                        match stack.serve(&requests[i as usize], &mut arena) {
                            Ok(_) => completed.fetch_add(1, Ordering::Relaxed),
                            Err(_) => rejected.fetch_add(1, Ordering::Relaxed),
                        };
                    }
                });
            }
        });
        crate::workload::driver::DriveReport {
            submitted: next.load(Ordering::Relaxed).min(n),
            completed: completed.load(Ordering::Relaxed),
            rejected: rejected.load(Ordering::Relaxed),
            elapsed: start.elapsed(),
        }
    }
}
