//! TCP front: a length-prefixed binary protocol so out-of-process
//! clients can drive the stack (examples/tcp_serve.rs; also the
//! server_tcp integration test).
//!
//! Frame = u32 LE length + payload. Request payload:
//!   u32 magic 'FLRQ' | u64 request_id | u64 user_id |
//!   u32 n_hist | u64*n_hist | u32 n_cand | u64*n_cand
//! Response payload:
//!   u32 magic 'FLRS' | u64 request_id | u32 status (0 ok) |
//!   u32 m | u32 n_tasks | f32*(m*n_tasks) | u64 overall_us
//! Status 1 = overloaded, 2 = error, 3 = cancelled (deadline expired /
//! request dropped as doomed work).
//!
//! Stats op (live metrics without interrupting the serve stream):
//!   request  = u32 magic 'FLST'
//!   response = u32 magic 'FLST' | string (u32 len + UTF-8) carrying the
//!              Prometheus-style text exposition of the frontend's
//!              current [`MetricsSnapshot`](crate::metrics::MetricsSnapshot).

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cancel::{CancelCause, CancelStage};
use crate::cluster::ClusterRouter;
use crate::error::{Error, Result};
use crate::pda::StagingArena;
use crate::server::pipeline::{Response, ServingStack};
use crate::server::stages::PipelineHandle;
use crate::util::bytes::{read_frame, write_frame, Builder, Cursor};
use crate::workload::Request;

pub const REQ_MAGIC: u32 = 0x464C_5251; // "FLRQ"
pub const RSP_MAGIC: u32 = 0x464C_5253; // "FLRS"
pub const STATS_MAGIC: u32 = 0x464C_5354; // "FLST"
const MAX_FRAME: usize = 64 << 20;

/// A connection that stays completely silent this long is closed (it
/// holds a thread; a hostile or wedged peer must not pin it forever).
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(60);

/// Encode a stats-request frame payload (magic only).
pub fn encode_stats_request() -> Vec<u8> {
    let mut b = Builder::new();
    b.u32(STATS_MAGIC);
    b.finish()
}

/// Encode a stats-response frame payload.
pub fn encode_stats_response(exposition: &str) -> Vec<u8> {
    let mut b = Builder::new();
    b.u32(STATS_MAGIC).string(exposition);
    b.finish()
}

/// Decode a stats-response frame payload into the exposition text.
pub fn decode_stats_response(buf: &[u8]) -> Result<String> {
    let mut c = Cursor::new(buf);
    if c.u32()? != STATS_MAGIC {
        return Err(Error::Protocol("bad stats magic".into()));
    }
    let text = c.string()?;
    if c.remaining() != 0 {
        return Err(Error::Protocol("trailing bytes in stats response".into()));
    }
    Ok(text)
}

/// Encode a request frame payload.
pub fn encode_request(r: &Request) -> Vec<u8> {
    let mut b = Builder::new();
    b.u32(REQ_MAGIC).u64(r.request_id).u64(r.user_id);
    b.u32(r.history.len() as u32);
    for &id in &r.history {
        b.u64(id);
    }
    b.u32(r.candidates.len() as u32);
    for &id in &r.candidates {
        b.u64(id);
    }
    b.finish()
}

/// Decode a request frame payload.
pub fn decode_request(buf: &[u8]) -> Result<Request> {
    let mut c = Cursor::new(buf);
    if c.u32()? != REQ_MAGIC {
        return Err(Error::Protocol("bad request magic".into()));
    }
    let request_id = c.u64()?;
    let user_id = c.u64()?;
    let nh = c.u32()? as usize;
    let mut history = Vec::with_capacity(nh);
    for _ in 0..nh {
        history.push(c.u64()?);
    }
    let nc = c.u32()? as usize;
    let mut candidates = Vec::with_capacity(nc);
    for _ in 0..nc {
        candidates.push(c.u64()?);
    }
    if c.remaining() != 0 {
        return Err(Error::Protocol("trailing bytes in request".into()));
    }
    Ok(Request { request_id, user_id, history, candidates, ..Default::default() })
}

/// Encode a response frame payload.
pub fn encode_response(r: &Response, n_tasks: usize) -> Vec<u8> {
    let mut b = Builder::new();
    b.u32(RSP_MAGIC).u64(r.request_id).u32(0);
    b.u32(r.m as u32).u32(n_tasks as u32);
    b.f32s(&r.scores);
    b.u64(r.overall_us);
    b.finish()
}

/// Encode an error response.
pub fn encode_error(request_id: u64, status: u32) -> Vec<u8> {
    let mut b = Builder::new();
    b.u32(RSP_MAGIC).u64(request_id).u32(status);
    b.u32(0).u32(0).u64(0);
    b.finish()
}

/// Decoded response.
#[derive(Clone, Debug)]
pub struct WireResponse {
    pub request_id: u64,
    pub status: u32,
    pub scores: Vec<f32>,
    pub m: usize,
    pub n_tasks: usize,
    pub overall_us: u64,
}

/// Decode a response frame payload.
pub fn decode_response(buf: &[u8]) -> Result<WireResponse> {
    let mut c = Cursor::new(buf);
    if c.u32()? != RSP_MAGIC {
        return Err(Error::Protocol("bad response magic".into()));
    }
    let request_id = c.u64()?;
    let status = c.u32()?;
    let m = c.u32()? as usize;
    let n_tasks = c.u32()? as usize;
    let scores = c.f32s(m * n_tasks)?;
    let overall_us = c.u64()?;
    Ok(WireResponse { request_id, status, scores, m, n_tasks, overall_us })
}

/// What the TCP front serves: a single in-process stack (synchronous
/// serve per connection thread), the staged pipeline over a stack
/// (submit + channel reply, so the connection thread can watch the
/// socket for a vanished client while the stages work), or the cluster
/// routing tier over N replicas.
#[derive(Clone)]
enum Frontend {
    Stack(Arc<ServingStack>),
    Pipeline(Arc<PipelineHandle>),
    Cluster(Arc<ClusterRouter>),
}

/// A running TCP server (one thread per connection; connections are
/// long-lived upstream proxies in the paper's deployment, not per-query
/// sockets).
pub struct TcpServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    /// Bind and serve `stack` on `addr` (e.g. "127.0.0.1:0").
    pub fn start(stack: Arc<ServingStack>, addr: &str) -> Result<TcpServer> {
        Self::start_frontend(Frontend::Stack(stack), addr, DEFAULT_IDLE_TIMEOUT)
    }

    /// [`TcpServer::start`] with a custom per-connection idle timeout
    /// (tests use tight values; production wants [`DEFAULT_IDLE_TIMEOUT`]).
    pub fn start_with_idle_timeout(
        stack: Arc<ServingStack>,
        addr: &str,
        idle: Duration,
    ) -> Result<TcpServer> {
        Self::start_frontend(Frontend::Stack(stack), addr, idle)
    }

    /// Bind and serve the staged pipeline on `addr`. Unlike
    /// [`TcpServer::start`], requests are *submitted* and the reply
    /// awaited on a channel, which lets the connection thread notice a
    /// client that hangs up mid-request and fire `ClientGone` on the
    /// request's cancel token — the stages then drop the doomed work at
    /// their next boundary instead of computing scores nobody will read.
    pub fn start_pipeline(handle: Arc<PipelineHandle>, addr: &str) -> Result<TcpServer> {
        Self::start_frontend(Frontend::Pipeline(handle), addr, DEFAULT_IDLE_TIMEOUT)
    }

    /// [`TcpServer::start_pipeline`] with a custom idle timeout.
    pub fn start_pipeline_with_idle_timeout(
        handle: Arc<PipelineHandle>,
        addr: &str,
        idle: Duration,
    ) -> Result<TcpServer> {
        Self::start_frontend(Frontend::Pipeline(handle), addr, idle)
    }

    /// Bind and serve a [`ClusterRouter`] on `addr` — the same wire
    /// protocol, but requests are placed across the router's replicas
    /// (admission shedding surfaces as status 1 frames). The router's
    /// result-cache tier, when enabled, is shared across every
    /// connection: identical requests from different upstream proxies
    /// hit one cache and coalesce onto one in-flight computation.
    pub fn start_cluster(router: Arc<ClusterRouter>, addr: &str) -> Result<TcpServer> {
        Self::start_frontend(Frontend::Cluster(router), addr, DEFAULT_IDLE_TIMEOUT)
    }

    fn start_frontend(frontend: Frontend, addr: &str, idle: Duration) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Io(format!("bind {addr}"), e))?;
        let local = listener.local_addr().map_err(|e| Error::Io("local_addr".into(), e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Io("set_nonblocking".into(), e))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let frontend = frontend.clone();
                            let stop3 = Arc::clone(&stop2);
                            conns.push(std::thread::spawn(move || match frontend {
                                Frontend::Stack(stack) => {
                                    let n_tasks = stack.model_cfg.n_tasks;
                                    let mut arena = StagingArena::new(stack.arena_capacity());
                                    let stats_stack = Arc::clone(&stack);
                                    let _ = handle_conn(
                                        stream,
                                        |req| stack.serve(req, &mut arena),
                                        move || {
                                            crate::obs::prom::render_recorder(
                                                &stats_stack.metrics,
                                            )
                                        },
                                        Some(n_tasks),
                                        idle,
                                        stop3,
                                    );
                                }
                                Frontend::Pipeline(handle) => {
                                    let stats_handle = Arc::clone(&handle);
                                    let _ = handle_conn_pipeline(
                                        stream,
                                        handle,
                                        move || {
                                            crate::obs::prom::render_recorder(
                                                &stats_handle.stack().metrics,
                                            )
                                        },
                                        idle,
                                        stop3,
                                    );
                                }
                                Frontend::Cluster(router) => {
                                    let stats_router = Arc::clone(&router);
                                    let _ = handle_conn(
                                        stream,
                                        |req| router.submit(req),
                                        move || {
                                            crate::obs::prom::render_recorder(
                                                &stats_router.metrics,
                                            )
                                        },
                                        None,
                                        idle,
                                        stop3,
                                    );
                                }
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })
            .map_err(|e| Error::Internal(format!("spawn accept: {e}")))?;
        Ok(TcpServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    /// Graceful drain: stop accepting connections, let each connection
    /// finish the request it is serving (and flush its response), then
    /// join. Nothing in flight is cancelled — cancellation is for
    /// *doomed* work, and a draining server's in-flight work is still
    /// wanted. Stage queues drain afterwards when the owning
    /// [`PipelineHandle`] / stack is dropped.
    pub fn drain(self) {
        self.shutdown();
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Outcome of one `read_frame` attempt on a connection with a 200ms
/// read timeout: a frame, "nothing yet, keep polling", or "close".
enum FrameRead {
    Frame(Vec<u8>),
    Idle,
    Close,
}

/// One poll for the next frame. Timeouts surface as protocol errors
/// wrapping WouldBlock. An oversized length prefix (hostile or broken
/// peer — `read_frame` rejects it *before* allocating) gets a typed
/// status-2 reply instead of a silent hangup, so well-meaning clients
/// with a framing bug can tell the difference from a network drop.
fn poll_frame(stream: &mut TcpStream) -> FrameRead {
    match read_frame(stream, MAX_FRAME) {
        Ok(f) => FrameRead::Frame(f),
        Err(Error::Protocol(msg)) => {
            if msg.contains("WouldBlock")
                || msg.contains("timed out")
                || msg.contains("Resource temporarily unavailable")
            {
                return FrameRead::Idle;
            }
            if msg.contains("exceeds cap") {
                let _ = write_frame(stream, &encode_error(0, 2));
                let _ = stream.flush();
            }
            FrameRead::Close // peer closed / garbage: drop connection
        }
        Err(_) => FrameRead::Close,
    }
}

/// Per-connection frame loop over any serve function. `n_tasks` fixes
/// the response header for single-stack fronts; `None` derives it per
/// response (cluster backends may differ in score width). `stats`
/// renders the live metrics exposition for 'FLST' frames. A connection
/// silent for longer than `idle` is closed.
fn handle_conn<F, S>(
    mut stream: TcpStream,
    mut serve: F,
    stats: S,
    n_tasks: Option<usize>,
    idle: Duration,
    stop: Arc<AtomicBool>,
) -> Result<()>
where
    F: FnMut(&Request) -> Result<Response>,
    S: Fn() -> String,
{
    stream
        .set_read_timeout(Some(Duration::from_millis(200)))
        .map_err(|e| Error::Io("set_read_timeout".into(), e))?;
    let mut last_activity = Instant::now();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let frame = match poll_frame(&mut stream) {
            FrameRead::Frame(f) => f,
            FrameRead::Idle => {
                if last_activity.elapsed() >= idle {
                    return Ok(()); // wedged or abandoned peer: reclaim the thread
                }
                continue;
            }
            FrameRead::Close => return Ok(()),
        };
        last_activity = Instant::now();
        if frame.len() >= 4 && frame[..4] == STATS_MAGIC.to_le_bytes() {
            write_frame(&mut stream, &encode_stats_response(&stats()))
                .map_err(|e| Error::Io("write stats frame".into(), e))?;
            stream.flush().map_err(|e| Error::Io("flush".into(), e))?;
            continue;
        }
        let req = match decode_request(&frame) {
            Ok(r) => r,
            Err(_) => {
                let _ = write_frame(&mut stream, &encode_error(0, 2));
                continue;
            }
        };
        let payload = match serve(&req) {
            Ok(resp) => {
                let nt = n_tasks.unwrap_or_else(|| {
                    if resp.m == 0 { 0 } else { resp.scores.len() / resp.m }
                });
                encode_response(&resp, nt)
            }
            Err(Error::Overloaded(_)) => encode_error(req.request_id, 1),
            Err(Error::Cancelled(..)) => encode_error(req.request_id, 3),
            Err(_) => encode_error(req.request_id, 2),
        };
        write_frame(&mut stream, &payload).map_err(|e| Error::Io("write frame".into(), e))?;
        stream.flush().map_err(|e| Error::Io("flush".into(), e))?;
    }
}

/// Best-effort liveness probe: true iff the peer has closed its end
/// (EOF on a nonblocking peek). Pending bytes (a pipelined next frame)
/// and an empty-but-open socket both read as alive; probe failures are
/// treated as alive — the regular frame loop will notice a real close.
fn peer_hung_up(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let mut probe = [0u8; 1];
    let gone = matches!(stream.peek(&mut probe), Ok(0));
    let _ = stream.set_nonblocking(false);
    gone
}

/// Per-connection frame loop for the pipelined front. Differs from
/// [`handle_conn`] in that requests are *submitted* to the staged
/// pipeline and the reply awaited on a channel, so this thread can
/// watch the socket while the stages work: a peer that hangs up
/// mid-request fires `ClientGone` on the request's cancel token and
/// the stages drop the doomed work at their next boundary. If the
/// request was already past every stage checkpoint and completes
/// anyway, the discarded response is counted here (stage=frontend) —
/// the stage drop sites and this site are mutually exclusive, keeping
/// the cancelled ledger exactly-once per request.
fn handle_conn_pipeline<S>(
    mut stream: TcpStream,
    handle: Arc<PipelineHandle>,
    stats: S,
    idle: Duration,
    stop: Arc<AtomicBool>,
) -> Result<()>
where
    S: Fn() -> String,
{
    let n_tasks = handle.stack().model_cfg.n_tasks;
    stream
        .set_read_timeout(Some(Duration::from_millis(200)))
        .map_err(|e| Error::Io("set_read_timeout".into(), e))?;
    let mut last_activity = Instant::now();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let frame = match poll_frame(&mut stream) {
            FrameRead::Frame(f) => f,
            FrameRead::Idle => {
                if last_activity.elapsed() >= idle {
                    return Ok(());
                }
                continue;
            }
            FrameRead::Close => return Ok(()),
        };
        last_activity = Instant::now();
        if frame.len() >= 4 && frame[..4] == STATS_MAGIC.to_le_bytes() {
            write_frame(&mut stream, &encode_stats_response(&stats()))
                .map_err(|e| Error::Io("write stats frame".into(), e))?;
            stream.flush().map_err(|e| Error::Io("flush".into(), e))?;
            continue;
        }
        let req = match decode_request(&frame) {
            Ok(r) => r,
            Err(_) => {
                let _ = write_frame(&mut stream, &encode_error(0, 2));
                continue;
            }
        };
        let request_id = req.request_id;
        let budget =
            Duration::from_micros(handle.stack().config.server.tenant_budget_us(req.tenant));
        let payload = match handle.submit_with_cancel(req, budget) {
            Err(Error::Overloaded(_)) => encode_error(request_id, 1),
            Err(_) => encode_error(request_id, 2),
            Ok((rx, token)) => {
                let mut client_gone = false;
                let outcome = loop {
                    match rx.recv_timeout(Duration::from_millis(5)) {
                        Ok(r) => break Some(r),
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                            if !client_gone && peer_hung_up(&stream) {
                                client_gone = true;
                                token.cancel(CancelCause::ClientGone);
                            }
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break None,
                    }
                };
                let Some(outcome) = outcome else {
                    return Ok(()); // pipeline shut down under us
                };
                if client_gone {
                    // The reply has nowhere to go. A typed Cancelled
                    // error means a stage already dropped (and counted)
                    // the request; an Ok means it outran every
                    // checkpoint, so this discard is its one drop site.
                    if outcome.is_ok() {
                        handle.stack().metrics.record_cancelled(
                            CancelCause::ClientGone,
                            CancelStage::Frontend,
                            0,
                        );
                    }
                    return Ok(());
                }
                match outcome {
                    Ok(resp) => encode_response(&resp, n_tasks),
                    Err(Error::Overloaded(_)) => encode_error(request_id, 1),
                    Err(Error::Cancelled(..)) => encode_error(request_id, 3),
                    Err(_) => encode_error(request_id, 2),
                }
            }
        };
        write_frame(&mut stream, &payload).map_err(|e| Error::Io("write frame".into(), e))?;
        stream.flush().map_err(|e| Error::Io("flush".into(), e))?;
    }
}

/// Minimal blocking client for tests/examples.
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Self> {
        let stream =
            TcpStream::connect(addr).map_err(|e| Error::Io(format!("connect {addr}"), e))?;
        Ok(TcpClient { stream })
    }

    pub fn call(&mut self, req: &Request) -> Result<WireResponse> {
        write_frame(&mut self.stream, &encode_request(req))
            .map_err(|e| Error::Io("write".into(), e))?;
        let frame = read_frame(&mut self.stream, MAX_FRAME)?;
        decode_response(&frame)
    }

    /// Fetch the server's live metrics exposition (Prometheus text).
    pub fn stats(&mut self) -> Result<String> {
        write_frame(&mut self.stream, &encode_stats_request())
            .map_err(|e| Error::Io("write".into(), e))?;
        let frame = read_frame(&mut self.stream, MAX_FRAME)?;
        decode_stats_response(&frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request {
            request_id: 7,
            user_id: 3,
            history: vec![1, 2, 3],
            candidates: vec![10, 11],
            ..Default::default()
        }
    }

    #[test]
    fn request_wire_roundtrip() {
        let r = req();
        assert_eq!(decode_request(&encode_request(&r)).unwrap(), r);
    }

    #[test]
    fn response_wire_roundtrip() {
        let resp = Response {
            request_id: 7,
            scores: vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6],
            m: 2,
            overall_us: 1234,
            compute_us: 900,
            feature_us: 100,
            queue_us: 30,
            handoff_us: 0,
            quality: crate::chaos::ServeQuality::Full,
        };
        let w = decode_response(&encode_response(&resp, 3)).unwrap();
        assert_eq!(w.request_id, 7);
        assert_eq!(w.status, 0);
        assert_eq!(w.m, 2);
        assert_eq!(w.n_tasks, 3);
        assert_eq!(w.scores, resp.scores);
        assert_eq!(w.overall_us, 1234);
    }

    #[test]
    fn error_frames() {
        let w = decode_response(&encode_error(42, 1)).unwrap();
        assert_eq!(w.request_id, 42);
        assert_eq!(w.status, 1);
        assert!(w.scores.is_empty());
    }

    #[test]
    fn rejects_wrong_magic() {
        let mut buf = encode_request(&req());
        buf[0] = 0;
        assert!(decode_request(&buf).is_err());
        let mut buf = encode_error(1, 0);
        buf[0] = 0;
        assert!(decode_response(&buf).is_err());
    }

    #[test]
    fn rejects_trailing_bytes() {
        let mut buf = encode_request(&req());
        buf.push(0);
        assert!(decode_request(&buf).is_err());
    }

    #[test]
    fn stats_wire_roundtrip() {
        let body = "# TYPE flame_requests_total counter\nflame_requests_total 7\n";
        let frame = encode_stats_response(body);
        assert_eq!(decode_stats_response(&frame).unwrap(), body);
        // the stats request is distinguishable from a serve request
        let sr = encode_stats_request();
        assert_eq!(sr[..4], STATS_MAGIC.to_le_bytes());
        assert!(decode_request(&sr).is_err());
    }

    #[test]
    fn stats_rejects_wrong_magic() {
        let mut frame = encode_stats_response("x");
        frame[0] = 0;
        assert!(decode_stats_response(&frame).is_err());
    }
}
