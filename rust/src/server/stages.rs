//! The decoupled two-stage serve pipeline (paper §3.1: feature
//! pre-processing runs concurrently with model computation, so neither
//! stage idles the other).
//!
//! ```text
//!            intake (bounded, sheds)        handoff (bounded, blocks)
//! submit ──▶ RequestQueue<PipelineJob> ──▶ N feature workers ──▶
//!            RequestQueue<StagedRequest> ──▶ M compute submitters ──▶ reply
//! ```
//!
//! `ServingStack::serve` runs both stages back to back on one thread, so
//! per-request latency is `feature_us + compute_us` and the worker's CPU
//! idles during every engine launch. Here the stages are separate thread
//! pools: while a compute submitter waits on request A's DSO launch, a
//! feature worker assembles request B — the overlap FLAME's PDA numbers
//! assume. Staging arenas come from a shared [`ArenaPool`]; an arena
//! travels with its staged request through the handoff queue and returns
//! to the pool only after the orchestrator has consumed its tensor views.
//!
//! **Backpressure** is a chain of bounded resources, each stalling the
//! one upstream: compute submitters drain the handoff queue; when they
//! fall behind, the handoff queue fills and `push_blocking` stalls the
//! feature workers; stalled feature workers stop draining the intake
//! queue, whose bounded `push` then sheds new requests (`Overloaded`) at
//! admission — the same front-door contract as the synchronous mode.
//!
//! **Score identity**: the stages run the exact same assembler and
//! orchestrator code as `serve`, so pipelined scores are bit-identical
//! to synchronous scores for any request interleaving (property-tested
//! over `SimEngine` in `tests/pipeline_stage.rs`).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::batching::RequestQueue;
use crate::cancel::{self, CancelCause, CancelStage, CancelToken};
use crate::chaos::{PanicSite, ServeQuality};
use crate::error::{Error, Result};
use crate::obs::{self, StageKind, TraceContext};
use crate::pda::{ArenaPool, AssembledInput, StagingArena};
use crate::workload::driver::DriveReport;
use crate::workload::Request;

use super::pipeline::{Response, ServingStack};

/// A request admitted into the pipeline, with its reply channel.
struct PipelineJob {
    req: Request,
    /// Absolute deadline, stamped at admission. With
    /// `ServerConfig::deadline_first` the intake pops the
    /// nearest-deadline job first instead of FIFO.
    deadline: Instant,
    /// Request-scoped trace, stamped at admission (None = tracing off;
    /// the hot path then carries nothing).
    trace: Option<TraceContext>,
    /// Request-scoped cancellation cell, checked at every stage
    /// boundary so doomed work is dropped at the earliest cheap point.
    cancel: CancelToken,
    reply: Sender<Result<Response>>,
}

/// Feature-stage output: tensors staged in a pooled arena, en route to a
/// compute submitter.
struct StagedRequest {
    request_id: u64,
    m: usize,
    /// The pooled arena holding this request's tensors; returns to the
    /// pool only after the orchestrator consumed the views.
    arena: StagingArena,
    assembled: AssembledInput,
    feature_us: u64,
    /// Ladder rung accumulated so far (stale features, truncation).
    quality: ServeQuality,
    /// Feature-stage start (overall latency anchor).
    t0: Instant,
    /// Trace carried over from the feature stage.
    trace: Option<TraceContext>,
    /// Cancellation cell carried over from admission.
    cancel: CancelToken,
    reply: Sender<Result<Response>>,
}

/// Handle to a running two-stage pipeline. Dropping it (or calling
/// [`PipelineHandle::shutdown`]) closes the intake, drains both stages,
/// and joins every worker.
pub struct PipelineHandle {
    stack: Arc<ServingStack>,
    intake: Arc<RequestQueue<PipelineJob>>,
    pool: Arc<ArenaPool>,
    feature_workers: Vec<JoinHandle<()>>,
    compute_workers: Vec<JoinHandle<()>>,
    handoff: Arc<RequestQueue<StagedRequest>>,
}

impl PipelineHandle {
    /// Spawn the stage workers per `stack.config.server`: N =
    /// `feature_workers`, M = `pipeline_workers`, handoff depth
    /// `handoff_capacity`, intake depth `dso.queue_capacity` (the same
    /// bound the synchronous open-loop mode uses).
    pub(crate) fn spawn(stack: Arc<ServingStack>) -> PipelineHandle {
        let n = stack.config.server.feature_workers.max(1);
        let m = stack.config.server.pipeline_workers.max(1);
        let handoff_cap = stack.config.server.handoff_capacity.max(1);
        let intake: Arc<RequestQueue<PipelineJob>> = if stack.config.server.deadline_first {
            // deadline-closest-first: feature workers pop the queued job
            // whose absolute deadline is nearest (µs since this epoch;
            // pre-epoch deadlines saturate to 0 and stay first)
            let epoch = Instant::now();
            RequestQueue::with_priority(stack.config.dso.queue_capacity, move |job| {
                job.deadline.saturating_duration_since(epoch).as_micros() as u64
            })
        } else {
            RequestQueue::new(stack.config.dso.queue_capacity)
        };
        let handoff: Arc<RequestQueue<StagedRequest>> = RequestQueue::new(handoff_cap);
        // Enough arenas that steady state never blocks on the pool: one
        // per feature worker (being filled), one per handoff slot
        // (queued), one per compute submitter (being consumed).
        let pool = Arc::new(ArenaPool::new(n + m + handoff_cap, stack.arena_capacity()));

        let topo = stack.topology.clone();
        let feature_workers = (0..n)
            .map(|i| {
                let stack = Arc::clone(&stack);
                let intake = Arc::clone(&intake);
                let handoff = Arc::clone(&handoff);
                let pool = Arc::clone(&pool);
                let cpu = topo.cpu_for_worker(i);
                std::thread::Builder::new()
                    .name(format!("pda-stage-{i}"))
                    .spawn(move || {
                        if stack.config.pda.numa_binding {
                            let _ = crate::pda::numa::pin_current_thread(cpu);
                        }
                        feature_loop(&stack, &intake, &handoff, &pool);
                    })
                    // lint: allow(panic) stage-worker spawn at startup is unrecoverable
                    .expect("spawn feature-stage worker")
            })
            .collect();
        let compute_workers = (0..m)
            .map(|i| {
                let stack = Arc::clone(&stack);
                let handoff = Arc::clone(&handoff);
                let pool = Arc::clone(&pool);
                std::thread::Builder::new()
                    .name(format!("dso-submit-{i}"))
                    .spawn(move || compute_loop(&stack, &handoff, &pool))
                    // lint: allow(panic) submitter spawn at startup is unrecoverable
                    .expect("spawn compute-stage submitter")
            })
            .collect();

        PipelineHandle { stack, intake, pool, feature_workers, compute_workers, handoff }
    }

    /// Admit a request (shedding on a full intake queue — the
    /// backpressure front door) and return the response receiver. The
    /// deadline is the request tenant's budget
    /// (`ServerConfig::tenant_budget_us`, falling back to
    /// `ServerConfig::deadline_ms`).
    pub fn submit(&self, req: Request) -> Result<Receiver<Result<Response>>> {
        let budget = Duration::from_micros(self.stack.config.server.tenant_budget_us(req.tenant));
        self.submit_with_deadline(req, budget)
    }

    /// Admit a request with an explicit deadline budget. Only matters
    /// under `ServerConfig::deadline_first`, where the intake pops the
    /// nearest-deadline request first — a tight budget overtakes slack
    /// ones queued ahead of it.
    pub fn submit_with_deadline(
        &self,
        req: Request,
        budget: Duration,
    ) -> Result<Receiver<Result<Response>>> {
        self.submit_with_cancel(req, budget).map(|(rx, _)| rx)
    }

    /// Admit a request and also return its [`CancelToken`], so the
    /// caller (TCP front, hedging router, tests) can fire an explicit
    /// cause (`ClientGone`, `Shutdown`, ...). With `ServerConfig::cancel`
    /// on, the token carries the absolute deadline and every stage
    /// boundary lazily expires it; with the knob off only explicit
    /// fires are honored (the token never self-expires).
    pub fn submit_with_cancel(
        &self,
        req: Request,
        budget: Duration,
    ) -> Result<(Receiver<Result<Response>>, CancelToken)> {
        let (reply, rx) = channel();
        let deadline = Instant::now() + budget;
        let cancel = if self.stack.config.server.cancel {
            CancelToken::with_deadline(deadline)
        } else {
            CancelToken::new()
        };
        let trace = self
            .stack
            .metrics
            .trace_begin(req.request_id, budget.as_micros() as u64);
        let tenant = req.tenant;
        if let Err(e) = self.intake.push(PipelineJob {
            req,
            deadline,
            trace,
            cancel: cancel.clone(),
            reply,
        }) {
            // shed at the front door: the bottom rung of the ladder
            self.stack.metrics.record_quality(ServeQuality::Shed);
            self.stack.metrics.record_tenant_shed(tenant);
            self.stack.metrics.record_tenant_quality(tenant, ServeQuality::Shed);
            return Err(e);
        }
        Ok((rx, cancel))
    }

    /// Admit a request whose response nobody will read (open-loop
    /// drivers measure through the recorder instead).
    pub fn enqueue(&self, req: Request) -> Result<()> {
        self.submit(req).map(|_| ())
    }

    /// Admit and block for the response — the closed-loop equivalent of
    /// `ServingStack::serve`, with the two stages overlapping across
    /// concurrent callers.
    pub fn serve(&self, req: &Request) -> Result<Response> {
        let rx = self.submit(req.clone())?;
        rx.recv()
            .map_err(|_| Error::Shutdown("pipeline shut down mid-request".into()))?
    }

    /// Closed-loop saturation driver over the pipeline (mirror of
    /// `ServingStack::drive_closed_loop`): `concurrency` submitters keep
    /// one request in flight each, so both stages stay busy. Unlike the
    /// synchronous driver there is no per-thread arena or NUMA pin to
    /// set up — the stage workers own those — so the generic
    /// [`crate::workload::driver::closed_loop`] does all the plumbing.
    pub fn drive_closed_loop(
        &self,
        requests: &[Request],
        concurrency: usize,
        duration: std::time::Duration,
    ) -> DriveReport {
        crate::workload::driver::closed_loop(requests.to_vec(), concurrency, duration, |r| {
            self.serve(r).is_ok()
        })
    }

    /// The serving stack behind the pipeline (metrics, orchestrator).
    pub fn stack(&self) -> &Arc<ServingStack> {
        &self.stack
    }

    /// Arenas currently idle in the pool (diagnostics/tests).
    pub fn idle_arenas(&self) -> usize {
        self.pool.idle()
    }

    /// Total arenas owned by the pool. `idle_arenas() == total_arenas()`
    /// after a drain means no request path leaked an arena.
    pub fn total_arenas(&self) -> usize {
        self.pool.total()
    }

    /// Requests waiting in the intake queue.
    pub fn intake_len(&self) -> usize {
        self.intake.len()
    }

    /// Drain both stages and join all workers. In-flight requests finish
    /// (`RequestQueue::close` drains before poppers observe `None`); new
    /// submits fail.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.intake.close();
        for w in self.feature_workers.drain(..) {
            let _ = w.join();
        }
        // only close the handoff after every feature worker exited, so
        // nothing staged is lost
        self.handoff.close();
        for w in self.compute_workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for PipelineHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Stage 1: intake → PDA assembly into a pooled arena → handoff. Blocks
/// on a full handoff queue (that *is* the backpressure) and on arena
/// exhaustion; exits when the intake closes.
fn feature_loop(
    stack: &ServingStack,
    intake: &RequestQueue<PipelineJob>,
    handoff: &RequestQueue<StagedRequest>,
    pool: &ArenaPool,
) {
    let l = stack.model_cfg.seq_len;
    while let Some((mut job, qdelay)) = intake.pop() {
        let qdelay_us = qdelay.as_micros() as u64;
        stack.metrics.record_queueing(qdelay_us);
        // doomed-work purge: a job whose token fired (or whose deadline
        // expired) while queued is resolved here, before any feature
        // work or arena checkout — the cheapest possible drop point
        if let Some(cause) = job.cancel.poll() {
            stack.metrics.record_cancelled(cause, CancelStage::Intake, job.req.m() as u64);
            if let Some(mut ctx) = job.trace.take() {
                ctx.span_ending_now(StageKind::Queue, qdelay_us);
                stack.metrics.trace_finish(ctx, cause == CancelCause::Expired);
            }
            let _ = job.reply.send(Err(Error::Cancelled(cause, CancelStage::Intake)));
            continue;
        }
        if let Some(ctx) = job.trace.as_mut() {
            ctx.span_ending_now(StageKind::Queue, qdelay_us);
            // deep shared paths (fetch coalescer) pick the trace id up
            // from the thread instead of a threaded parameter
            obs::set_current_trace(ctx.trace_id());
        }
        // the fetch coalescer's rider wait observes cancellation through
        // the thread-local token, mirroring the trace id above
        cancel::set_current(Some(job.cancel.clone()));
        let reply = job.reply.clone();
        let request_id = job.req.request_id;
        let took_arena = std::cell::Cell::new(false);
        // lint: supervisor — a panicking request (injected or real) is
        // failed with a typed error and the stage worker keeps draining;
        // the reply sender is held out here so the unwind cannot take
        // the caller's channel down with it
        let staged = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if let Some(plan) = stack.chaos.get() {
                if plan.panic_due(PanicSite::Feature) {
                    // lint: allow(panic) chaos injection, caught by the stage supervisor
                    panic!("chaos: injected feature-stage panic");
                }
            }
            let mut quality = ServeQuality::Full;
            // degradation rung: a request whose remaining deadline
            // cannot fit its full candidate set serves the prefix that
            // fits (candidates arrive ranked, so the prefix is top-K)
            if stack.config.server.truncate_over_budget {
                let pace = stack.pair_cost_ns();
                let remaining_us =
                    job.deadline.saturating_duration_since(Instant::now()).as_micros() as u64;
                if pace > 0 && !job.req.candidates.is_empty() {
                    let fit = (remaining_us.saturating_mul(1_000) / pace) as usize;
                    if fit < job.req.candidates.len() {
                        job.req.candidates.truncate(fit.max(1));
                        quality = quality.worst(ServeQuality::TruncatedCandidates);
                    }
                }
            }
            let t0 = Instant::now();
            let mut arena = pool.get();
            took_arena.set(true);
            let growth0 = arena.growth_count();
            let assembled = stack.assembler.assemble_request(
                &job.req.history,
                l,
                &job.req.candidates,
                &mut arena,
            );
            let grew = arena.growth_count() - growth0;
            if grew > 0 {
                stack.metrics.record_arena_growth(grew);
            }
            // stale/default features: still well-formed input, but the
            // response must say so
            if assembled.stale + assembled.missing > 0 {
                quality = quality.worst(ServeQuality::StaleFeatures);
            }
            let feature_us = t0.elapsed().as_micros() as u64;
            if let Some(ctx) = job.trace.as_mut() {
                ctx.span_ending_now(StageKind::Feature, feature_us);
                obs::set_current_trace(0);
            }
            StagedRequest {
                request_id: job.req.request_id,
                m: job.req.m(),
                arena,
                assembled,
                feature_us,
                quality,
                t0,
                trace: job.trace,
                cancel: job.cancel,
                reply: job.reply,
            }
        }));
        cancel::set_current(None);
        match staged {
            Ok(staged) => {
                if let Err(staged) = handoff.push_blocking(staged) {
                    // shutdown race: the handoff closed under us — fail
                    // the request explicitly and recycle its arena
                    stack.metrics.record_dropped();
                    let _ = staged
                        .reply
                        .send(Err(Error::Shutdown("pipeline handoff closed".into())));
                    pool.put(staged.arena);
                }
            }
            Err(_) => {
                obs::set_current_trace(0);
                stack.metrics.record_worker_restart();
                stack.metrics.record_dropped();
                let _ = reply.send(Err(Error::WorkerPanic(format!(
                    "feature stage lost request {request_id}"
                ))));
                if took_arena.get() {
                    // the pooled arena unwound with the stage body;
                    // restore the pool's population so later requests
                    // cannot starve on `get`
                    pool.put(StagingArena::new(stack.arena_capacity()));
                }
            }
        }
    }
}

/// Stage 2: handoff → DSO orchestrator → response packaging → arena back
/// to the pool. The submitter thread blocks inside `submit_slice` while
/// the executors run the launch — which is exactly when the feature
/// workers are free to assemble the next requests.
fn compute_loop(stack: &ServingStack, handoff: &RequestQueue<StagedRequest>, pool: &ArenaPool) {
    while let Some((staged, stage_wait)) = handoff.pop() {
        let StagedRequest {
            request_id,
            m,
            arena,
            assembled,
            feature_us,
            quality,
            t0,
            mut trace,
            cancel,
            reply,
        } = staged;
        let handoff_us = stage_wait.as_micros() as u64;
        stack.metrics.record_handoff(handoff_us);
        if let Some(ctx) = trace.as_mut() {
            ctx.span_ending_now(StageKind::Handoff, handoff_us);
        }
        // doomed-work purge: resolve a fired token before the DSO
        // submit, returning the staged arena with exact accounting
        if let Some(cause) = cancel.poll() {
            stack.metrics.record_cancelled(cause, CancelStage::Handoff, m as u64);
            if let Some(ctx) = trace.take() {
                stack.metrics.trace_finish(ctx, cause == CancelCause::Expired);
            }
            let _ = reply.send(Err(Error::Cancelled(cause, CancelStage::Handoff)));
            pool.put(arena);
            continue;
        }
        let trace_id = trace.as_ref().map_or(0, |c| c.trace_id());
        let compute_begin = trace.as_ref().map_or(0, |c| c.now_us());
        // lint: supervisor — a panic submitting this request fails it
        // with a typed error and the submitter survives; the body only
        // borrows the arena/views, so both outlive an unwind
        let submitted = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if let Some(plan) = stack.chaos.get() {
                if plan.panic_due(PanicSite::Compute) {
                    // lint: allow(panic) chaos injection, caught by the stage supervisor
                    panic!("chaos: injected compute-stage panic");
                }
            }
            let (hist, cands) = assembled.views(&arena);
            stack
                .orchestrator
                .submit_cancellable(hist, cands, m, trace_id, Some(cancel.clone()))
        }));
        match submitted {
            Ok(Ok(outcome)) => {
                let overall_us = t0.elapsed().as_micros() as u64;
                stack.metrics.record_request(overall_us, m);
                stack.metrics.record_quality(quality);
                stack.metrics.record_compute(outcome.compute_us);
                stack.metrics.record_feature(feature_us);
                stack.metrics.record_queueing(outcome.queue_us);
                stack.note_pair_cost(outcome.compute_us, m);
                if let Some(mut ctx) = trace.take() {
                    let end = ctx.now_us();
                    ctx.span_linked(StageKind::Compute, compute_begin, end, &outcome.launch_ids);
                    let sla_missed =
                        ctx.budget_us() > 0 && ctx.elapsed_us() > ctx.budget_us();
                    stack.metrics.trace_finish(ctx, sla_missed);
                }
                let _ = reply.send(Ok(Response {
                    request_id,
                    scores: outcome.scores,
                    m,
                    overall_us,
                    compute_us: outcome.compute_us,
                    feature_us,
                    queue_us: outcome.queue_us,
                    handoff_us,
                    quality,
                }));
            }
            // a DSO-plane drop site (coalescer eviction, pre-launch
            // check) resolved the request: the error carries the stage
            // that dropped it, and *this* is the single site that counts
            // it — the drop site itself never touches the recorder, so
            // fires and counts stay exactly 1:1
            Ok(Err(Error::Cancelled(cause, stage))) => {
                stack.metrics.record_cancelled(cause, stage, m as u64);
                if let Some(ctx) = trace.take() {
                    stack.metrics.trace_finish(ctx, cause == CancelCause::Expired);
                }
                let _ = reply.send(Err(Error::Cancelled(cause, stage)));
            }
            Ok(Err(e)) => {
                stack.metrics.record_dropped();
                if let Some(ctx) = trace.take() {
                    let sla_missed =
                        ctx.budget_us() > 0 && ctx.elapsed_us() > ctx.budget_us();
                    stack.metrics.trace_finish(ctx, sla_missed);
                }
                log::warn!("pipelined request {request_id} failed: {e}");
                let _ = reply.send(Err(e));
            }
            Err(_) => {
                stack.metrics.record_worker_restart();
                stack.metrics.record_dropped();
                if let Some(ctx) = trace.take() {
                    let sla_missed =
                        ctx.budget_us() > 0 && ctx.elapsed_us() > ctx.budget_us();
                    stack.metrics.trace_finish(ctx, sla_missed);
                }
                log::warn!("pipelined request {request_id} failed: compute stage panicked");
                let _ = reply.send(Err(Error::WorkerPanic(format!(
                    "compute stage lost request {request_id}"
                ))));
            }
        }
        // the orchestrator has copied the views into its own chunk
        // buffers (and collected the scores) by the time submit_slice
        // returns — the arena is safe to recycle
        pool.put(arena);
    }
}
