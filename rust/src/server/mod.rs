//! The serving front: the full request pipeline (PDA feature stage →
//! DSO compute stage → response), the in-process serving stack the
//! examples/benches drive, and a TCP front with a length-prefixed binary
//! protocol for out-of-process clients.

pub mod pipeline;
pub mod tcp;

pub use pipeline::{ServingStack, StackBuilder, Response};
