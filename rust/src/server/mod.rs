//! The serving front: the full request pipeline (PDA feature stage →
//! DSO compute stage → response), the decoupled two-stage mode where the
//! stages overlap across requests (`stages`), the in-process serving
//! stack the examples/benches drive, and a TCP front with a
//! length-prefixed binary protocol for out-of-process clients.

pub mod pipeline;
pub mod stages;
pub mod tcp;

pub use pipeline::{Response, ServingStack, StackBuilder};
pub use stages::PipelineHandle;
