//! NUMA topology + core binding (paper §3.1, Fig 6).
//!
//! The paper pins worker threads with `pthread_attr_setaffinity_np` /
//! `numactl` so feature-processing threads keep their working set in
//! node-local memory. We read the topology from
//! `/sys/devices/system/node` and pin with `sched_setaffinity`; on a
//! single-node container the pinning still removes cross-core migration
//! (cache-warm workers), which is the measurable slice of the benefit on
//! this testbed — DESIGN.md §Hardware-Adaptation.

use crate::error::{Error, Result};

/// One NUMA node's CPU set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Node {
    pub id: usize,
    pub cpus: Vec<usize>,
}

/// Host topology.
#[derive(Clone, Debug)]
pub struct Topology {
    pub nodes: Vec<Node>,
}

impl Topology {
    /// Read from sysfs; falls back to a single node covering all CPUs.
    pub fn detect() -> Topology {
        Self::from_sysfs("/sys/devices/system/node").unwrap_or_else(|_| Self::flat())
    }

    /// Single-node fallback topology.
    pub fn flat() -> Topology {
        let n = num_cpus();
        Topology { nodes: vec![Node { id: 0, cpus: (0..n).collect() }] }
    }

    /// Parse `node*/cpulist` files under a sysfs-style directory.
    pub fn from_sysfs(root: &str) -> Result<Topology> {
        let mut nodes = Vec::new();
        let rd = std::fs::read_dir(root).map_err(crate::error::io_err(root))?;
        for e in rd.flatten() {
            let name = e.file_name().to_string_lossy().to_string();
            if let Some(ids) = name.strip_prefix("node") {
                if let Ok(id) = ids.parse::<usize>() {
                    let cpulist = e.path().join("cpulist");
                    if let Ok(text) = std::fs::read_to_string(&cpulist) {
                        nodes.push(Node { id, cpus: parse_cpulist(text.trim())? });
                    }
                }
            }
        }
        if nodes.is_empty() {
            return Err(Error::Config("no NUMA nodes found".into()));
        }
        nodes.sort_by_key(|n| n.id);
        Ok(Topology { nodes })
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Total CPUs across nodes.
    pub fn n_cpus(&self) -> usize {
        self.nodes.iter().map(|n| n.cpus.len()).sum()
    }

    /// Assign worker i of n to a CPU, filling nodes breadth-first so
    /// workers spread across nodes (each worker's memory stays local).
    pub fn cpu_for_worker(&self, i: usize) -> usize {
        let node = &self.nodes[i % self.nodes.len()];
        node.cpus[(i / self.nodes.len()) % node.cpus.len()]
    }
}

/// Parse "0-3,8,10-11" style cpulist.
pub fn parse_cpulist(s: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    if s.is_empty() {
        return Ok(out);
    }
    for part in s.split(',') {
        let part = part.trim();
        if let Some((a, b)) = part.split_once('-') {
            let a: usize = a.trim().parse().map_err(|_| bad(part))?;
            let b: usize = b.trim().parse().map_err(|_| bad(part))?;
            if b < a {
                return Err(bad(part));
            }
            out.extend(a..=b);
        } else {
            out.push(part.parse().map_err(|_| bad(part))?);
        }
    }
    Ok(out)
}

fn bad(part: &str) -> Error {
    Error::Config(format!("bad cpulist fragment '{part}'"))
}

/// Number of online CPUs.
pub fn num_cpus() -> usize {
    // sysconf is the portable answer without external crates.
    // SAFETY: sysconf with a valid selector constant reads kernel state
    // only; it has no pointer arguments or preconditions.
    let n = unsafe { libc::sysconf(libc::_SC_NPROCESSORS_ONLN) };
    if n < 1 {
        1
    } else {
        n as usize
    }
}

/// Pin the calling thread to one CPU (`sched_setaffinity`). Returns Err
/// if the kernel refuses (e.g. cpuset-restricted container); callers
/// treat pinning as best-effort.
pub fn pin_current_thread(cpu: usize) -> Result<()> {
    // cpu_set_t is a plain #[repr(C)] bitmask, so an all-zeroes value
    // is valid; sched_setaffinity reads `&set` (a live stack allocation
    // of exactly `size_of::<cpu_set_t>()` bytes) and pid 0 means
    // "calling thread" — no aliasing, no retained pointers.
    // SAFETY: see above — zeroed cpu_set_t is valid, pointer args live.
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_ZERO(&mut set);
        libc::CPU_SET(cpu % num_cpus(), &mut set);
        let rc = libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set);
        if rc != 0 {
            return Err(Error::Internal(format!(
                "sched_setaffinity(cpu {cpu}) failed: {}",
                std::io::Error::last_os_error()
            )));
        }
    }
    Ok(())
}

/// The CPU the calling thread is currently on.
pub fn current_cpu() -> usize {
    // SAFETY: sched_getcpu takes no arguments and only reads the
    // calling thread's CPU id from the kernel.
    let c = unsafe { libc::sched_getcpu() };
    if c < 0 {
        0
    } else {
        c as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parsing() {
        assert_eq!(parse_cpulist("0-3").unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0,2,4").unwrap(), vec![0, 2, 4]);
        assert_eq!(parse_cpulist("0-1,8,10-11").unwrap(), vec![0, 1, 8, 10, 11]);
        assert_eq!(parse_cpulist("").unwrap(), Vec::<usize>::new());
        assert!(parse_cpulist("3-1").is_err());
        assert!(parse_cpulist("x").is_err());
    }

    #[test]
    fn detect_has_cpus() {
        let t = Topology::detect();
        assert!(t.n_nodes() >= 1);
        assert!(t.n_cpus() >= 1);
    }

    #[test]
    fn flat_covers_all() {
        let t = Topology::flat();
        assert_eq!(t.n_cpus(), num_cpus());
    }

    #[test]
    fn worker_assignment_round_robins_nodes() {
        let t = Topology {
            nodes: vec![
                Node { id: 0, cpus: vec![0, 1] },
                Node { id: 1, cpus: vec![2, 3] },
            ],
        };
        let cpus: Vec<usize> = (0..4).map(|i| t.cpu_for_worker(i)).collect();
        assert_eq!(cpus, vec![0, 2, 1, 3]);
        // wraps around
        assert_eq!(t.cpu_for_worker(4), 0);
    }

    #[test]
    fn pin_current_thread_best_effort() {
        // should succeed on CPU 0 in any environment that allows affinity
        match pin_current_thread(0) {
            Ok(()) => {
                // after pinning to 0, we should observe cpu 0 (eventually)
                std::thread::yield_now();
                assert_eq!(current_cpu(), 0);
            }
            Err(_) => { /* restricted container: acceptable */ }
        }
    }

    #[test]
    fn sysfs_parser_on_synthetic_tree() {
        let dir = std::env::temp_dir().join(format!("flame_numa_test_{}", std::process::id()));
        let n0 = dir.join("node0");
        let n1 = dir.join("node1");
        std::fs::create_dir_all(&n0).unwrap();
        std::fs::create_dir_all(&n1).unwrap();
        std::fs::write(n0.join("cpulist"), "0-1\n").unwrap();
        std::fs::write(n1.join("cpulist"), "2-3\n").unwrap();
        let t = Topology::from_sysfs(dir.to_str().unwrap()).unwrap();
        assert_eq!(t.n_nodes(), 2);
        assert_eq!(t.nodes[1].cpus, vec![2, 3]);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
