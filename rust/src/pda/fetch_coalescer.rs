//! Cross-request feature-miss coalescer: single-flights concurrent
//! cache misses per item id and packs them into shared remote multiget
//! batches (the PDA-side sibling of the DSO batch coalescer).
//!
//! Without it, `QueryEngine::fetch_sync` issues one blocking remote
//! query per request — K concurrent requests missing the same hot Zipf
//! id pay K `Link` round-trips for one value. With it, the first miss
//! of an id becomes that id's **leader** (a [`Ticket`] is opened and
//! the id joins a pending batch); every later miss of the same id while
//! the fetch is in flight becomes a **rider** that just waits on the
//! ticket. Pending ids accumulate in per-shard slots; a batch is
//! executed when it fills ([`FETCH_BATCH`] ids) or when its
//! `fetch_wait_us` deadline expires — so the added per-request latency
//! is bounded, exactly like the DSO coalescer's `coalesce_wait_us`.
//!
//! The deadline flusher **merges expired batches across shards into one
//! multiget** (they all target the same store): a lone request whose
//! misses spread over several shards still pays a single round-trip,
//! same as the uncoalesced path — every batch one `fetch` call opens
//! shares a single deadline, so the flusher always collects them
//! together, and a small grace window (`merge_grace`, bounded by half
//! the wait) additionally merges batches opened by nearly-simultaneous
//! calls.
//!
//! Locking mirrors `dso::coalescer`: per-shard slot mutexes are never
//! held while taking the flusher's signal mutex, so the two orders
//! cannot deadlock; the flusher takes slot locks briefly, one at a
//! time, under `signal`. Ticket resolution happens after the remote
//! fetch completes, cache-insert first, so a waiter that re-probes the
//! cache immediately after waking hits.
//!
//! **No rider waits forever.** [`Ticket::wait`] has no timeout, so the
//! executor carries a resolve-on-drop guard: however `execute` exits —
//! normal return, store error, or a panic unwinding through it (chaos
//! injection, store bug) — every ticket the batch owns is resolved and
//! deregistered. A resolved-with-`None` id is free again: the next
//! miss of it becomes a fresh leader. The flusher additionally runs
//! each batch under a supervisor, so an unwinding batch cannot kill
//! the deadline watcher and wedge every future partial batch.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cache::ShardedCache;
use crate::featurestore::{ItemFeatures, RemoteStore};
use crate::metrics::Recorder;
use crate::obs::{self, SharedSpan, StageKind};

/// Max ids folded into one coalesced multiget (fill-triggered flush).
pub const FETCH_BATCH: usize = 64;

/// Pending-slot shards. Few on purpose: each open batch is one remote
/// query at flush, so fragmenting the pending set costs round-trips,
/// while the slot mutexes are held only for a map probe + push.
const FETCH_SHARDS: usize = 4;

/// One id's in-flight fetch: the leader resolves it, riders wait on it.
struct Ticket {
    /// `None` until resolved. The payload is (value, fetch span id):
    /// value `None` = the store failed and the waiter must fall back
    /// (stale value / zero default); the span id names the shared
    /// multiget span that resolved this ticket (0 = tracing off), so
    /// waiters can report the cross-request causality edge.
    state: Mutex<Option<(Option<ItemFeatures>, u64)>>,
    cv: Condvar,
}

impl Ticket {
    fn new() -> Self {
        Ticket { state: Mutex::new(None), cv: Condvar::new() }
    }

    fn resolve(&self, value: Option<ItemFeatures>, span_id: u64) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        *st = Some((value, span_id));
        self.cv.notify_all();
    }

    fn wait(&self) -> (Option<ItemFeatures>, u64) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(v) = &*st {
                return v.clone();
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Like [`Ticket::wait`], but a waiter whose cancel token fires
    /// abandons the wait and returns `None` — the caller degrades to
    /// stale/default features, exactly as a failed fetch would. Only
    /// the *wait* is abandoned: the ticket stays registered and the
    /// leader's execute path (or its resolve-on-drop guard) still
    /// resolves it and removes the single-flight entry, so abandoning
    /// never disturbs leader/rider semantics or leaks inflight state.
    fn wait_cancellable(
        &self,
        cancel: Option<&crate::cancel::CancelToken>,
    ) -> (Option<ItemFeatures>, u64) {
        let Some(token) = cancel else { return self.wait() };
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(v) = &*st {
                return v.clone();
            }
            if token.poll().is_some() {
                return (None, 0);
            }
            st = self
                .cv
                .wait_timeout(st, Duration::from_millis(1))
                .unwrap_or_else(|e| e.into_inner())
                .0;
        }
    }
}

/// An open (not yet executed) pending batch of leader ids.
struct OpenBatch {
    ids: Vec<u64>,
    deadline: Instant,
}

struct Shard {
    /// id -> ticket for every fetch currently in flight through this
    /// coalescer (whether its batch is still open or already executing).
    inflight: HashMap<u64, Arc<Ticket>>,
    open: Option<OpenBatch>,
}

/// Counters snapshot (CLI, benches, tests).
#[derive(Clone, Debug, Default)]
pub struct FetchCoalesceStats {
    /// Ids that rode another request's in-flight fetch (the saved
    /// round-trips live here).
    pub riders: u64,
    /// Coalesced multiget queries executed against the store.
    pub batches: u64,
    /// Leader ids fetched by those batches.
    pub batched_ids: u64,
    /// Deadline flushes that merged ≥ 2 shards' batches into one query.
    pub merged_flushes: u64,
}

/// The coalescer proper. Owned by `QueryEngine` (sync cache mode only);
/// a dedicated flusher thread drives the deadline path.
pub(crate) struct FetchCoalescer {
    shards: Vec<Mutex<Shard>>,
    /// Flusher parking lot — see module docs for the lock order.
    signal: Mutex<()>,
    cv: Condvar,
    wait: Duration,
    merge_grace: Duration,
    store: Arc<RemoteStore>,
    cache: Arc<ShardedCache<ItemFeatures>>,
    store_errors: Arc<AtomicU64>,
    shutdown: AtomicBool,
    riders: AtomicU64,
    batches: AtomicU64,
    batched_ids: AtomicU64,
    merged_flushes: AtomicU64,
    recorder: Option<Arc<Recorder>>,
    /// Test hook: make the next `execute` panic after registering its
    /// resolve-on-drop guard — the leader-panic wedge regression.
    #[cfg(test)]
    test_panic_next_execute: AtomicBool,
}

impl FetchCoalescer {
    pub(crate) fn new(
        wait_us: u64,
        store: Arc<RemoteStore>,
        cache: Arc<ShardedCache<ItemFeatures>>,
        store_errors: Arc<AtomicU64>,
        recorder: Option<Arc<Recorder>>,
    ) -> Self {
        let wait = Duration::from_micros(wait_us.max(1));
        FetchCoalescer {
            shards: (0..FETCH_SHARDS)
                .map(|_| Mutex::new(Shard { inflight: HashMap::new(), open: None }))
                .collect(),
            signal: Mutex::new(()),
            cv: Condvar::new(),
            wait,
            // batches opened by one request differ by µs; flushing ≤ this
            // much early merges them into one query and is harmless
            merge_grace: (wait / 2).min(Duration::from_micros(50)),
            store,
            cache,
            store_errors,
            shutdown: AtomicBool::new(false),
            riders: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_ids: AtomicU64::new(0),
            merged_flushes: AtomicU64::new(0),
            recorder,
            #[cfg(test)]
            test_panic_next_execute: AtomicBool::new(false),
        }
    }

    #[inline]
    // lint: no_alloc — per-request hot path, must stay allocation-free
    fn shard_of(&self, id: u64) -> usize {
        (id.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 61) as usize & (FETCH_SHARDS - 1)
    }

    /// Fetch `ids` through the coalescer, blocking until every id is
    /// resolved. Returns per-id results aligned with the input; `None`
    /// means the store failed for that id's batch (the caller degrades
    /// to stale/default, same as the uncoalesced path).
    pub(crate) fn fetch(&self, ids: &[u64]) -> Vec<Option<ItemFeatures>> {
        let mut tickets: Vec<Arc<Ticket>> = Vec::with_capacity(ids.len());
        let mut filled: Vec<Vec<u64>> = Vec::new();
        let mut opened = false;
        // one deadline for every batch this call opens: however the
        // thread is scheduled mid-loop, the flusher sees identical
        // deadlines and merges a lone request's cross-shard misses into
        // one multiget deterministically
        let deadline = Instant::now() + self.wait;
        for &id in ids {
            let mut shard =
                self.shards[self.shard_of(id)].lock().unwrap_or_else(|e| e.into_inner());
            if let Some(t) = shard.inflight.get(&id) {
                // rider: someone is already fetching this id
                tickets.push(Arc::clone(t));
                self.riders.fetch_add(1, Ordering::Relaxed);
                if let Some(rec) = &self.recorder {
                    rec.record_fetch_coalesced();
                }
                continue;
            }
            let ticket = Arc::new(Ticket::new());
            shard.inflight.insert(id, Arc::clone(&ticket));
            tickets.push(ticket);
            let batch = shard.open.get_or_insert_with(|| {
                opened = true;
                OpenBatch { ids: Vec::with_capacity(FETCH_BATCH), deadline }
            });
            batch.ids.push(id);
            if batch.ids.len() >= FETCH_BATCH {
                // lint: allow(panic) guarded: full==true proves open is Some
                filled.push(shard.open.take().unwrap().ids);
            }
        }
        if opened {
            // a fresh batch sets a new earliest deadline; notify under
            // the signal mutex (never while a shard lock is held) so the
            // flusher cannot miss it between its scan and its wait
            let _parked = self.signal.lock().unwrap_or_else(|e| e.into_inner());
            self.cv.notify_all();
        }
        for ids in filled {
            self.execute_supervised(&ids, false);
        }
        // a cancelled requester abandons its waits (degrading to
        // stale/default features); the token comes off the thread, set
        // by the owning stage worker — same channel as the trace id
        let cancel = crate::cancel::current();
        let results: Vec<(Option<ItemFeatures>, u64)> =
            tickets.iter().map(|t| t.wait_cancellable(cancel.as_ref())).collect();
        // causality: this request waited on these shared fetch spans.
        // The trace id comes from the thread (set by the feature worker)
        // — riders of another request's fetch report the edge out of
        // band, since their own span for this stage does not exist yet.
        if let Some(tracer) = self.recorder.as_ref().and_then(|r| r.tracer()) {
            let trace = obs::current_trace();
            if trace != 0 {
                let mut seen: Vec<u64> = Vec::new();
                for &(_, span_id) in &results {
                    if span_id != 0 && !seen.contains(&span_id) {
                        tracer.flow(trace, span_id);
                        seen.push(span_id);
                    }
                }
            }
        }
        results.into_iter().map(|(v, _)| v).collect()
    }

    /// Run `execute` under a supervisor. `Ticket::wait` has no timeout,
    /// so a batch that unwinds mid-flight would otherwise strand its
    /// riders forever *and* (on the flusher thread) kill the deadline
    /// watcher. The drop guard inside `execute` resolves the tickets;
    /// this wrapper absorbs the unwind so the calling thread lives on.
    fn execute_supervised(&self, ids: &[u64], merged: bool) {
        // lint: supervisor — tickets resolve via execute's drop guard;
        // the calling thread (flusher or feature worker) must survive
        let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.execute(ids, merged)
        }));
        if unwound.is_err() {
            if let Some(rec) = &self.recorder {
                rec.record_worker_restart();
            }
        }
    }

    /// Run one remote multiget for `ids` and resolve their tickets —
    /// cache-insert first, so waiters (and fresh probes) hit immediately.
    /// A store timeout resolves every ticket with `None`; nothing ever
    /// leaves a waiter parked: a resolve-on-drop guard covers every exit,
    /// including a panic unwinding out of the store call.
    fn execute(&self, ids: &[u64], merged: bool) {
        debug_assert!(!ids.is_empty());
        // Resolve-on-drop: on every exit from this scope, any id still
        // holding an unresolved ticket is resolved with `None` (waiters
        // degrade to stale/default) and deregistered (the id can lead
        // again). On the normal path resolve() already emptied the
        // inflight slots, so this sweep is a no-op.
        struct ResolveRemaining<'a> {
            co: &'a FetchCoalescer,
            ids: &'a [u64],
        }
        impl Drop for ResolveRemaining<'_> {
            fn drop(&mut self) {
                if std::thread::panicking() {
                    self.co.store_errors.fetch_add(1, Ordering::Relaxed);
                }
                for &id in self.ids {
                    self.co.resolve(id, None, 0);
                }
            }
        }
        let _resolve_all = ResolveRemaining { co: self, ids };
        #[cfg(test)]
        if self.test_panic_next_execute.swap(false, Ordering::Relaxed) {
            // lint: allow(panic) test-injected executor crash, absorbed by execute_supervised
            panic!("test: injected execute panic");
        }
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_ids.fetch_add(ids.len() as u64, Ordering::Relaxed);
        if merged {
            self.merged_flushes.fetch_add(1, Ordering::Relaxed);
        }
        if let Some(rec) = &self.recorder {
            rec.record_fetch_batch();
        }
        let tracing = self
            .recorder
            .as_ref()
            .and_then(|r| r.tracer().map(|t| (Arc::clone(t), r.tracer_pid())));
        let begin_us = tracing.as_ref().map_or(0, |(t, _)| t.now_us());
        let result = self.store.try_fetch_batch(ids);
        // one shared span per multiget (failed fetches too — a timed-out
        // store round-trip is exactly what a slow trace should show)
        let span_id = match &tracing {
            Some((t, pid)) => {
                let id = t.new_span_id();
                t.emit_shared(SharedSpan {
                    span_id: id,
                    kind: StageKind::Fetch,
                    label: format!(
                        "multiget ×{}{}",
                        ids.len(),
                        if merged { " (merged)" } else { "" }
                    ),
                    begin_us,
                    end_us: t.now_us(),
                    pid: *pid,
                    tid: obs::tid(),
                    member_traces: Vec::new(),
                });
                id
            }
            None => 0,
        };
        match result {
            Ok(fetched) => {
                for f in fetched {
                    self.cache.insert(f.item_id, f.clone());
                    self.resolve(f.item_id, Some(f), span_id);
                }
            }
            Err(_) => {
                self.store_errors.fetch_add(1, Ordering::Relaxed);
                for &id in ids {
                    self.resolve(id, None, span_id);
                }
            }
        }
    }

    fn resolve(&self, id: u64, value: Option<ItemFeatures>, span_id: u64) {
        let shard = &self.shards[self.shard_of(id)];
        let ticket = shard.lock().unwrap_or_else(|e| e.into_inner()).inflight.remove(&id);
        if let Some(t) = ticket {
            t.resolve(value, span_id);
        }
    }

    /// Deadline watcher: merges expired shard batches into one multiget;
    /// parked on the condvar otherwise. Runs on a dedicated thread until
    /// [`FetchCoalescer::begin_shutdown`].
    pub(crate) fn run_flusher(&self) {
        let mut parked = self.signal.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if self.shutdown.load(Ordering::Acquire) {
                drop(parked);
                // drain: resolve every open batch so no waiter is left
                let leftover = self.collect_expired(Instant::now() + self.wait + self.wait);
                if !leftover.is_empty() {
                    self.execute_supervised(&leftover, false);
                }
                return;
            }
            let now = Instant::now();
            let expired = self.collect_expired(now + self.merge_grace);
            if !expired.is_empty() {
                let merged = {
                    // merged = ids from > 1 shard; cheap proxy: did more
                    // than one shard contribute? Track via shard spread.
                    expired.len() > 1
                        && expired.iter().any(|&a| self.shard_of(a) != self.shard_of(expired[0]))
                };
                drop(parked);
                self.execute_supervised(&expired, merged);
                parked = self.signal.lock().unwrap_or_else(|e| e.into_inner());
                continue;
            }
            let next = self.earliest_deadline();
            parked = match next {
                None => self.cv.wait(parked).unwrap_or_else(|e| e.into_inner()),
                Some(deadline) => {
                    self.cv
                        .wait_timeout(parked, deadline.saturating_duration_since(now))
                        .unwrap_or_else(|e| e.into_inner())
                        .0
                }
            };
        }
    }

    /// Take every open batch whose deadline is at or before `cutoff`,
    /// merged into one id list. Shard locks are taken briefly, one at a
    /// time (under `signal` when called from the flusher — same order
    /// discipline as `dso::Coalescer`).
    fn collect_expired(&self, cutoff: Instant) -> Vec<u64> {
        let mut ids = Vec::new();
        for shard in &self.shards {
            let mut s = shard.lock().unwrap_or_else(|e| e.into_inner());
            if s.open.as_ref().is_some_and(|b| b.deadline <= cutoff) {
                // lint: allow(panic) guarded: the is_some_and check proves open is Some
                ids.extend(s.open.take().unwrap().ids);
            }
        }
        ids
    }

    fn earliest_deadline(&self) -> Option<Instant> {
        let mut next: Option<Instant> = None;
        for shard in &self.shards {
            let s = shard.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(b) = &s.open {
                next = Some(next.map_or(b.deadline, |n| n.min(b.deadline)));
            }
        }
        next
    }

    /// Stop the flusher (it drains open batches on the way out).
    pub(crate) fn begin_shutdown(&self) {
        let _parked = self.signal.lock().unwrap_or_else(|e| e.into_inner());
        self.shutdown.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    /// Single-flight entries currently registered across every shard
    /// (leak assertions: zero once all in-flight fetches resolved).
    pub(crate) fn inflight_len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(|e| e.into_inner()).inflight.len())
            .sum()
    }

    pub(crate) fn stats(&self) -> FetchCoalesceStats {
        FetchCoalesceStats {
            riders: self.riders.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_ids: self.batched_ids.load(Ordering::Relaxed),
            merged_flushes: self.merged_flushes.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurestore::FeatureSchema;
    use crate::netsim::{Link, LinkConfig};
    use std::sync::Barrier;

    fn parts() -> (Arc<RemoteStore>, Arc<ShardedCache<ItemFeatures>>) {
        let link = Arc::new(Link::new(LinkConfig {
            rtt: Duration::from_micros(300),
            bandwidth_bps: 1e9,
            jitter: 0.0,
            fail_rate: 0.0,
        }));
        let store = Arc::new(RemoteStore::new(FeatureSchema::default(), link, 11));
        let cache = Arc::new(ShardedCache::new(1024, 4, Duration::from_secs(60)));
        (store, cache)
    }

    fn spawn(co: &Arc<FetchCoalescer>) -> std::thread::JoinHandle<()> {
        let runner = Arc::clone(co);
        std::thread::spawn(move || runner.run_flusher())
    }

    #[test]
    fn concurrent_same_id_single_flights() {
        const N: usize = 8;
        let (store, cache) = parts();
        let errors = Arc::new(AtomicU64::new(0));
        // window wide enough that all N threads join before the flush,
        // even when a thread is badly descheduled after the barrier
        let co = Arc::new(FetchCoalescer::new(
            200_000,
            Arc::clone(&store),
            cache,
            errors,
            None,
        ));
        let flusher = spawn(&co);
        let barrier = Arc::new(Barrier::new(N));
        let got: Vec<Option<ItemFeatures>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..N)
                .map(|_| {
                    let co = Arc::clone(&co);
                    let barrier = Arc::clone(&barrier);
                    s.spawn(move || {
                        barrier.wait();
                        co.fetch(&[42]).pop().unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let first = got[0].clone().expect("fetch succeeded");
        assert!(got.iter().all(|g| g.as_ref() == Some(&first)));
        assert_eq!(store.link().queries_total(), 1, "one round-trip for N concurrent misses");
        let stats = co.stats();
        assert_eq!(stats.batched_ids, 1);
        assert_eq!(stats.riders as usize, N - 1);
        co.begin_shutdown();
        flusher.join().unwrap();
    }

    #[test]
    fn lone_request_cross_shard_misses_merge_into_one_query() {
        let (store, cache) = parts();
        let co = Arc::new(FetchCoalescer::new(
            200,
            Arc::clone(&store),
            cache,
            Arc::new(AtomicU64::new(0)),
            None,
        ));
        let flusher = spawn(&co);
        // ids chosen to spread over shards; none fill a batch, so the
        // deadline flusher must merge them into a single multiget
        let ids: Vec<u64> = (0..12).collect();
        let got = co.fetch(&ids);
        assert!(got.iter().all(|g| g.is_some()));
        assert_eq!(
            store.link().queries_total(),
            1,
            "cross-shard partial batches must merge at the deadline"
        );
        co.begin_shutdown();
        flusher.join().unwrap();
    }

    #[test]
    fn filled_batch_executes_without_waiting_for_deadline() {
        let (store, cache) = parts();
        // wait long enough that a deadline flush inside this test would fail it
        let co = Arc::new(FetchCoalescer::new(
            2_000_000,
            Arc::clone(&store),
            cache,
            Arc::new(AtomicU64::new(0)),
            None,
        ));
        let flusher = spawn(&co);
        // FETCH_BATCH ids all hashing to one shard: that batch fills
        // exactly, so the flush is fill-triggered, not deadline-driven
        let shard0 = co.shard_of(0);
        let ids: Vec<u64> = (0..).filter(|&i| co.shard_of(i) == shard0).take(FETCH_BATCH).collect();
        let t0 = Instant::now();
        let got = co.fetch(&ids);
        assert!(t0.elapsed() < Duration::from_secs(1), "fill-triggered flush did not fire");
        assert!(got.iter().all(|g| g.is_some()));
        assert_eq!(store.link().queries_total(), 1);
        co.begin_shutdown();
        flusher.join().unwrap();
    }

    #[test]
    fn store_failure_resolves_waiters_with_none() {
        let link = Arc::new(Link::new(LinkConfig {
            rtt: Duration::from_micros(100),
            bandwidth_bps: 1e9,
            jitter: 0.0,
            fail_rate: 1.0, // every transfer times out
        }));
        let store = Arc::new(RemoteStore::new(FeatureSchema::default(), link, 11));
        let cache = Arc::new(ShardedCache::new(64, 2, Duration::from_secs(60)));
        let errors = Arc::new(AtomicU64::new(0));
        let co = Arc::new(FetchCoalescer::new(100, store, cache, Arc::clone(&errors), None));
        let flusher = spawn(&co);
        let got = co.fetch(&[1, 2, 3]);
        assert!(got.iter().all(|g| g.is_none()), "failed batch must resolve with None");
        assert!(errors.load(Ordering::Relaxed) >= 1);
        co.begin_shutdown();
        flusher.join().unwrap();
    }

    /// Regression (executor panic wedge): before the resolve-on-drop
    /// guard, a panic unwinding out of `execute` left its tickets in
    /// `inflight` unresolved — every rider of those ids waited forever
    /// on an untimed condvar, the ids were permanently poisoned, and
    /// (when it fired on the flusher thread) the deadline watcher died
    /// with it. Now: waiters resolve with `None`, the ids are free to
    /// lead again, and the flusher survives to drive the retry.
    #[test]
    fn executor_panic_resolves_waiters_and_frees_the_ids() {
        let (store, cache) = parts();
        let errors = Arc::new(AtomicU64::new(0));
        let co = Arc::new(FetchCoalescer::new(
            500,
            Arc::clone(&store),
            cache,
            Arc::clone(&errors),
            None,
        ));
        let flusher = spawn(&co);
        co.test_panic_next_execute.store(true, Ordering::Relaxed);
        let got = co.fetch(&[7, 8]);
        assert!(
            got.iter().all(|g| g.is_none()),
            "a panicking executor must resolve its tickets with None, not wedge them"
        );
        assert!(errors.load(Ordering::Relaxed) >= 1, "the unwound batch counts as a store error");
        // the ids are free again: the retry leads a fresh fetch, and the
        // flusher survived the panic to execute it
        let retry = co.fetch(&[7, 8]);
        assert!(retry.iter().all(|g| g.is_some()), "retry must re-lead after the failed flight");
        co.begin_shutdown();
        flusher.join().unwrap();
    }

    #[test]
    fn shutdown_drains_open_batches() {
        let (store, cache) = parts();
        let co = Arc::new(FetchCoalescer::new(
            5_000_000, // far future deadline: only shutdown can flush
            Arc::clone(&store),
            cache,
            Arc::new(AtomicU64::new(0)),
            None,
        ));
        let flusher = spawn(&co);
        let waiter = {
            let co = Arc::clone(&co);
            std::thread::spawn(move || co.fetch(&[7]))
        };
        std::thread::sleep(Duration::from_millis(20));
        co.begin_shutdown();
        flusher.join().unwrap();
        let got = waiter.join().unwrap();
        assert!(got[0].is_some(), "shutdown drain must resolve parked waiters");
    }
}
