//! Input assembly: the last CPU-side stage of the PDA pipeline.
//!
//! Takes a request's user history (item ids) + candidate ids, pulls item
//! features through the query engine, folds them into embeddings, and
//! writes the model's two input tensors — hist [L, D] and cands [M, D] —
//! either into a reusable `StagingArena` ("Mem Opt" on) or into fresh
//! per-request `Vec`s (the pageable-memory baseline arm of Table 3).

use std::sync::Arc;

use crate::embedding::EmbeddingTable;
use crate::pda::engine::{FetchClass, QueryEngine};
use crate::pda::staging::{Region, StagingArena};

/// Assembled model input: views or buffers for the two tensors.
pub struct AssembledInput {
    /// [L * D] row-major history embeddings.
    pub hist: InputBuf,
    /// [M * D] candidate embeddings.
    pub cands: InputBuf,
    /// How many candidate fetches were fresh/stale/missing (telemetry).
    pub fresh: usize,
    pub stale: usize,
    pub missing: usize,
}

/// Owned-or-staged input storage.
pub enum InputBuf {
    Owned(Vec<f32>),
    Staged(Region),
}

/// The assembler: embeddings + feature folding + tensor layout.
pub struct InputAssembler {
    table: Arc<EmbeddingTable>,
    query: Arc<QueryEngine>,
    d: usize,
    use_staging: bool,
}

impl InputAssembler {
    pub fn new(
        table: Arc<EmbeddingTable>,
        query: Arc<QueryEngine>,
        use_staging: bool,
    ) -> Self {
        let d = table.dim();
        InputAssembler { table, query, d, use_staging }
    }

    pub fn query_engine(&self) -> &Arc<QueryEngine> {
        &self.query
    }

    /// Assemble one raw request: truncate/zero-pad `history` to exactly
    /// `l` ids in a worker-local scratch (the hot path must not clone +
    /// resize a fresh `Vec` per request), then [`InputAssembler::assemble`].
    /// Shared by the synchronous serve path and the pipeline's
    /// feature-stage workers so the two can never diverge on padding.
    pub fn assemble_request(
        &self,
        history: &[u64],
        l: usize,
        candidates: &[u64],
        arena: &mut StagingArena,
    ) -> AssembledInput {
        thread_local! {
            static HIST_SCRATCH: std::cell::RefCell<Vec<u64>> =
                std::cell::RefCell::new(Vec::new());
        }
        HIST_SCRATCH.with(|scratch| {
            let mut padded = scratch.borrow_mut();
            padded.clear();
            padded.extend_from_slice(&history[..history.len().min(l)]);
            padded.resize(l, 0); // pad short histories to L
            self.assemble(&padded, candidates, arena)
        })
    }

    /// Assemble one request. `arena` is reset and reused when staging is
    /// enabled; ignored otherwise.
    pub fn assemble(
        &self,
        history: &[u64],
        candidates: &[u64],
        arena: &mut StagingArena,
    ) -> AssembledInput {
        // Item features for candidates go through the cached query engine
        // (the expensive, network-facing path the PDA optimizes).
        let fetched = self.query.fetch(candidates);
        let (mut fresh, mut stale, mut missing) = (0usize, 0usize, 0usize);
        for (_, class) in &fetched {
            match class {
                FetchClass::Fresh => fresh += 1,
                FetchClass::Stale => stale += 1,
                FetchClass::MissDefault => missing += 1,
                FetchClass::Remote => fresh += 1,
            }
        }

        let hist_len = history.len() * self.d;
        let cand_len = candidates.len() * self.d;

        if self.use_staging {
            arena.reset();
            let hr = arena.alloc(hist_len);
            {
                let hs = arena.slice_mut(hr);
                for (i, &id) in history.iter().enumerate() {
                    self.table.embed_into(id, &mut hs[i * self.d..(i + 1) * self.d]);
                }
            }
            let cr = arena.alloc(cand_len);
            {
                let cs = arena.slice_mut(cr);
                for (i, (f, _)) in fetched.iter().enumerate() {
                    self.table.embed_with_features_into(
                        f.item_id,
                        &f.dense,
                        &mut cs[i * self.d..(i + 1) * self.d],
                    );
                }
            }
            AssembledInput {
                hist: InputBuf::Staged(hr),
                cands: InputBuf::Staged(cr),
                fresh,
                stale,
                missing,
            }
        } else {
            // baseline arm: fresh allocations + per-row copies
            let mut hist = vec![0.0f32; hist_len];
            for (i, &id) in history.iter().enumerate() {
                self.table.embed_into(id, &mut hist[i * self.d..(i + 1) * self.d]);
            }
            let mut cands = vec![0.0f32; cand_len];
            for (i, (f, _)) in fetched.iter().enumerate() {
                self.table.embed_with_features_into(
                    f.item_id,
                    &f.dense,
                    &mut cands[i * self.d..(i + 1) * self.d],
                );
            }
            AssembledInput {
                hist: InputBuf::Owned(hist),
                cands: InputBuf::Owned(cands),
                fresh,
                stale,
                missing,
            }
        }
    }
}

impl AssembledInput {
    /// Resolve the two tensors against the arena they may live in.
    pub fn views<'a>(&'a self, arena: &'a StagingArena) -> (&'a [f32], &'a [f32]) {
        let h = match &self.hist {
            InputBuf::Owned(v) => v.as_slice(),
            InputBuf::Staged(r) => arena.slice(*r),
        };
        let c = match &self.cands {
            InputBuf::Owned(v) => v.as_slice(),
            InputBuf::Staged(r) => arena.slice(*r),
        };
        (h, c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CacheMode, PdaConfig};
    use crate::featurestore::{FeatureSchema, RemoteStore};
    use crate::netsim::{Link, LinkConfig};
    use std::time::Duration;

    fn engine(mode: CacheMode) -> Arc<QueryEngine> {
        let link = Arc::new(Link::new(LinkConfig {
            rtt: Duration::from_micros(100),
            bandwidth_bps: 1e9,
            jitter: 0.0,
            fail_rate: 0.0,
        }));
        let store = Arc::new(RemoteStore::new(FeatureSchema::default(), link, 11));
        Arc::new(QueryEngine::new(
            &PdaConfig { cache_mode: mode, ..PdaConfig::default() },
            store,
        ))
    }

    fn assembler(staging: bool, mode: CacheMode) -> InputAssembler {
        let table = Arc::new(EmbeddingTable::new(8, 3, 1024));
        InputAssembler::new(table, engine(mode), staging)
    }

    #[test]
    fn staged_and_owned_agree() {
        let hist_ids = vec![1u64, 2, 3, 4];
        let cand_ids = vec![10u64, 11];
        let mut arena = StagingArena::new(1024);

        let a = assembler(true, CacheMode::Sync);
        let staged = a.assemble(&hist_ids, &cand_ids, &mut arena);
        let (sh, sc) = staged.views(&arena);
        let (sh, sc) = (sh.to_vec(), sc.to_vec());

        let b = assembler(false, CacheMode::Sync);
        let mut dummy = StagingArena::new(1);
        let owned = b.assemble(&hist_ids, &cand_ids, &mut dummy);
        let (oh, oc) = owned.views(&dummy);

        assert_eq!(sh, oh);
        assert_eq!(sc, oc);
    }

    #[test]
    fn shapes_match_request() {
        let a = assembler(true, CacheMode::Sync);
        let mut arena = StagingArena::new(4096);
        let out = a.assemble(&[1, 2, 3], &[7, 8, 9, 10], &mut arena);
        let (h, c) = out.views(&arena);
        assert_eq!(h.len(), 3 * 8);
        assert_eq!(c.len(), 4 * 8);
    }

    #[test]
    fn async_mode_counts_missing() {
        let a = assembler(true, CacheMode::Async);
        let mut arena = StagingArena::new(4096);
        let out = a.assemble(&[1], &[100, 101], &mut arena);
        assert_eq!(out.missing, 2, "cold cache: all candidates missing");
        a.query_engine().drain_refreshes();
        let out2 = a.assemble(&[1], &[100, 101], &mut arena);
        assert_eq!(out2.fresh, 2);
    }

    #[test]
    fn missing_features_still_wellformed() {
        let a = assembler(true, CacheMode::Async);
        let mut arena = StagingArena::new(4096);
        let out = a.assemble(&[1, 2], &[50], &mut arena);
        let (_, c) = out.views(&arena);
        assert!(c.iter().all(|x| x.is_finite()));
        assert!(c.iter().any(|&x| x != 0.0), "base embedding present");
    }
}
