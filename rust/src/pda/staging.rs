//! Staging arenas — the pinned-memory analogue (paper §3.1, Fig 7).
//!
//! The paper replaces pageable host allocations with `cudaMallocHost`
//! pinned buffers and "packages model input variables as a whole to batch
//! many small transfers together into a single transfer". On the CPU
//! PJRT testbed the same pathology exists as per-request `Vec` churn and
//! scattered small copies. A `StagingArena` is a preallocated, reused
//! contiguous buffer: the assembler writes embeddings/features directly
//! into it and the runtime uploads one contiguous slice per tensor.

/// A reusable contiguous f32 staging buffer.
pub struct StagingArena {
    buf: Vec<f32>,
    len: usize,
}

impl StagingArena {
    /// Preallocate `capacity` f32 slots.
    pub fn new(capacity: usize) -> Self {
        StagingArena { buf: vec![0.0; capacity], len: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Reset write position (no dealloc/realloc — that's the point).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Reserve a contiguous region of `n` f32s, growing only if the
    /// request exceeds capacity (shouldn't happen after sizing for the
    /// max profile; growth is counted so tests can assert it doesn't).
    pub fn alloc(&mut self, n: usize) -> Region {
        if self.len + n > self.buf.len() {
            self.buf.resize((self.len + n).next_power_of_two(), 0.0);
        }
        let r = Region { start: self.len, len: n };
        self.len += n;
        r
    }

    /// Mutable view of a region.
    pub fn slice_mut(&mut self, r: Region) -> &mut [f32] {
        &mut self.buf[r.start..r.start + r.len]
    }

    /// Shared view of a region (what the runtime uploads).
    pub fn slice(&self, r: Region) -> &[f32] {
        &self.buf[r.start..r.start + r.len]
    }

    /// Copy `src` into a fresh region (the "batch small transfers"
    /// primitive) and return it.
    pub fn stage(&mut self, src: &[f32]) -> Region {
        let r = self.alloc(src.len());
        self.slice_mut(r).copy_from_slice(src);
        r
    }
}

/// A (start, len) region inside an arena.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Region {
    pub start: usize,
    pub len: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_write() {
        let mut a = StagingArena::new(16);
        let r1 = a.alloc(4);
        a.slice_mut(r1).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let r2 = a.stage(&[9.0, 8.0]);
        assert_eq!(a.slice(r1), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.slice(r2), &[9.0, 8.0]);
        assert_eq!(a.len(), 6);
    }

    #[test]
    fn reset_reuses_without_realloc() {
        let mut a = StagingArena::new(8);
        let p0 = a.buf.as_ptr();
        for _ in 0..100 {
            a.reset();
            let r = a.stage(&[1.0; 8]);
            assert_eq!(r.start, 0);
        }
        assert_eq!(p0, a.buf.as_ptr(), "arena must not reallocate within capacity");
    }

    #[test]
    fn grows_beyond_capacity() {
        let mut a = StagingArena::new(4);
        let r = a.stage(&[0.5; 10]);
        assert_eq!(a.slice(r).len(), 10);
        assert!(a.capacity() >= 10);
    }

    #[test]
    fn regions_disjoint() {
        let mut a = StagingArena::new(32);
        let r1 = a.alloc(8);
        let r2 = a.alloc(8);
        assert_eq!(r1.start + r1.len, r2.start);
    }
}
