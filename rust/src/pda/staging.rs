//! Staging arenas — the pinned-memory analogue (paper §3.1, Fig 7).
//!
//! The paper replaces pageable host allocations with `cudaMallocHost`
//! pinned buffers and "packages model input variables as a whole to batch
//! many small transfers together into a single transfer". On the CPU
//! PJRT testbed the same pathology exists as per-request `Vec` churn and
//! scattered small copies. A `StagingArena` is a preallocated, reused
//! contiguous buffer: the assembler writes embeddings/features directly
//! into it and the runtime uploads one contiguous slice per tensor.

/// A reusable contiguous f32 staging buffer.
pub struct StagingArena {
    buf: Vec<f32>,
    len: usize,
    /// Times `alloc` had to grow the buffer past its preallocated
    /// capacity. Steady-state serving must keep this at zero (a growth
    /// is a hidden pageable reallocation — exactly what arenas exist to
    /// avoid); the pipeline mirrors the count into `metrics::Recorder`.
    growths: u64,
}

impl StagingArena {
    /// Preallocate `capacity` f32 slots.
    pub fn new(capacity: usize) -> Self {
        StagingArena { buf: vec![0.0; capacity], len: 0, growths: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Growths since construction (see the field doc).
    pub fn growth_count(&self) -> u64 {
        self.growths
    }

    /// Reset write position (no dealloc/realloc — that's the point).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Reserve a contiguous region of `n` f32s, growing only if the
    /// request exceeds capacity (shouldn't happen after sizing for the
    /// max profile; growth is counted so tests can assert it doesn't).
    pub fn alloc(&mut self, n: usize) -> Region {
        if self.len + n > self.buf.len() {
            self.buf.resize((self.len + n).next_power_of_two(), 0.0);
            self.growths += 1;
        }
        let r = Region { start: self.len, len: n };
        self.len += n;
        r
    }

    /// Mutable view of a region.
    pub fn slice_mut(&mut self, r: Region) -> &mut [f32] {
        &mut self.buf[r.start..r.start + r.len]
    }

    /// Shared view of a region (what the runtime uploads).
    pub fn slice(&self, r: Region) -> &[f32] {
        &self.buf[r.start..r.start + r.len]
    }

    /// Copy `src` into a fresh region (the "batch small transfers"
    /// primitive) and return it.
    pub fn stage(&mut self, src: &[f32]) -> Region {
        let r = self.alloc(src.len());
        self.slice_mut(r).copy_from_slice(src);
        r
    }
}

/// A (start, len) region inside an arena.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Region {
    pub start: usize,
    pub len: usize,
}

/// A bounded pool of staging arenas shared between the pipeline's
/// feature-stage workers and compute-stage submitters.
///
/// In the decoupled pipeline an arena's lifetime spans two threads: a
/// feature worker assembles into it, the staged request rides the
/// handoff queue, and a compute submitter holds it until the DSO
/// orchestrator has consumed its tensor views — only then does it
/// return here. A fixed arena set bounds staging memory; `get` blocks
/// when every arena is in flight, which is part of the pipeline's
/// backpressure chain (feature workers stall → the intake queue fills →
/// admission sheds).
pub struct ArenaPool {
    arenas: std::sync::Mutex<Vec<StagingArena>>,
    available: std::sync::Condvar,
    total: usize,
}

impl ArenaPool {
    /// Pre-create `n` arenas of `capacity` f32 slots each.
    pub fn new(n: usize, capacity: usize) -> Self {
        let n = n.max(1);
        ArenaPool {
            arenas: std::sync::Mutex::new(
                (0..n).map(|_| StagingArena::new(capacity)).collect(),
            ),
            available: std::sync::Condvar::new(),
            total: n,
        }
    }

    /// Take an arena, blocking until one returns if all are in flight.
    pub fn get(&self) -> StagingArena {
        let mut g = self.arenas.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(a) = g.pop() {
                return a;
            }
            g = self.available.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking take (tests/diagnostics).
    pub fn try_get(&self) -> Option<StagingArena> {
        self.arenas.lock().unwrap_or_else(|e| e.into_inner()).pop()
    }

    /// Return an arena after its views have been consumed. The arena is
    /// reset here so the next `get` never observes a stale write offset.
    pub fn put(&self, mut arena: StagingArena) {
        arena.reset();
        self.arenas.lock().unwrap_or_else(|e| e.into_inner()).push(arena);
        self.available.notify_one();
    }

    /// Arenas the pool was built with.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Arenas currently checked in (idle).
    pub fn idle(&self) -> usize {
        self.arenas.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_write() {
        let mut a = StagingArena::new(16);
        let r1 = a.alloc(4);
        a.slice_mut(r1).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let r2 = a.stage(&[9.0, 8.0]);
        assert_eq!(a.slice(r1), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.slice(r2), &[9.0, 8.0]);
        assert_eq!(a.len(), 6);
    }

    #[test]
    fn reset_reuses_without_realloc() {
        let mut a = StagingArena::new(8);
        let p0 = a.buf.as_ptr();
        for _ in 0..100 {
            a.reset();
            let r = a.stage(&[1.0; 8]);
            assert_eq!(r.start, 0);
        }
        assert_eq!(p0, a.buf.as_ptr(), "arena must not reallocate within capacity");
    }

    #[test]
    fn grows_beyond_capacity() {
        let mut a = StagingArena::new(4);
        assert_eq!(a.growth_count(), 0);
        let r = a.stage(&[0.5; 10]);
        assert_eq!(a.slice(r).len(), 10);
        assert!(a.capacity() >= 10);
        assert_eq!(a.growth_count(), 1, "growth must be counted");
        // within the grown capacity: no further growth
        a.reset();
        a.stage(&[0.5; 10]);
        assert_eq!(a.growth_count(), 1);
    }

    #[test]
    fn pool_reuses_and_resets_arenas() {
        let pool = ArenaPool::new(1, 16);
        assert_eq!((pool.total(), pool.idle()), (1, 1));
        let mut a = pool.get();
        assert_eq!(pool.idle(), 0);
        let r = a.alloc(8);
        let p0 = a.slice(r).as_ptr();
        assert_eq!(a.len(), 8);
        pool.put(a);
        let b = pool.get();
        assert_eq!(b.len(), 0, "returned arena must come back reset");
        assert_eq!(b.slice(Region { start: 0, len: 1 }).as_ptr(), p0, "same buffer reused");
        assert!(pool.try_get().is_none(), "single-arena pool is exhausted");
        pool.put(b);
    }

    #[test]
    fn pool_get_blocks_until_put() {
        let pool = std::sync::Arc::new(ArenaPool::new(1, 8));
        let a = pool.get();
        let waiter = {
            let pool = std::sync::Arc::clone(&pool);
            std::thread::spawn(move || {
                let _a = pool.get();
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        // can only trip if get() handed out an arena that was never
        // returned — never because the waiter merely started late
        assert!(!waiter.is_finished(), "get returned without an available arena");
        pool.put(a);
        waiter.join().unwrap();
    }

    #[test]
    fn regions_disjoint() {
        let mut a = StagingArena::new(32);
        let r1 = a.alloc(8);
        let r2 = a.alloc(8);
        assert_eq!(r1.start + r1.len, r2.start);
    }
}
