//! The cached feature-query engine (paper §3.1, Fig 5).
//!
//! Two flows over the sharded TTL-LRU item cache:
//!
//! * **async** (stale-while-revalidate): fresh hit → return; stale hit →
//!   return the stale value immediately and enqueue a background refresh;
//!   miss → return a zero/default feature and enqueue a refresh. Never
//!   blocks on the network; trades occasional missing/stale features for
//!   latency (exactly the accuracy note in §3.1).
//! * **sync**: fresh hit → return; stale/miss → blocking remote query,
//!   update cache, return the fresh value (accuracy-preserving).
//!
//! `CacheMode::Off` bypasses the cache entirely (the Table 3 baseline).
//!
//! With `PdaConfig::fetch_coalesce` on (sync mode), concurrent requests'
//! cache misses go through the [`FetchCoalescer`]: per-id single-flight
//! plus shared multiget batches bounded by `fetch_wait_us` — K in-flight
//! requests missing the same hot id pay one `Link` round-trip, not K.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::cache::{Lookup, ShardedCache};
use crate::config::{CacheMode, PdaConfig};
use crate::featurestore::{ItemFeatures, RemoteStore};
use crate::metrics::Recorder;
use crate::pda::fetch_coalescer::{FetchCoalesceStats, FetchCoalescer};
use crate::util::threadpool::ThreadPool;

/// Outcome classification for one item fetch (per-request accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FetchClass {
    Fresh,
    Stale,
    MissDefault,
    Remote,
}

/// The query engine fronting the remote store.
pub struct QueryEngine {
    mode: CacheMode,
    cache: Arc<ShardedCache<ItemFeatures>>,
    store: Arc<RemoteStore>,
    refresh_pool: Option<ThreadPool>,
    /// Keys currently being refreshed (dedups concurrent refreshes of a
    /// hot key — important precisely because traffic is Zipf-skewed).
    in_refresh: Arc<Mutex<HashSet<u64>>>,
    /// Pending refresh ids, drained in *batches* by the workers — one
    /// remote query per batch, not per item (the same batching the sync
    /// path gets for free, and what keeps refresh traffic off the
    /// request path's link budget).
    pending: Arc<Mutex<Vec<u64>>>,
    drain_scheduled: Arc<AtomicBool>,
    /// Remote-store timeouts observed (failure-injection telemetry).
    pub store_errors: Arc<std::sync::atomic::AtomicU64>,
    /// Shared zero-row default for missing features — one allocation per
    /// schema, cloned by refcount per missing item.
    zero_row: Arc<[f32]>,
    /// Cross-request miss coalescer (sync mode + `fetch_coalesce` only).
    fetch_coalescer: Option<Arc<FetchCoalescer>>,
    fetch_flusher: Option<std::thread::JoinHandle<()>>,
}

/// Max items folded into one background refresh query.
const REFRESH_BATCH: usize = 64;

impl QueryEngine {
    pub fn new(cfg: &PdaConfig, store: Arc<RemoteStore>) -> Self {
        Self::new_with_recorder(cfg, store, None)
    }

    /// Like [`QueryEngine::new`], with fetch-coalescer telemetry mirrored
    /// into `recorder` (the serving stack's metrics).
    pub fn new_with_recorder(
        cfg: &PdaConfig,
        store: Arc<RemoteStore>,
        recorder: Option<Arc<Recorder>>,
    ) -> Self {
        let cache = Arc::new(ShardedCache::new(
            cfg.cache_capacity,
            cfg.cache_shards,
            std::time::Duration::from_millis(cfg.cache_ttl_ms),
        ));
        let refresh_pool = match cfg.cache_mode {
            CacheMode::Async => {
                Some(ThreadPool::new(cfg.refresh_workers.max(1), "pda-refresh", None))
            }
            _ => None,
        };
        let store_errors = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let zero_row: Arc<[f32]> = vec![0.0f32; store.schema().dense_dims].into();
        let (fetch_coalescer, fetch_flusher) =
            if cfg.fetch_coalesce && cfg.cache_mode == CacheMode::Sync {
                let co = Arc::new(FetchCoalescer::new(
                    cfg.fetch_wait_us,
                    Arc::clone(&store),
                    Arc::clone(&cache),
                    Arc::clone(&store_errors),
                    recorder,
                ));
                let runner = Arc::clone(&co);
                let handle = std::thread::Builder::new()
                    .name("pda-fetch-flush".into())
                    .spawn(move || runner.run_flusher())
                    // lint: allow(panic) flusher spawn at startup is unrecoverable
                    .expect("spawn fetch flusher");
                (Some(co), Some(handle))
            } else {
                (None, None)
            };
        QueryEngine {
            mode: cfg.cache_mode,
            cache,
            store,
            refresh_pool,
            in_refresh: Arc::new(Mutex::new(HashSet::new())),
            pending: Arc::new(Mutex::new(Vec::new())),
            drain_scheduled: Arc::new(AtomicBool::new(false)),
            store_errors,
            zero_row,
            fetch_coalescer,
            fetch_flusher,
        }
    }

    pub fn cache(&self) -> &ShardedCache<ItemFeatures> {
        &self.cache
    }

    pub fn mode(&self) -> CacheMode {
        self.mode
    }

    /// Fetch features for a batch of items according to the engine mode.
    /// Returns per-item features plus the fetch classification.
    pub fn fetch(&self, item_ids: &[u64]) -> Vec<(ItemFeatures, FetchClass)> {
        match self.mode {
            CacheMode::Off => self
                .store
                .fetch_batch(item_ids)
                .into_iter()
                .map(|f| (f, FetchClass::Remote))
                .collect(),
            CacheMode::Async => self.fetch_async(item_ids),
            CacheMode::Sync => self.fetch_sync(item_ids),
        }
    }

    fn fetch_async(&self, item_ids: &[u64]) -> Vec<(ItemFeatures, FetchClass)> {
        let mut out = Vec::with_capacity(item_ids.len());
        for &id in item_ids {
            match self.cache.get(id) {
                Lookup::Fresh(f) => out.push((f, FetchClass::Fresh)),
                Lookup::Stale(f) => {
                    self.spawn_refresh(id);
                    out.push((f, FetchClass::Stale));
                }
                Lookup::Miss => {
                    self.spawn_refresh(id);
                    // empty result now; features arrive for later requests
                    out.push((self.default_features(id), FetchClass::MissDefault));
                }
            }
        }
        out
    }

    fn fetch_sync(&self, item_ids: &[u64]) -> Vec<(ItemFeatures, FetchClass)> {
        let mut out: Vec<Option<(ItemFeatures, FetchClass)>> = vec![None; item_ids.len()];
        // misses carry their stale value (if any) for timeout fallback
        let mut need: Vec<(usize, u64, Option<ItemFeatures>)> = Vec::new();
        for (i, &id) in item_ids.iter().enumerate() {
            match self.cache.get(id) {
                Lookup::Fresh(f) => out[i] = Some((f, FetchClass::Fresh)),
                Lookup::Stale(f) => need.push((i, id, Some(f))),
                Lookup::Miss => need.push((i, id, None)),
            }
        }
        if !need.is_empty() {
            if let Some(co) = &self.fetch_coalescer {
                // coalesced path: misses single-flight per id and pack
                // into shared multiget batches with other in-flight
                // requests (values are identical either way — the store
                // is deterministic per (id, epoch))
                let ids: Vec<u64> = need.iter().map(|&(_, id, _)| id).collect();
                let fetched = co.fetch(&ids);
                for ((i, id, stale), f) in need.into_iter().zip(fetched) {
                    out[i] = Some(match f {
                        Some(f) => (f, FetchClass::Remote),
                        // store failed for this id's batch: degrade like
                        // the uncoalesced path below
                        None => match stale {
                            Some(f) => (f, FetchClass::Stale),
                            None => (self.default_features(id), FetchClass::MissDefault),
                        },
                    });
                }
            } else {
                // one batched blocking query for all misses of this request
                let ids: Vec<u64> = need.iter().map(|&(_, id, _)| id).collect();
                match self.store.try_fetch_batch(&ids) {
                    Ok(fetched) => {
                        for ((i, _, _), f) in need.into_iter().zip(fetched) {
                            self.cache.insert(f.item_id, f.clone());
                            out[i] = Some((f, FetchClass::Remote));
                        }
                    }
                    Err(_) => {
                        // graceful degradation: stale value when we have
                        // one, zero-default otherwise — never fail the
                        // request on a feature-service timeout
                        self.store_errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        for (i, id, stale) in need {
                            out[i] = Some(match stale {
                                Some(f) => (f, FetchClass::Stale),
                                None => (self.default_features(id), FetchClass::MissDefault),
                            });
                        }
                    }
                }
            }
        }
        // lint: allow(panic) every slot was filled by the fetch loop above
        out.into_iter().map(|o| o.unwrap()).collect()
    }

    /// The degraded well-formed input for a missing item: the shared
    /// zero row (one allocation per schema, refcounted per miss).
    fn default_features(&self, id: u64) -> ItemFeatures {
        ItemFeatures { item_id: id, dense: Arc::clone(&self.zero_row), version: u64::MAX }
    }

    /// Whether the cross-request miss coalescer is active.
    pub fn fetch_coalesce_enabled(&self) -> bool {
        self.fetch_coalescer.is_some()
    }

    /// Miss-coalescer counters (zeroes when it is off).
    pub fn fetch_coalesce_stats(&self) -> FetchCoalesceStats {
        self.fetch_coalescer.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Single-flight entries currently registered in the miss coalescer
    /// (leak assertions in tests: zero once every fetch resolved).
    pub fn fetch_inflight(&self) -> usize {
        self.fetch_coalescer.as_ref().map(|c| c.inflight_len()).unwrap_or(0)
    }

    fn spawn_refresh(&self, id: u64) {
        let pool = match &self.refresh_pool {
            Some(p) => p,
            None => return,
        };
        {
            let mut inflight = self.in_refresh.lock().unwrap_or_else(|e| e.into_inner());
            if !inflight.insert(id) {
                return; // refresh already queued
            }
        }
        self.pending.lock().unwrap_or_else(|e| e.into_inner()).push(id);
        self.schedule_drain(pool);
    }

    /// Enqueue one drain job if none is scheduled; the job re-schedules
    /// itself while ids remain, so at most one batch query is in flight
    /// per scheduling chain.
    fn schedule_drain(&self, pool: &ThreadPool) {
        if self.drain_scheduled.swap(true, Ordering::AcqRel) {
            return;
        }
        let store = Arc::clone(&self.store);
        let cache = Arc::clone(&self.cache);
        let inflight = Arc::clone(&self.in_refresh);
        let pending = Arc::clone(&self.pending);
        let scheduled = Arc::clone(&self.drain_scheduled);
        let errors = Arc::clone(&self.store_errors);
        pool.execute(move || loop {
            let batch: Vec<u64> = {
                let mut p = pending.lock().unwrap_or_else(|e| e.into_inner());
                let take = p.len().min(REFRESH_BATCH);
                p.drain(..take).collect()
            };
            if batch.is_empty() {
                scheduled.store(false, Ordering::Release);
                // re-check: an id may have landed between drain and store
                if pending.lock().unwrap_or_else(|e| e.into_inner()).is_empty()
                    || scheduled.swap(true, Ordering::AcqRel)
                {
                    return;
                }
                continue;
            }
            match store.try_fetch_batch(&batch) {
                Ok(fetched) => {
                    for f in fetched {
                        cache.insert(f.item_id, f);
                    }
                }
                Err(_) => {
                    // failed refresh: drop the attempt; the ids become
                    // eligible for re-queueing on their next stale/miss hit
                    errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }
            let mut g = inflight.lock().unwrap_or_else(|e| e.into_inner());
            for id in &batch {
                g.remove(id);
            }
        });
    }

    /// Block until queued background refreshes complete (tests/benches).
    pub fn drain_refreshes(&self) {
        if let Some(p) = &self.refresh_pool {
            p.wait_idle();
        }
    }
}

impl Drop for QueryEngine {
    fn drop(&mut self) {
        // Stop the fetch flusher; it resolves any parked waiters by
        // draining open batches on the way out.
        if let Some(co) = &self.fetch_coalescer {
            co.begin_shutdown();
        }
        if let Some(handle) = self.fetch_flusher.take() {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featurestore::FeatureSchema;
    use crate::netsim::{Link, LinkConfig};
    use std::time::Duration;

    fn store() -> Arc<RemoteStore> {
        let link = Arc::new(Link::new(LinkConfig {
            rtt: Duration::from_micros(300),
            bandwidth_bps: 1e9,
            jitter: 0.0,
            fail_rate: 0.0,
        }));
        Arc::new(RemoteStore::new(FeatureSchema::default(), link, 11))
    }

    fn cfg(mode: CacheMode) -> PdaConfig {
        PdaConfig {
            cache_mode: mode,
            cache_capacity: 1024,
            cache_shards: 4,
            cache_ttl_ms: 10_000,
            refresh_workers: 2,
            ..PdaConfig::default()
        }
    }

    #[test]
    fn off_mode_always_remote() {
        let s = store();
        let e = QueryEngine::new(&cfg(CacheMode::Off), Arc::clone(&s));
        for _ in 0..3 {
            let r = e.fetch(&[1, 2]);
            assert!(r.iter().all(|(_, c)| *c == FetchClass::Remote));
        }
        assert_eq!(s.link().queries_total(), 3);
    }

    #[test]
    fn sync_mode_caches_after_first_fetch() {
        let s = store();
        let e = QueryEngine::new(&cfg(CacheMode::Sync), Arc::clone(&s));
        let r1 = e.fetch(&[5, 6]);
        assert!(r1.iter().all(|(_, c)| *c == FetchClass::Remote));
        let r2 = e.fetch(&[5, 6]);
        assert!(r2.iter().all(|(_, c)| *c == FetchClass::Fresh));
        assert_eq!(r1[0].0, r2[0].0, "cached value must equal remote value");
        assert_eq!(s.link().queries_total(), 1, "second fetch fully cached");
    }

    #[test]
    fn sync_mode_batches_misses() {
        let s = store();
        let e = QueryEngine::new(&cfg(CacheMode::Sync), Arc::clone(&s));
        e.fetch(&[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(s.link().queries_total(), 1, "one batched remote query");
    }

    #[test]
    fn async_mode_never_blocks_and_backfills() {
        let s = store();
        let e = QueryEngine::new(&cfg(CacheMode::Async), Arc::clone(&s));
        let r1 = e.fetch(&[9]);
        assert_eq!(r1[0].1, FetchClass::MissDefault);
        assert!(r1[0].0.dense.iter().all(|&x| x == 0.0));
        e.drain_refreshes();
        let r2 = e.fetch(&[9]);
        assert_eq!(r2[0].1, FetchClass::Fresh);
        assert_eq!(r2[0].0, s.fetch_one(9));
    }

    #[test]
    fn async_stale_served_then_refreshed() {
        let s = store();
        let mut c = cfg(CacheMode::Async);
        c.cache_ttl_ms = 1; // immediate staleness
        let e = QueryEngine::new(&c, Arc::clone(&s));
        e.fetch(&[3]);
        e.drain_refreshes(); // cache now has v0
        std::thread::sleep(Duration::from_millis(5));
        s.bump_epoch(); // upstream updated
        let r = e.fetch(&[3]);
        assert_eq!(r[0].1, FetchClass::Stale, "stale value served without blocking");
        assert_eq!(r[0].0.version, 0);
        e.drain_refreshes();
        std::thread::sleep(Duration::from_millis(2));
        let r2 = e.fetch(&[3]);
        // after refresh the new epoch's version is visible (fresh or stale
        // depending on ttl, but the *value* must be updated)
        assert_eq!(r2[0].0.version, 1);
    }

    fn coalesce_cfg(wait_us: u64) -> PdaConfig {
        PdaConfig { fetch_coalesce: true, fetch_wait_us: wait_us, ..cfg(CacheMode::Sync) }
    }

    #[test]
    fn sync_coalesced_values_match_uncoalesced() {
        let (sa, sb) = (store(), store()); // same seed: identical features
        let plain = QueryEngine::new(&cfg(CacheMode::Sync), Arc::clone(&sa));
        let co = QueryEngine::new(&coalesce_cfg(200), Arc::clone(&sb));
        let expected = plain.fetch(&[1, 2, 3, 4]);
        let got = co.fetch(&[1, 2, 3, 4]);
        assert_eq!(expected, got, "coalesced fetch must return identical features");
        // and the cache is populated: the repeat is fully local
        let again = co.fetch(&[1, 2, 3, 4]);
        assert!(again.iter().all(|(_, c)| *c == FetchClass::Fresh));
        assert_eq!(sb.link().queries_total(), 1, "one merged multiget for the first fetch");
    }

    #[test]
    fn sync_coalesced_hot_misses_pay_one_round_trip() {
        const N: usize = 6;
        let s = store();
        // window wide enough that even a badly descheduled thread joins
        // the open batch instead of becoming a second leader
        let e = Arc::new(QueryEngine::new(&coalesce_cfg(200_000), Arc::clone(&s)));
        let barrier = Arc::new(std::sync::Barrier::new(N));
        let got: Vec<Vec<(ItemFeatures, FetchClass)>> = std::thread::scope(|sc| {
            let hs: Vec<_> = (0..N)
                .map(|_| {
                    let e = Arc::clone(&e);
                    let barrier = Arc::clone(&barrier);
                    sc.spawn(move || {
                        barrier.wait();
                        e.fetch(&[99, 100])
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &got {
            assert_eq!(r, &got[0]);
        }
        assert_eq!(
            s.link().queries_total(),
            1,
            "N concurrent requests missing the same ids must share one multiget"
        );
        let stats = e.fetch_coalesce_stats();
        assert_eq!(stats.batched_ids, 2);
        assert_eq!(stats.riders as usize, 2 * (N - 1));
    }

    #[test]
    fn miss_defaults_share_one_zero_row() {
        let s = store();
        let e = QueryEngine::new(&cfg(CacheMode::Async), Arc::clone(&s));
        let a = e.fetch(&[1])[0].0.dense.clone();
        let b = e.fetch(&[2])[0].0.dense.clone();
        assert!(a.iter().all(|&x| x == 0.0));
        assert!(Arc::ptr_eq(&a, &b), "miss defaults must share one allocation");
    }

    #[test]
    fn refresh_dedup_under_hot_key() {
        let s = store();
        let e = QueryEngine::new(&cfg(CacheMode::Async), Arc::clone(&s));
        // 50 requests for the same missing hot key before refresh lands
        for _ in 0..50 {
            e.fetch(&[77]);
        }
        e.drain_refreshes();
        // dedup means far fewer remote queries than requests
        assert!(
            s.link().queries_total() <= 3,
            "expected deduped refreshes, got {}",
            s.link().queries_total()
        );
    }
}
