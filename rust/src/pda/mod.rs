//! PDA — Proximal Data Accelerator (paper §3.1).
//!
//! Everything between a raw request and the tensors the GPU-side engine
//! consumes: the cached feature-query engine (async stale-while-
//! revalidate / sync modes, Fig 5), NUMA-affinity core binding, and the
//! pinned-memory-style staging arenas that batch many small feature
//! copies into contiguous transfer buffers.

pub mod assembler;
pub mod engine;
pub mod numa;
pub mod staging;

pub use assembler::{AssembledInput, InputAssembler};
pub use engine::QueryEngine;
pub use staging::StagingArena;
