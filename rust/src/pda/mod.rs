//! PDA — Proximal Data Accelerator (paper §3.1).
//!
//! Everything between a raw request and the tensors the GPU-side engine
//! consumes: the cached feature-query engine (async stale-while-
//! revalidate / sync modes, Fig 5), the cross-request feature-miss
//! coalescer (single-flight + shared multiget batches on the sync miss
//! path), NUMA-affinity core binding, and the pinned-memory-style
//! staging arenas that batch many small feature copies into contiguous
//! transfer buffers (pooled for the decoupled pipeline).

pub mod assembler;
pub mod engine;
pub mod fetch_coalescer;
pub mod numa;
pub mod staging;

pub use assembler::{AssembledInput, InputAssembler};
pub use engine::QueryEngine;
pub use fetch_coalescer::FetchCoalesceStats;
pub use staging::{ArenaPool, StagingArena};
