//! In-tree substrates replacing crates.io dependencies that are not
//! available in this offline image (see DESIGN.md §Environment
//! substitutions): JSON, RNG + distributions, a thread pool, byte/f32 IO,
//! a property-test harness, and small timing helpers.

pub mod bytes;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod threadpool;
pub mod timeutil;
