//! Timing helpers shared by metrics, benches, and the netsim clock.

use std::time::{Duration, Instant};

/// A stopwatch returning elapsed microseconds.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn micros(&self) -> u64 {
        self.elapsed().as_micros() as u64
    }

    pub fn millis_f64(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Format a duration in adaptive human units (used by benchkit tables).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Format a rate (per-second count) with k/M suffixes, paper-style
/// ("throughput is reported in k, denoting thousands of user-item pairs").
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e6 {
        format!("{:.1} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.1} k/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1} /s")
    }
}

/// Wait for a simulated-work duration. Sleeps for anything at or above
/// the scheduler-visible range and busy-spins only for very short waits
/// — spinning on longer waits would steal the core from real work (on a
/// single-CPU host a spinning background refresher can starve model
/// compute entirely, which is not the behaviour being simulated: a real
/// remote query blocks on the NIC, not the CPU).
pub fn precise_wait(d: Duration) {
    if d >= Duration::from_micros(200) {
        std::thread::sleep(d);
        return;
    }
    let end = Instant::now() + d;
    while Instant::now() < end {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.micros() >= 1_500);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_duration(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(50)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with("s"));
    }

    #[test]
    fn fmt_rate_units() {
        assert_eq!(fmt_rate(1_500.0), "1.5 k/s");
        assert_eq!(fmt_rate(2_500_000.0), "2.5 M/s");
        assert_eq!(fmt_rate(12.0), "12.0 /s");
    }

    #[test]
    fn precise_wait_short() {
        let sw = Stopwatch::start();
        precise_wait(Duration::from_micros(200));
        assert!(sw.micros() >= 200);
    }
}
