//! A small fixed-size thread pool (the offline image has no tokio).
//!
//! The FLAME coordinator uses explicit worker threads rather than an async
//! runtime: the paper's design (NUMA-bound workers, per-profile executor
//! threads, CUDA-stream-like concurrency) maps naturally onto dedicated
//! OS threads, and pinning (`pda::numa`) requires real threads anyway.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<State>,
    cond: Condvar,
}

struct State {
    jobs: VecDeque<Job>,
    shutdown: bool,
    in_flight: usize,
}

/// Fixed-size worker pool with graceful shutdown and `wait_idle`.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    idle: Arc<(Mutex<usize>, Condvar)>, // completed-job counter
}

impl ThreadPool {
    /// Spawn `n` named workers. `pin_offset` optionally pins worker `i` to
    /// CPU `pin_offset + i` (see `pda::numa`); `None` leaves scheduling to
    /// the OS — the "-Mem Opt" ablation arm.
    pub fn new(n: usize, name: &str, pin_offset: Option<usize>) -> Self {
        assert!(n > 0);
        let shared = Arc::new(Shared {
            queue: Mutex::new(State { jobs: VecDeque::new(), shutdown: false, in_flight: 0 }),
            cond: Condvar::new(),
        });
        let idle = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let shared = Arc::clone(&shared);
            let idle = Arc::clone(&idle);
            let thread_name = format!("{name}-{i}");
            let pin = pin_offset.map(|o| o + i);
            workers.push(
                std::thread::Builder::new()
                    .name(thread_name)
                    .spawn(move || {
                        if let Some(cpu) = pin {
                            // best-effort; single-core hosts just no-op
                            let _ = crate::pda::numa::pin_current_thread(cpu);
                        }
                        worker_loop(shared, idle);
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { shared, workers, idle }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut st = self.shared.queue.lock().unwrap_or_else(|e| e.into_inner());
        assert!(!st.shutdown, "execute after shutdown");
        st.jobs.push_back(Box::new(f));
        st.in_flight += 1;
        drop(st);
        self.shared.cond.notify_one();
    }

    /// Number of jobs queued or running.
    pub fn in_flight(&self) -> usize {
        self.shared.queue.lock().unwrap_or_else(|e| e.into_inner()).in_flight
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let (lock, cond) = &*self.idle;
        let mut done = lock.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if self.shared.queue.lock().unwrap_or_else(|e| e.into_inner()).in_flight == 0 {
                return;
            }
            done = cond.wait(done).unwrap_or_else(|e| e.into_inner());
        }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }
}

fn worker_loop(shared: Arc<Shared>, idle: Arc<(Mutex<usize>, Condvar)>) {
    loop {
        let job = {
            let mut st = shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = shared.cond.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        job();
        {
            let mut st = shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            st.in_flight -= 1;
        }
        let (lock, cond) = &*idle;
        let mut done = lock.lock().unwrap_or_else(|e| e.into_inner());
        *done += 1;
        cond.notify_all();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.queue.lock().unwrap_or_else(|e| e.into_inner());
            st.shutdown = true;
        }
        self.shared.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, "t", None);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2, "t", None);
        pool.wait_idle(); // must not hang
    }

    #[test]
    fn jobs_run_concurrently() {
        let pool = ThreadPool::new(4, "t", None);
        let gate = Arc::new((Mutex::new(0usize), Condvar::new()));
        // 4 jobs that all must be in-flight at once to finish.
        for _ in 0..4 {
            let gate = Arc::clone(&gate);
            pool.execute(move || {
                let (lock, cond) = &*gate;
                let mut n = lock.lock().unwrap();
                *n += 1;
                cond.notify_all();
                while *n < 4 {
                    n = cond.wait(n).unwrap();
                }
            });
        }
        pool.wait_idle();
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2, "t", None);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait_idle();
        } // drop
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
