//! Little-endian byte helpers for the binary interchange formats:
//! `weights_<scenario>.bin` (raw f32 concat), test-vector containers, and
//! the TCP wire protocol.

use crate::error::{io_err, Error, Result};
use std::io::{Read, Write};

/// Read an entire file into memory with path context on error.
pub fn read_file(path: &std::path::Path) -> Result<Vec<u8>> {
    std::fs::read(path).map_err(io_err(path.display().to_string()))
}

/// Interpret a little-endian byte slice as f32 values.
pub fn f32_slice_from_le(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        return Err(Error::Manifest(format!(
            "f32 buffer length {} not divisible by 4",
            bytes.len()
        )));
    }
    let mut out = Vec::with_capacity(bytes.len() / 4);
    for c in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    Ok(out)
}

/// Serialize f32 values as little-endian bytes.
pub fn f32_slice_to_le(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

// ---- cursor-style reader for binary containers / wire frames ----

/// Sequential reader over a byte slice with protocol-style errors.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Protocol(format!(
                "truncated buffer: need {n} bytes, have {}",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64> {
        Ok(self.u64()? as i64)
    }

    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.take(n * 4)?;
        f32_slice_from_le(b)
    }

    pub fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let b = self.take(len)?;
        String::from_utf8(b.to_vec()).map_err(|e| Error::Protocol(format!("bad utf8: {e}")))
    }
}

// ---- stream framing for the TCP protocol ----

/// Write a length-prefixed frame (u32 LE length + payload).
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)
}

/// Read a length-prefixed frame; `max` caps the allocation.
pub fn read_frame<R: Read>(r: &mut R, max: usize) -> Result<Vec<u8>> {
    let mut len_b = [0u8; 4];
    r.read_exact(&mut len_b)
        .map_err(|e| Error::Protocol(format!("frame header: {e}")))?;
    let len = u32::from_le_bytes(len_b) as usize;
    if len > max {
        return Err(Error::Protocol(format!("frame of {len} bytes exceeds cap {max}")));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)
        .map_err(|e| Error::Protocol(format!("frame body: {e}")))?;
    Ok(buf)
}

/// Builder-side mirror of `Cursor`.
#[derive(Default)]
pub struct Builder {
    buf: Vec<u8>,
}

impl Builder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32s(&mut self, vs: &[f32]) -> &mut Self {
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
        self
    }

    pub fn string(&mut self, s: &str) -> &mut Self {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
        self
    }

    pub fn bytes(&mut self, b: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(b);
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let vals = vec![0.0f32, -1.5, 3.72e9, f32::MIN_POSITIVE];
        let bytes = f32_slice_to_le(&vals);
        assert_eq!(f32_slice_from_le(&bytes).unwrap(), vals);
    }

    #[test]
    fn f32_rejects_misaligned() {
        assert!(f32_slice_from_le(&[0, 1, 2]).is_err());
    }

    #[test]
    fn cursor_builder_roundtrip() {
        let mut b = Builder::new();
        b.u32(7).u64(1 << 40).string("name").f32s(&[1.0, 2.0]);
        let buf = b.finish();
        let mut c = Cursor::new(&buf);
        assert_eq!(c.u32().unwrap(), 7);
        assert_eq!(c.u64().unwrap(), 1 << 40);
        assert_eq!(c.string().unwrap(), "name");
        assert_eq!(c.f32s(2).unwrap(), vec![1.0, 2.0]);
        assert_eq!(c.remaining(), 0);
    }

    #[test]
    fn cursor_truncation_errors() {
        let mut c = Cursor::new(&[1, 2]);
        assert!(c.u32().is_err());
    }

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r, 1024).unwrap(), b"hello");
    }

    #[test]
    fn frame_cap_enforced() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &[0u8; 100]).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(read_frame(&mut r, 10).is_err());
    }
}
