//! Deterministic RNG + the distributions the workload generator needs.
//!
//! The offline image has no `rand` crate, so this module implements
//! splitmix64 (seeding), xoshiro256** (the main generator), and the
//! distributions the paper's traffic model requires: uniform, Zipf
//! (hot-item popularity — what makes the PDA item cache win), exponential
//! (Poisson arrivals), and normal (feature noise).

/// splitmix64 step — used to expand a single u64 seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a u64 (splitmix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-thread / per-shard RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0, 1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Standard normal via Box–Muller (f32).
    pub fn normal_f32(&mut self) -> f32 {
        let u1 = (1.0 - self.next_f64()).max(1e-300); // avoid ln(0)
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Exponential with rate lambda (inter-arrival times of a Poisson
    /// process at `lambda` events/sec).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipf-distributed sampler over ranks `0..n` with exponent `theta`.
///
/// Uses the rejection-inversion method of Hörmann & Derflinger — O(1)
/// per sample, no O(n) table — so catalogs of 10^7 items cost nothing to
/// set up. `theta` around 0.9–1.1 matches measured hot-item skew on
/// content platforms; this skew is what gives the paper's item-side
/// feature cache its high hit rate (Table 3).
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    theta: f64,
    h_half: f64,
    s: f64,
}

impl Zipf {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n >= 1, "zipf needs n >= 1");
        assert!(theta > 0.0, "theta must be positive");
        // theta == 1 uses the logarithmic forms h(x)=ln x, h_inv(y)=e^y;
        // nudge near-1 values onto the exact-1 branch for stability.
        let theta = if (theta - 1.0).abs() < 1e-9 { 1.0 } else { theta };
        let h = |x: f64| -> f64 {
            if theta == 1.0 {
                x.ln()
            } else {
                (x.powf(1.0 - theta) - 1.0) / (1.0 - theta)
            }
        };
        let h_inv = |y: f64| -> f64 {
            if theta == 1.0 {
                y.exp()
            } else {
                (1.0 + y * (1.0 - theta)).powf(1.0 / (1.0 - theta))
            }
        };
        let h_half = h(0.5);
        let s = 2.0 - h_inv(h(1.5) - 0.5f64.powf(theta));
        Zipf { n, theta, h_half, s }
    }

    #[inline]
    fn h(&self, x: f64) -> f64 {
        if self.theta == 1.0 {
            x.ln()
        } else {
            (x.powf(1.0 - self.theta) - 1.0) / (1.0 - self.theta)
        }
    }

    #[inline]
    fn h_inv(&self, y: f64) -> f64 {
        if self.theta == 1.0 {
            y.exp()
        } else {
            (1.0 + y * (1.0 - self.theta)).powf(1.0 / (1.0 - self.theta))
        }
    }

    /// Sample a rank in `0..n` (0 = hottest item).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let h_n = self.h(self.n as f64 + 0.5);
        loop {
            let u = rng.next_f64() * (h_n - self.h_half) + self.h_half;
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(1.0);
            if k - x <= self.s || u >= self.h(k + 0.5) - k.powf(-self.theta) {
                let k = (k as u64).clamp(1, self.n);
                return k - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.below(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal_f32() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let lambda = 250.0;
        let n = 50_000;
        let total: f64 = (0..n).map(|_| r.exp(lambda)).sum();
        let mean = total / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.1 / lambda * 3.0, "mean {mean}");
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::new(17);
        let z = Zipf::new(1000, 0.99);
        let n = 100_000;
        let mut head = 0usize;
        for _ in 0..n {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            if k < 10 {
                head += 1;
            }
        }
        // With theta≈1, the top-1% of ranks draw a large share of traffic.
        let frac = head as f64 / n as f64;
        assert!(frac > 0.25, "head fraction {frac}");
    }

    #[test]
    fn zipf_rank_ratio_matches_law() {
        let mut r = Rng::new(19);
        let theta = 0.9;
        let z = Zipf::new(100, theta);
        let n = 400_000;
        let mut c = [0usize; 100];
        for _ in 0..n {
            c[z.sample(&mut r) as usize] += 1;
        }
        // p(rank 1)/p(rank 8) should be ~ (8/1)^theta = 8^0.9 ≈ 6.5
        let ratio = c[0] as f64 / c[7] as f64;
        let expect = 8f64.powf(theta);
        assert!(
            (ratio / expect - 1.0).abs() < 0.25,
            "ratio {ratio} expect {expect}"
        );
    }

    #[test]
    fn zipf_theta_one_exact() {
        let mut r = Rng::new(29);
        let z = Zipf::new(1000, 1.0);
        let n = 100_000;
        let mut head = 0usize;
        for _ in 0..n {
            let k = z.sample(&mut r);
            assert!(k < 1000);
            if k == 0 {
                head += 1;
            }
        }
        // p(rank 1) = 1/H_1000 ≈ 0.1336 at theta=1
        let frac = head as f64 / n as f64;
        assert!((frac - 0.1336).abs() < 0.02, "head fraction {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
