//! Minimal JSON parser + writer (no serde in the offline image).
//!
//! Covers the full JSON grammar; used for the artifact manifest,
//! configuration files, and workload traces. Numbers are held as f64
//! (adequate: the manifest's largest integers are FLOP counts < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors (manifest/config convenience) ----

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json(format!("expected object, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Json(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {f}")));
        }
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Json(format!("expected bool, got {self:?}"))),
        }
    }

    /// Field lookup with a path-aware error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing field '{key}'")))
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    // ---- construction helpers for the writer side ----

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        // report a line/col for debuggability
        let (mut line, mut col) = (1usize, 1usize);
        for &c in &self.b[..self.i.min(self.b.len())] {
            if c == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Error::Json(format!("{msg} at line {line} col {col}"))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(c) = self.peek() {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs: join if high surrogate
                            if (0xD800..0xDC00).contains(&cp)
                                && self.b[self.i + 5..].starts_with(b"\\u")
                            {
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.i + 7..self.i + 11])
                                        .map_err(|_| self.err("bad surrogate"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad surrogate"))?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(
                                    char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                                self.i += 6; // extra surrogate bytes
                            } else {
                                out.push(
                                    char::from_u32(cp).unwrap_or('\u{FFFD}'),
                                );
                            }
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one utf8 char
                    let rest = &self.b[self.i..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1F600}");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"n":-7,"o":{"k":"v"}}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn error_reports_position() {
        let e = parse("{\n  \"a\": x\n}").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("line 2"), "{msg}");
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"u": 7, "f": 1.5, "b": true}"#).unwrap();
        assert_eq!(v.get("u").unwrap().as_u64().unwrap(), 7);
        assert_eq!(v.get("u").unwrap().as_usize().unwrap(), 7);
        assert!(v.get("f").unwrap().as_u64().is_err());
        assert!(v.get("b").unwrap().as_bool().unwrap());
        assert!(v.get("missing").is_err());
        assert!(v.opt("missing").is_none());
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_serialized_without_fraction() {
        let s = Json::Num(3.72e9).to_string();
        assert_eq!(s, "3720000000");
    }
}
