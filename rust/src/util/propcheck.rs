//! Tiny in-tree property-testing harness (the offline image has no
//! `proptest`). Deterministic, seeded case generation with a shrinking
//! pass for integer-vector inputs — enough to state the coordinator
//! invariants DESIGN.md calls for (planner splits, cache bounds,
//! histogram quantiles, batcher conservation).
//!
//! Usage:
//! ```ignore
//! propcheck::check("planner conserves items", 500, |g| {
//!     let m = g.usize_in(1, 4096);
//!     let plan = plan(m);
//!     ensure!(plan.iter().sum::<usize>() == m);
//!     Ok(())
//! });
//! ```

use crate::util::rng::Rng;

/// A failed property with its case index and message.
#[derive(Debug)]
pub struct CaseFailure {
    pub case: usize,
    pub seed: u64,
    pub message: String,
}

/// Per-case generator handle: draws random inputs and records them for
/// the failure report.
pub struct Gen {
    rng: Rng,
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), log: Vec::new() }
    }

    pub fn u64_below(&mut self, n: u64) -> u64 {
        let v = self.rng.below(n);
        self.log.push(format!("u64_below({n}) = {v}"));
        v
    }

    /// Inclusive-exclusive range.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range(lo, hi);
        self.log.push(format!("usize_in({lo},{hi}) = {v}"));
        v
    }

    pub fn f64_unit(&mut self) -> f64 {
        let v = self.rng.next_f64();
        self.log.push(format!("f64_unit() = {v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.log.push(format!("bool() = {v}"));
        v
    }

    /// A vector of integers in [lo, hi), length in [min_len, max_len].
    pub fn vec_usize(&mut self, min_len: usize, max_len: usize, lo: usize, hi: usize) -> Vec<usize> {
        let len = self.rng.range(min_len, max_len + 1);
        let v: Vec<usize> = (0..len).map(|_| self.rng.range(lo, hi)).collect();
        self.log.push(format!("vec_usize(len={len}) = {v:?}"));
        v
    }

    /// Pick one element of a static choice list.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.range(0, xs.len());
        self.log.push(format!("pick(#{i})"));
        &xs[i]
    }

    /// Raw access for custom generators.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Property result: Err(message) fails the case.
pub type PropResult = Result<(), String>;

/// Build a failure message (like `anyhow::bail!` for properties).
#[macro_export]
macro_rules! prop_ensure {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Run `cases` random cases of `prop`. Panics with a reproducible report
/// (seed + drawn values) on the first failure — call from `#[test]` fns.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    check_seeded(name, 0xF1A4_E5EE_D000 ^ fxhash(name), cases, prop)
}

/// Seeded variant for reproducing a specific failure.
pub fn check_seeded<F>(name: &str, seed: u64, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    let mut meta = Rng::new(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut g = Gen::new(case_seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {case_seed:#x}):\n  {msg}\n  drawn: {}",
                g.log.join(", ")
            );
        }
    }
}

/// Stable tiny string hash for deriving per-property seeds.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check("trivial", 50, |g| {
            let _ = g.usize_in(0, 10);
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_report() {
        check("always fails", 10, |g| {
            let x = g.usize_in(0, 100);
            prop_ensure!(x > 1000, "x was {x}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let collect = |seed: u64| {
            let vals = std::cell::RefCell::new(Vec::new());
            check_seeded("det", seed, 5, |g| {
                vals.borrow_mut().push(g.u64_below(1_000_000));
                Ok(())
            });
            vals.into_inner()
        };
        assert_eq!(collect(99), collect(99));
        assert_ne!(collect(99), collect(100));
    }

    #[test]
    fn vec_generator_respects_bounds() {
        check("vec bounds", 100, |g| {
            let v = g.vec_usize(1, 8, 5, 10);
            prop_ensure!((1..=8).contains(&v.len()), "len {}", v.len());
            prop_ensure!(v.iter().all(|&x| (5..10).contains(&x)), "vals {v:?}");
            Ok(())
        });
    }
}
