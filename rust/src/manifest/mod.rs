//! Artifact manifest: the build-time contract between `python/compile`
//! and the rust runtime. Parses `artifacts/manifest.json`, loads
//! `weights_<scenario>.bin` (f32 LE concat in the canonical flatten
//! order), and reads test-vector containers.

pub mod testvec;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::util::bytes;
use crate::util::json::{parse, Json};

/// One weight tensor's (name, shape) in flatten order.
#[derive(Clone, Debug, PartialEq)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl WeightSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Per-scenario artifact block.
#[derive(Clone, Debug)]
pub struct ScenarioArtifacts {
    pub config: ModelConfig,
    pub weights_file: String,
    pub weights_bytes: u64,
    pub weights: Vec<WeightSpec>,
    pub seed: u64,
}

/// One lowered engine (HLO file) entry.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub scenario: String,
    pub variant: String,
    pub m: usize,
    pub path: String,
    pub flops: u64,
    pub n_weight_inputs: usize,
}

/// One exported test vector.
#[derive(Clone, Debug)]
pub struct TestVectorEntry {
    pub scenario: String,
    pub variant: String,
    pub m: usize,
    pub path: String,
}

/// Parsed manifest plus its base directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub scenarios: BTreeMap<String, ScenarioArtifacts>,
    pub models: Vec<ModelEntry>,
    pub testvectors: Vec<TestVectorEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(crate::error::io_err(path.display().to_string()))?;
        Self::from_json_str(&text, dir)
    }

    pub fn from_json_str(text: &str, dir: PathBuf) -> Result<Self> {
        let j = parse(text)?;
        let mut scenarios = BTreeMap::new();
        for (name, sj) in j.get("scenarios")?.as_obj()? {
            scenarios.insert(name.clone(), parse_scenario(name, sj)?);
        }
        let mut models = Vec::new();
        for mj in j.get("models")?.as_arr()? {
            models.push(ModelEntry {
                scenario: mj.get("scenario")?.as_str()?.to_string(),
                variant: mj.get("variant")?.as_str()?.to_string(),
                m: mj.get("m")?.as_usize()?,
                path: mj.get("path")?.as_str()?.to_string(),
                flops: mj.get("flops")?.as_u64()?,
                n_weight_inputs: mj.get("n_weight_inputs")?.as_usize()?,
            });
        }
        let mut testvectors = Vec::new();
        if let Some(tv) = j.opt("testvectors") {
            for t in tv.as_arr()? {
                testvectors.push(TestVectorEntry {
                    scenario: t.get("scenario")?.as_str()?.to_string(),
                    variant: t.get("variant")?.as_str()?.to_string(),
                    m: t.get("m")?.as_usize()?,
                    path: t.get("path")?.as_str()?.to_string(),
                });
            }
        }
        let m = Manifest { dir, scenarios, models, testvectors };
        m.validate()?;
        Ok(m)
    }

    /// Cross-checks: models reference known scenarios + profiles; weight
    /// byte counts match the spec; rust/python FLOP formulas agree.
    pub fn validate(&self) -> Result<()> {
        for e in &self.models {
            let s = self.scenarios.get(&e.scenario).ok_or_else(|| {
                Error::Manifest(format!("model {} references unknown scenario {}", e.path, e.scenario))
            })?;
            if !s.config.m_profiles.contains(&e.m) {
                return Err(Error::Manifest(format!(
                    "model {} has M={} not in scenario profiles {:?}",
                    e.path, e.m, s.config.m_profiles
                )));
            }
            let expect = crate::config::flops::model_flops(&s.config, e.m);
            if expect != e.flops {
                return Err(Error::Manifest(format!(
                    "FLOPs mismatch for {}: python says {}, rust says {expect}",
                    e.path, e.flops
                )));
            }
            if e.n_weight_inputs != s.weights.len() {
                return Err(Error::Manifest(format!(
                    "weight-input count mismatch for {}", e.path
                )));
            }
        }
        for (name, s) in &self.scenarios {
            let numel: usize = s.weights.iter().map(|w| w.numel()).sum();
            if numel as u64 * 4 != s.weights_bytes {
                return Err(Error::Manifest(format!(
                    "scenario {name}: weight bytes {} != 4 * numel {numel}",
                    s.weights_bytes
                )));
            }
        }
        Ok(())
    }

    /// Find the engine entry for (scenario, variant, m).
    pub fn find(&self, scenario: &str, variant: &str, m: usize) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|e| e.scenario == scenario && e.variant == variant && e.m == m)
            .ok_or_else(|| {
                Error::UnknownEngine(format!(
                    "{scenario}/{variant}/m{m} (have: {})",
                    self.models
                        .iter()
                        .map(|e| format!("{}/{}/m{}", e.scenario, e.variant, e.m))
                        .collect::<Vec<_>>()
                        .join(", ")
                ))
            })
    }

    pub fn scenario(&self, name: &str) -> Result<&ScenarioArtifacts> {
        self.scenarios
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("scenario '{name}' not in manifest")))
    }

    /// All M profiles that have a lowered engine for (scenario, variant).
    pub fn profiles_for(&self, scenario: &str, variant: &str) -> Vec<usize> {
        let mut ms: Vec<usize> = self
            .models
            .iter()
            .filter(|e| e.scenario == scenario && e.variant == variant)
            .map(|e| e.m)
            .collect();
        ms.sort();
        ms
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }

    /// Load a scenario's weight blob, sliced per tensor in flatten order.
    pub fn load_weights(&self, scenario: &str) -> Result<Vec<(WeightSpec, Vec<f32>)>> {
        let s = self.scenario(scenario)?;
        let raw = bytes::read_file(&self.path_of(&s.weights_file))?;
        if raw.len() as u64 != s.weights_bytes {
            return Err(Error::Manifest(format!(
                "weights file {} is {} bytes, manifest says {}",
                s.weights_file,
                raw.len(),
                s.weights_bytes
            )));
        }
        let all = bytes::f32_slice_from_le(&raw)?;
        let mut out = Vec::with_capacity(s.weights.len());
        let mut off = 0usize;
        for w in &s.weights {
            let n = w.numel();
            out.push((w.clone(), all[off..off + n].to_vec()));
            off += n;
        }
        debug_assert_eq!(off, all.len());
        Ok(out)
    }
}

fn parse_scenario(name: &str, sj: &Json) -> Result<ScenarioArtifacts> {
    let config = ModelConfig {
        name: name.to_string(),
        seq_len: sj.get("seq_len")?.as_usize()?,
        n_blocks: sj.get("n_blocks")?.as_usize()?,
        layers_per_block: sj.get("layers_per_block")?.as_usize()?,
        d_model: sj.get("d_model")?.as_usize()?,
        n_heads: sj.get("n_heads")?.as_usize()?,
        n_tasks: sj.get("n_tasks")?.as_usize()?,
        m_profiles: sj
            .get("m_profiles")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?,
        native_m: sj.get("native_m")?.as_usize()?,
    };
    config.validate()?;
    let mut weights = Vec::new();
    for w in sj.get("weights")?.as_arr()? {
        weights.push(WeightSpec {
            name: w.get("name")?.as_str()?.to_string(),
            shape: w
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<Vec<_>>>()?,
        });
    }
    Ok(ScenarioArtifacts {
        config,
        weights_file: sj.get("weights_file")?.as_str()?.to_string(),
        weights_bytes: sj.get("weights_bytes")?.as_u64()?,
        weights,
        seed: sj.get("seed")?.as_u64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest() -> String {
        // A self-consistent tiny manifest (FLOPs must match the rust
        // formula: tiny @ M=8 = 2_791_424).
        r#"{
          "version": 1,
          "scenarios": {
            "tiny": {
              "seq_len": 32, "n_blocks": 2, "layers_per_block": 2,
              "d_model": 32, "n_heads": 2, "n_tasks": 3, "d_ff": 128,
              "block_len": 16, "m_profiles": [4, 8], "native_m": 8,
              "seed": 1001, "weights_file": "weights_tiny.bin",
              "weights_bytes": 16,
              "weights": [{"name": "w0", "shape": [2, 2]}]
            }
          },
          "models": [
            {"scenario": "tiny", "variant": "api", "m": 8,
             "path": "tiny_api_m8.hlo.txt", "flops": 2791424,
             "n_weight_inputs": 1}
          ],
          "testvectors": [
            {"scenario": "tiny", "variant": "api", "m": 8, "path": "tv.bin"}
          ]
        }"#
        .to_string()
    }

    #[test]
    fn parses_and_validates() {
        let m = Manifest::from_json_str(&mini_manifest(), PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.scenarios.len(), 1);
        let e = m.find("tiny", "api", 8).unwrap();
        assert_eq!(e.flops, 2_791_424);
        assert_eq!(m.profiles_for("tiny", "api"), vec![8]);
        assert!(m.find("tiny", "api", 4).is_err());
        assert!(m.find("tiny", "fused", 8).is_err());
    }

    #[test]
    fn rejects_flops_mismatch() {
        let bad = mini_manifest().replace("2791424", "123");
        assert!(Manifest::from_json_str(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_unknown_profile() {
        let bad = mini_manifest().replace("\"m\": 8", "\"m\": 16");
        assert!(Manifest::from_json_str(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_weight_byte_mismatch() {
        let bad = mini_manifest().replace("\"weights_bytes\": 16", "\"weights_bytes\": 20");
        assert!(Manifest::from_json_str(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn weight_spec_numel() {
        let w = WeightSpec { name: "x".into(), shape: vec![2, 3, 4] };
        assert_eq!(w.numel(), 24);
    }
}
