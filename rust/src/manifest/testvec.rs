//! Test-vector container reader — mirror of
//! `python/compile/aot.py::write_testvector` (magic "FLTV", version, then
//! named f32 tensors). Used by the e2e integration tests to check the
//! rust runtime's numerics against the python execution of the same HLO.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::bytes::{read_file, Cursor};

pub const TV_MAGIC: u32 = 0x464C_5456; // "FLTV"

/// A named f32 tensor from a test-vector file.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed test-vector file: name -> tensor.
#[derive(Debug)]
pub struct TestVector {
    pub tensors: BTreeMap<String, Tensor>,
}

impl TestVector {
    pub fn load(path: &Path) -> Result<Self> {
        let raw = read_file(path)?;
        Self::parse(&raw)
    }

    pub fn parse(raw: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(raw);
        let magic = c.u32()?;
        if magic != TV_MAGIC {
            return Err(Error::Manifest(format!("bad testvec magic {magic:#x}")));
        }
        let version = c.u32()?;
        if version != 1 {
            return Err(Error::Manifest(format!("unsupported testvec version {version}")));
        }
        let count = c.u32()? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name = c.string()?;
            let ndim = c.u32()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let d = c.i64()?;
                if d < 0 {
                    return Err(Error::Manifest(format!("negative dim in {name}")));
                }
                shape.push(d as usize);
            }
            let numel: usize = shape.iter().product();
            let data = c.f32s(numel)?;
            tensors.insert(name, Tensor { shape, data });
        }
        if c.remaining() != 0 {
            return Err(Error::Manifest(format!(
                "trailing {} bytes in testvec",
                c.remaining()
            )));
        }
        Ok(TestVector { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("testvec missing tensor '{name}'")))
    }
}

/// Max |a-b| over two f32 slices (numeric comparison helper for tests).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::Builder;

    fn sample_file() -> Vec<u8> {
        let mut b = Builder::new();
        b.u32(TV_MAGIC).u32(1).u32(2);
        // tensor "a": shape [2, 2]
        b.string("a").u32(2).u64(2).u64(2).f32s(&[1.0, 2.0, 3.0, 4.0]);
        // tensor "b": shape [3]
        b.string("b").u32(1).u64(3).f32s(&[5.0, 6.0, 7.0]);
        b.finish()
    }

    #[test]
    fn parses_tensors() {
        let tv = TestVector::parse(&sample_file()).unwrap();
        let a = tv.get("a").unwrap();
        assert_eq!(a.shape, vec![2, 2]);
        assert_eq!(a.data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(tv.get("b").unwrap().numel(), 3);
        assert!(tv.get("missing").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut f = sample_file();
        f[0] = 0;
        assert!(TestVector::parse(&f).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let f = sample_file();
        assert!(TestVector::parse(&f[..f.len() - 2]).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut f = sample_file();
        f.extend_from_slice(&[0u8; 4]);
        assert!(TestVector::parse(&f).is_err());
    }

    #[test]
    fn diff_helper() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
    }
}
