//! CPU-side embedding tables (the paper's decoupled design keeps
//! "embedding look-up" on the CPU; only the dense transformer runs on the
//! accelerator).
//!
//! Tables are hashed + seeded: the d-dim vector of an item id is
//! synthesized deterministically on first touch, so a catalog of 10^6+
//! items costs no startup time, while repeated lookups of hot items hit a
//! small materialized cache. Item side features (from the PDA query
//! engine) are folded into the embedding via a fixed projection, so
//! feature staleness/missingness visibly changes the model input — the
//! accuracy side of the async-cache trade-off is observable end to end.

use std::sync::{Arc, Mutex};

use crate::cache::ShardedCache;
use crate::util::rng::Rng;

/// Hashed embedding table: id -> dense f32 vector of dimension d.
pub struct EmbeddingTable {
    d: usize,
    seed: u64,
    /// Materialized-hot-row cache (id -> vector).
    cache: ShardedCache<Vec<f32>>,
    /// Projection weights folding side features into the embedding.
    /// Shared behind an `Arc` so a lookup borrows it without copying.
    feat_proj: Mutex<Arc<Vec<f32>>>, // [feat_dims] broadcast scale, lazily sized
}

impl EmbeddingTable {
    pub fn new(d: usize, seed: u64, hot_capacity: usize) -> Self {
        EmbeddingTable {
            d,
            seed,
            cache: ShardedCache::new(hot_capacity.max(1), 8, std::time::Duration::from_secs(3600)),
            feat_proj: Mutex::new(Arc::new(Vec::new())),
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Synthesize the base embedding row of `id` directly into `out`.
    fn synthesize_row_into(&self, id: u64, out: &mut [f32]) {
        let mut rng = Rng::new(self.seed ^ id.wrapping_mul(0x2545_F491_4F6C_DD1D));
        let scale = 1.0 / (self.d as f32).sqrt();
        for o in out.iter_mut() {
            *o = rng.normal_f32() * scale;
        }
    }

    /// Write the embedding of `id` into `out` (len d). A hot-row cache
    /// hit copies straight from the cached row into `out` with zero
    /// allocation (`ShardedCache::with_fresh`); only a cold id pays one
    /// materialization + insert.
    pub fn embed_into(&self, id: u64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.d);
        if self.cache.with_fresh(id, |row| out.copy_from_slice(row)).is_some() {
            return;
        }
        self.synthesize_row_into(id, out);
        self.cache.insert(id, out.to_vec());
    }

    /// Write embedding + folded side features into `out`.
    ///
    /// Missing features (the async-cache zero default) leave the base
    /// embedding unperturbed — a degraded but well-formed input.
    pub fn embed_with_features_into(&self, id: u64, features: &[f32], out: &mut [f32]) {
        self.embed_into(id, out);
        if features.is_empty() {
            return;
        }
        let proj = self.feature_projection(features.len());
        // fold: out[j] += 0.1 * proj[i] * feat[i] rotated over dims
        for (i, (&f, &p)) in features.iter().zip(proj.iter()).enumerate() {
            out[i % self.d] += 0.1 * p * f;
        }
    }

    fn feature_projection(&self, n: usize) -> Arc<Vec<f32>> {
        let mut proj = self.feat_proj.lock().unwrap();
        if proj.len() < n {
            let mut rng = Rng::new(self.seed ^ 0xFEED_FACE);
            *proj = Arc::new((0..n).map(|_| rng.normal_f32()).collect());
        }
        Arc::clone(&proj)
    }

    /// Hot-row cache statistics (hit rate on popular items).
    pub fn cache_stats(&self) -> &crate::cache::CacheStats {
        &self.cache.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_rows() {
        let t = EmbeddingTable::new(16, 3, 128);
        let mut a = vec![0.0; 16];
        let mut b = vec![0.0; 16];
        t.embed_into(42, &mut a);
        t.embed_into(42, &mut b);
        assert_eq!(a, b);
        t.embed_into(43, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn unit_ish_scale() {
        let t = EmbeddingTable::new(64, 5, 128);
        let mut v = vec![0.0; 64];
        t.embed_into(7, &mut v);
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(norm > 0.3 && norm < 3.0, "norm {norm}");
    }

    #[test]
    fn features_perturb_embedding() {
        let t = EmbeddingTable::new(16, 3, 128);
        let mut base = vec![0.0; 16];
        let mut with = vec![0.0; 16];
        t.embed_into(42, &mut base);
        t.embed_with_features_into(42, &[1.0, -1.0, 0.5], &mut with);
        assert_ne!(base, with);
        // zero features == missing features == base embedding
        let mut zero = vec![0.0; 16];
        t.embed_with_features_into(42, &[0.0, 0.0, 0.0], &mut zero);
        assert_eq!(base, zero);
    }

    #[test]
    fn hot_cache_hits_on_repeat() {
        let t = EmbeddingTable::new(8, 3, 128);
        let mut v = vec![0.0; 8];
        for _ in 0..10 {
            t.embed_into(1, &mut v);
        }
        let (hits, _, misses, _, _) = t.cache_stats().snapshot();
        assert_eq!(misses, 1);
        assert_eq!(hits, 9);
    }

    /// Regression: the hit path must be a pure copy-into — it used to
    /// build a fresh `Vec` per materialization and clone it into the
    /// cache, and even hits returned an owned `Vec` that was then copied
    /// again. Observable contract: repeats neither re-insert nor
    /// re-synthesize, and the copied-out row still matches the original.
    #[test]
    fn hit_path_copies_into_out_without_reinsert() {
        let t = EmbeddingTable::new(16, 3, 128);
        let mut first = vec![0.0; 16];
        t.embed_into(9, &mut first);
        for _ in 0..20 {
            let mut v = vec![1.0; 16]; // dirty buffer: must be fully overwritten
            t.embed_into(9, &mut v);
            assert_eq!(v, first);
        }
        let (hits, _, misses, inserts, _) = t.cache_stats().snapshot();
        assert_eq!(misses, 1);
        assert_eq!(inserts, 1, "hit path must not re-insert (and so not re-allocate)");
        assert_eq!(hits, 20);
    }

    #[test]
    fn feature_projection_stable_across_growth() {
        // growing the lazily-sized projection must keep the prefix, so
        // the same (id, features) folds identically before and after a
        // wider request was seen
        let t = EmbeddingTable::new(16, 3, 128);
        let mut narrow = vec![0.0; 16];
        t.embed_with_features_into(4, &[0.5, -0.5], &mut narrow);
        let mut wide = vec![0.0; 16];
        t.embed_with_features_into(4, &[0.1; 12], &mut wide);
        let mut narrow_again = vec![0.0; 16];
        t.embed_with_features_into(4, &[0.5, -0.5], &mut narrow_again);
        assert_eq!(narrow, narrow_again);
    }

    #[test]
    fn concurrent_lookups_consistent() {
        let t = std::sync::Arc::new(EmbeddingTable::new(32, 9, 1024));
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let t = std::sync::Arc::clone(&t);
                std::thread::spawn(move || {
                    let mut v = vec![0.0; 32];
                    t.embed_into(123, &mut v);
                    v
                })
            })
            .collect();
        let rows: Vec<Vec<f32>> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(rows.windows(2).all(|w| w[0] == w[1]));
    }
}
