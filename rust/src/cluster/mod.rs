//! Cluster tier: multi-replica routing in front of N serving stacks.
//!
//! The paper's deployment serves 1e10..1e12 requests/day — far past one
//! `ServingStack` behind one listener. This module adds the missing
//! layer: a [`ClusterRouter`] that fronts N independent replicas with
//!
//! * pluggable placement ([`RoutePolicy`]): round-robin, least-loaded
//!   power-of-two-choices, and **cache-affinity** consistent hashing on
//!   `user_id` so returning users land on the replica whose PDA feature
//!   cache already holds their features;
//! * **deadline-aware admission** ([`Admission`]): sojourn time is
//!   estimated from each replica's rolling latency histogram + current
//!   congestion; requests that cannot make their SLA are re-routed to
//!   the cheapest healthy replica or shed at the front door;
//! * **replica health**: consecutive-error ejection with timed
//!   re-admission (half-open probing after a cooldown);
//! * **result cache tier** ([`result_cache::ResultCache`]): a
//!   router-level cache of scored responses keyed on the canonicalized
//!   (user, candidate set), with single-flight coalescing so concurrent
//!   identical requests ride one backend computation — see
//!   [`result_cache`] for the full design.
//!
//! Backends implement [`ReplicaBackend`]: [`StackReplica`] wraps a real
//! `ServingStack`; `sim::SimReplica` is the artifact-free model used by
//! `bench_cluster` and the integration tests.

pub mod admission;
pub mod controller;
pub mod policy;
pub mod replica;
pub mod result_cache;
pub mod sim;
pub mod tenant;

pub use admission::{Admission, Verdict};
pub use controller::{Decision, OverloadController};
pub use policy::{HashRing, RoutePolicy};
pub use replica::{Replica, ReplicaBackend, ReplicaSnapshot, StackReplica};
pub use result_cache::{ResultCache, ResultCacheConfig};
pub use sim::{SimConfig, SimReplica};
pub use tenant::{TenantSet, TenantSpec};

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cancel::{CancelCause, CancelStage, CancelToken};
use crate::error::{Error, Result};
use crate::metrics::Recorder;
use crate::obs::{self, SharedSpan, StageKind, TraceContext};
use crate::server::pipeline::Response;
use crate::util::rng::splitmix64;
use crate::workload::Request;

/// Cluster-tier knobs.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub policy: RoutePolicy,
    /// Default per-request deadline budget (paper envelope: < 50 ms).
    pub deadline_ms: u64,
    /// Virtual nodes per replica on the consistent-hash ring.
    pub vnodes: usize,
    /// Service-parallelism hint per replica (sojourn estimator).
    pub slots_per_replica: usize,
    /// Consecutive errors before a replica is ejected.
    pub eject_after: u32,
    /// Ejection cooldown before timed re-admission (ms).
    pub eject_cooldown_ms: u64,
    /// Allow deadline/failover re-routes to another replica.
    pub reroute: bool,
    /// Max failover retries after a replica error (each to the cheapest
    /// alternative, budget-aware). 1 = the classic single failover.
    pub max_retries: u32,
    /// Base retry backoff (µs), doubled per attempt; a retry is skipped
    /// when its backoff would eat the remaining budget. 0 = no backoff.
    pub retry_backoff_us: u64,
    /// Hedged dispatch: when the picked replica has not answered within
    /// ~2x its estimate (a brownout signature), re-dispatch once to a
    /// second replica and take whichever answers first. Costs a thread
    /// per dispatch on this path, so it is opt-in (chaos/degraded runs).
    pub hedge: bool,
    /// Router-level result cache + single-flight coalescing knobs
    /// (disabled by default: `capacity == 0`).
    pub result_cache: ResultCacheConfig,
    /// Per-tenant weights and SLA budgets (`--tenants`). The default
    /// registry is neutral: every tenant weight 1, cluster deadline.
    pub tenants: TenantSet,
    /// Enable the per-tenant feedback overload controller (`--controller`).
    /// Off by default: admission behaves exactly as before tenancy.
    pub controller: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            policy: RoutePolicy::CacheAffinity,
            deadline_ms: 50,
            vnodes: 64,
            slots_per_replica: 4,
            eject_after: 3,
            eject_cooldown_ms: 500,
            reroute: true,
            max_retries: 1,
            retry_backoff_us: 0,
            hedge: false,
            result_cache: ResultCacheConfig::default(),
            tenants: TenantSet::default(),
            controller: false,
        }
    }
}

/// Cluster-wide point-in-time view.
#[derive(Clone, Debug)]
pub struct ClusterSnapshot {
    pub policy: &'static str,
    pub replicas: Vec<ReplicaSnapshot>,
    pub shed: u64,
    pub sla_misses: u64,
    pub rerouted: u64,
    pub aggregate_cache_hit_rate: f64,
    /// Result-tier counters (all 0 when the tier is disabled).
    pub result_hits: u64,
    pub result_misses: u64,
    pub result_coalesced: u64,
    /// Degradation-ladder counters: failover retries, hedged
    /// re-dispatches (and how many the hedge won), canary probes.
    pub retries: u64,
    pub hedges: u64,
    pub hedge_wins: u64,
    pub probes_ok: u64,
    pub probes_failed: u64,
}

/// The routing tier over N replicas.
pub struct ClusterRouter {
    replicas: Vec<Arc<Replica>>,
    cfg: ClusterConfig,
    ring: HashRing,
    rr_next: AtomicUsize,
    rng_state: AtomicU64,
    /// Router-level result cache + single-flight table (None = disabled).
    result_cache: Option<ResultCache>,
    /// Per-tenant feedback overload controller (None = open loop).
    controller: Option<OverloadController>,
    pub admission: Admission,
    /// Aggregate cluster-level latency/throughput (what a load balancer
    /// in front of the fleet would observe).
    pub metrics: Recorder,
}

impl ClusterRouter {
    pub fn new(backends: Vec<Arc<dyn ReplicaBackend>>, cfg: ClusterConfig) -> Result<Self> {
        if backends.is_empty() {
            return Err(Error::Config("cluster needs at least one replica".into()));
        }
        let cooldown_us = cfg.eject_cooldown_ms.saturating_mul(1_000);
        let replicas: Vec<Arc<Replica>> = backends
            .into_iter()
            .enumerate()
            .map(|(id, b)| {
                Arc::new(Replica::new(id, b, cfg.slots_per_replica, cfg.eject_after, cooldown_us))
            })
            .collect();
        let ring = HashRing::new(replicas.len(), cfg.vnodes);
        let rng_state = AtomicU64::new(0x5EED_0000 ^ replicas.len() as u64);
        let result_cache = ResultCache::new(&cfg.result_cache);
        let controller = cfg
            .controller
            .then(|| OverloadController::new(&cfg.tenants, 0xF1A3_0009 ^ replicas.len() as u64));
        Ok(ClusterRouter {
            replicas,
            cfg,
            ring,
            rr_next: AtomicUsize::new(0),
            rng_state,
            result_cache,
            controller,
            admission: Admission::new(),
            metrics: Recorder::new(),
        })
    }

    pub fn replicas(&self) -> &[Arc<Replica>] {
        &self.replicas
    }

    /// The router's result-cache tier, if enabled.
    pub fn result_cache(&self) -> Option<&ResultCache> {
        self.result_cache.as_ref()
    }

    /// Upstream user-feature update hook: evicts the user's cached
    /// result rows ahead of their TTL so stale-serve degradation can
    /// never return pre-update scores. Returns evicted entries.
    pub fn invalidate_user(&self, user_id: u64) -> usize {
        self.result_cache.as_ref().map_or(0, |rc| rc.invalidate_user(user_id))
    }

    pub fn policy(&self) -> RoutePolicy {
        self.cfg.policy
    }

    /// The feedback overload controller, when enabled.
    pub fn controller(&self) -> Option<&OverloadController> {
        self.controller.as_ref()
    }

    /// The tenant registry (weights + per-tenant budgets).
    pub fn tenants(&self) -> &TenantSet {
        &self.cfg.tenants
    }

    /// Cluster queue depth as per-mille of total service slots — the
    /// controller's pressure sensor (1000 = every slot busy).
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn queue_permille(&self) -> u64 {
        let (mut in_flight, mut slots) = (0u64, 0u64);
        for r in &self.replicas {
            in_flight += r.in_flight() as u64;
            slots += r.slots() as u64;
        }
        in_flight.saturating_mul(1_000) / slots.max(1)
    }

    /// Default deadline budget in µs.
    pub fn deadline_us(&self) -> u64 {
        self.cfg.deadline_ms.saturating_mul(1_000)
    }

    /// Lock-free uniform draw (atomic splitmix64: fetch-add the golden
    /// gamma, finalize locally).
    fn next_rand(&self) -> u64 {
        let mut s = self.rng_state.fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed);
        splitmix64(&mut s)
    }

    /// Policy-chosen healthy replica for `req`, or None if the whole
    /// fleet is ejected.
    fn pick(&self, req: &Request) -> Option<usize> {
        let n = self.replicas.len();
        let healthy = |i: usize| self.replicas[i].healthy();
        match self.cfg.policy {
            RoutePolicy::RoundRobin => {
                // one counter draw, then a contiguous scan: interleaved
                // fetch_adds under concurrency must still cover every
                // index, or a lone healthy replica could be missed
                let start = self.rr_next.fetch_add(1, Ordering::Relaxed);
                (0..n).map(|k| start.wrapping_add(k) % n).find(|&i| healthy(i))
            }
            RoutePolicy::LeastLoaded => {
                let alive: Vec<usize> = (0..n).filter(|&i| healthy(i)).collect();
                match alive.len() {
                    0 => None,
                    1 => Some(alive[0]),
                    k => {
                        // power of two choices: two independent draws,
                        // keep the less-loaded one
                        let r = self.next_rand();
                        let a = alive[(r >> 32) as usize % k];
                        let mut b = alive[(r as u32) as usize % k];
                        if a == b {
                            b = alive[((r as u32) as usize + 1) % k];
                        }
                        let (la, lb) =
                            (self.replicas[a].in_flight(), self.replicas[b].in_flight());
                        Some(if lb < la { b } else { a })
                    }
                }
            }
            RoutePolicy::CacheAffinity => self.ring.route_filtered(req.user_id, healthy),
        }
    }

    /// Healthy replica (excluding `exclude`) with the lowest estimated
    /// sojourn — the re-route target.
    fn cheapest_alternative(&self, exclude: usize) -> Option<(usize, u64)> {
        self.replicas
            .iter()
            .filter(|r| r.id != exclude && r.healthy())
            .map(|r| (r.id, Admission::estimate_us(r)))
            .min_by_key(|&(_, est)| est)
    }

    /// Route and serve one request under its tenant's deadline (the
    /// cluster default unless the tenant registry overrides it).
    pub fn submit(&self, req: &Request) -> Result<Response> {
        self.submit_with_budget(req, self.cfg.tenants.budget_us(req.tenant, self.deadline_us()))
    }

    /// Route and serve one request with an explicit deadline budget
    /// (µs). When the overload controller is on, the request first
    /// passes its weighted-fair gate: an over-share tenant under
    /// pressure has part of its stream degraded — candidates truncated
    /// (the `TruncatedCandidates` rung) at moderate shed levels, refused
    /// outright (`Shed`) beyond [`controller::TRUNCATE_CEILING`] — so a
    /// flash crowd pays its own overload bill before it can queue.
    pub fn submit_with_budget(&self, req: &Request, budget_us: u64) -> Result<Response> {
        if let Some(ctrl) = &self.controller {
            ctrl.note_submit(req.tenant);
            ctrl.maybe_tick(self.queue_permille());
            match ctrl.decision(req.tenant) {
                Decision::Admit => {}
                Decision::Truncate => {
                    let keep = (req.candidates.len() / 2).max(1);
                    let mut truncated = req.clone();
                    truncated.candidates.truncate(keep);
                    return self.submit_gated(
                        &truncated,
                        budget_us,
                        Some(crate::chaos::ServeQuality::TruncatedCandidates),
                    );
                }
                Decision::Shed => {
                    self.admission.note_shed();
                    self.metrics.record_quality(crate::chaos::ServeQuality::Shed);
                    self.metrics.record_tenant_shed(req.tenant);
                    self.metrics
                        .record_tenant_quality(req.tenant, crate::chaos::ServeQuality::Shed);
                    return Err(Error::Overloaded(format!(
                        "overload controller shed tenant {} request {} (level {}‰)",
                        req.tenant.0,
                        req.request_id,
                        ctrl.shed_permille(req.tenant)
                    )));
                }
            }
        }
        self.submit_gated(req, budget_us, None)
    }

    /// The post-controller request path: result-cache lookup
    /// (hit/coalesce = serve without touching a replica) → policy pick →
    /// deadline admission (re-route or shed) → dispatch (one failover
    /// retry on replica error) → SLA accounting. `quality_floor` carries
    /// a controller-imposed degradation rung into the response.
    fn submit_gated(
        &self,
        req: &Request,
        budget_us: u64,
        quality_floor: Option<crate::chaos::ServeQuality>,
    ) -> Result<Response> {
        let t0 = Instant::now();
        // one OnceLock::get returning None when tracing is off
        let mut trace = self.metrics.trace_begin(req.request_id, budget_us);
        if let Some(rc) = &self.result_cache {
            let cache_begin = trace.as_ref().map_or(0, |c| c.now_us());
            // every begin() classification below must mirror into
            // `self.metrics` — the Recorder's result_* counters and
            // the ResultCache's own are two sinks of the same events
            match rc.begin(req, Duration::from_micros(budget_us)) {
                result_cache::Begin::Hit(resp) => {
                    self.metrics.record_result_hit();
                    if let Some(ctx) = trace.as_mut() {
                        let end = ctx.now_us();
                        ctx.span(StageKind::Cache, cache_begin, end);
                    }
                    return Ok(self.finish_cached(req, resp, t0, budget_us, quality_floor, trace));
                }
                result_cache::Begin::Coalesced(resp, leader_span) => {
                    self.metrics.record_result_coalesced();
                    // the whole wait rode the leader's computation: the
                    // cache span links to the leader's flight span
                    if let Some(ctx) = trace.as_mut() {
                        let end = ctx.now_us();
                        ctx.span_linked(StageKind::Cache, cache_begin, end, &[leader_span]);
                    }
                    return Ok(self.finish_cached(req, resp, t0, budget_us, quality_floor, trace));
                }
                result_cache::Begin::Leader(mut flight) => {
                    self.metrics.record_result_miss();
                    // allocate the shared flight-span id up front so
                    // waiters observe it with the published outcome
                    let tracer = self.metrics.tracer().map(Arc::clone);
                    let span_id = tracer.as_ref().map_or(0, |t| t.new_span_id());
                    flight.set_span_id(span_id);
                    let flight_begin = tracer.as_ref().map_or(0, |t| t.now_us());
                    let result = self.dispatch(req, budget_us, t0, quality_floor);
                    if let Some(t) = &tracer {
                        t.emit_shared(SharedSpan {
                            span_id,
                            kind: StageKind::Cache,
                            label: format!("single-flight leader req {}", req.request_id),
                            begin_us: flight_begin,
                            end_us: t.now_us(),
                            pid: self.metrics.tracer_pid(),
                            tid: obs::tid(),
                            member_traces: trace
                                .as_ref()
                                .map(|c| vec![c.trace_id()])
                                .unwrap_or_default(),
                        });
                    }
                    if let Some(ctx) = trace.as_mut() {
                        let end = ctx.now_us();
                        ctx.span_linked(StageKind::Compute, flight_begin, end, &[span_id]);
                    }
                    flight.complete(req, &result);
                    self.finish_trace(trace);
                    return result;
                }
                result_cache::Begin::Fallback => {
                    // the in-flight leader failed or overran our budget:
                    // compute independently, no re-coalescing
                    self.metrics.record_result_miss();
                }
            }
        }
        let compute_begin = trace.as_ref().map_or(0, |c| c.now_us());
        let result = self.dispatch(req, budget_us, t0, quality_floor);
        if let Some(ctx) = trace.as_mut() {
            let end = ctx.now_us();
            ctx.span(StageKind::Compute, compute_begin, end);
        }
        self.finish_trace(trace);
        result
    }

    /// Finish a router-level trace, judging the SLA against its budget.
    fn finish_trace(&self, trace: Option<TraceContext>) {
        if let Some(ctx) = trace {
            let sla = ctx.budget_us() > 0 && ctx.elapsed_us() > ctx.budget_us();
            self.metrics.trace_finish(ctx, sla);
        }
    }

    /// Complete a request served from the result tier: stamp the
    /// requester's own elapsed time and account it exactly like a
    /// backend completion (it *is* one, just a free one).
    fn finish_cached(
        &self,
        req: &Request,
        mut resp: Response,
        t0: Instant,
        budget_us: u64,
        quality_floor: Option<crate::chaos::ServeQuality>,
        trace: Option<TraceContext>,
    ) -> Response {
        let elapsed_us = t0.elapsed().as_micros() as u64;
        resp.overall_us = elapsed_us;
        // a cache-served answer sits on the CachedResult rung of the
        // degradation ladder (unless the cached row — or a controller-
        // imposed floor — was itself worse)
        resp.quality = resp.quality.worst(crate::chaos::ServeQuality::CachedResult);
        if let Some(floor) = quality_floor {
            resp.quality = resp.quality.worst(floor);
        }
        self.metrics.record_request(elapsed_us, req.m());
        self.metrics.record_quality(resp.quality);
        let missed = elapsed_us > budget_us;
        self.metrics.record_tenant_request(req.tenant, elapsed_us, missed);
        self.metrics.record_tenant_quality(req.tenant, resp.quality);
        self.admission.note_completion(elapsed_us, budget_us);
        if let Some(ctrl) = &self.controller {
            ctrl.note_outcome(req.tenant, missed);
        }
        self.finish_trace(trace);
        resp
    }

    /// Policy pick → deadline admission → replica dispatch — the
    /// pre-result-cache request path. Degradation machinery lives here:
    /// half-open canaries re-prove ejected replicas, replica errors get
    /// budget-aware retry-with-backoff, and (opt-in) a hedged
    /// re-dispatch races a second replica when the first looks browned
    /// out.
    fn dispatch(
        &self,
        req: &Request,
        budget_us: u64,
        t0: Instant,
        quality_floor: Option<crate::chaos::ServeQuality>,
    ) -> Result<Response> {
        // Admission sees the budget *remaining* at this instant: time
        // already burned since t0 (e.g. waiting on a single-flight
        // leader that failed) must not be granted a second time. SLA
        // accounting below still judges against the full budget.
        let remaining_us = budget_us.saturating_sub(t0.elapsed().as_micros() as u64);

        // Half-open canary: a cooled-down ejected replica gets exactly
        // one request before full traffic returns. A successful canary
        // is this request's answer; a failed one re-ejects the replica
        // and the request falls through to normal dispatch.
        let mut result = None;
        let mut last_target = usize::MAX;
        for r in &self.replicas {
            if r.try_acquire_probe() {
                let probe = r.probe_serve(req);
                if probe.is_ok() {
                    result = Some(probe);
                } else {
                    last_target = r.id;
                }
                break;
            }
        }

        let mut result = match result {
            Some(ok) => ok,
            None => {
                let primary = self
                    .pick(req)
                    .ok_or_else(|| Error::Overloaded("no healthy replicas".into()))?;

                // The overload controller widens this tenant's tail blend
                // when its SLA-miss rate climbs, so admission stops
                // trusting a lagging rolling-window p99 mid-regime-shift.
                let blend = self
                    .controller
                    .as_ref()
                    .map_or(1_000, |c| c.blend_permille(req.tenant));
                let target = match self.admission.check_with(
                    &self.replicas[primary],
                    remaining_us,
                    blend,
                ) {
                    Verdict::Admit => primary,
                    Verdict::Overbudget { estimate_us } => {
                        match self.cheapest_alternative(primary) {
                            Some((alt, est)) if self.cfg.reroute && est <= remaining_us => {
                                self.admission.note_reroute();
                                alt
                            }
                            _ => {
                                self.admission.note_shed();
                                self.metrics.record_quality(crate::chaos::ServeQuality::Shed);
                                self.metrics.record_tenant_shed(req.tenant);
                                self.metrics.record_tenant_quality(
                                    req.tenant,
                                    crate::chaos::ServeQuality::Shed,
                                );
                                return Err(Error::Overloaded(format!(
                                    "deadline admission: estimated {estimate_us} µs > remaining budget {remaining_us} µs on replica {primary}"
                                )));
                            }
                        }
                    }
                };
                last_target = target;
                self.serve_maybe_hedged(target, req, remaining_us)
            }
        };

        // Budget-aware retry-with-backoff: each failed attempt re-routes
        // to the cheapest alternative after an exponential pause, as
        // long as budget remains and attempts are left.
        let mut attempt: u32 = 0;
        while result.is_err() && self.cfg.reroute && attempt < self.cfg.max_retries {
            let rem = budget_us.saturating_sub(t0.elapsed().as_micros() as u64);
            if rem == 0 {
                break;
            }
            let backoff = self.cfg.retry_backoff_us.saturating_mul(1 << attempt.min(10));
            if backoff >= rem {
                break;
            }
            if backoff > 0 {
                crate::util::timeutil::precise_wait(Duration::from_micros(backoff));
            }
            let Some((alt, _)) = self.cheapest_alternative(last_target) else { break };
            self.admission.note_reroute();
            self.metrics.record_retry();
            result = self.replicas[alt].serve_tracked(req);
            last_target = alt;
            attempt += 1;
        }

        if let Ok(resp) = &mut result {
            if let Some(floor) = quality_floor {
                resp.quality = resp.quality.worst(floor);
            }
            let elapsed_us = t0.elapsed().as_micros() as u64;
            self.metrics.record_request(elapsed_us, req.m());
            self.metrics.record_quality(resp.quality);
            let missed = elapsed_us > budget_us;
            self.metrics.record_tenant_request(req.tenant, elapsed_us, missed);
            self.metrics.record_tenant_quality(req.tenant, resp.quality);
            self.admission.note_completion(elapsed_us, budget_us);
            if let Some(ctrl) = &self.controller {
                ctrl.note_outcome(req.tenant, missed);
            }
        }
        result
    }

    /// Serve on `target`, racing a hedged re-dispatch to the cheapest
    /// alternative when hedging is on and the primary has not answered
    /// within ~2x its estimate (the brownout signature). First answer
    /// wins; the loser's dispatch carries a [`CancelToken`] fired the
    /// moment the winner lands, so the losing completion keeps its load
    /// and health accounting but stays out of the latency/SLA feeds —
    /// the request was already counted once by the winner. The fire is
    /// counted under `cancelled_total{cause="hedge_loser"}` exactly when
    /// the CAS wins (best-effort: a primary that finished in the same
    /// instant the winner landed already recorded itself).
    fn serve_maybe_hedged(
        &self,
        target: usize,
        req: &Request,
        remaining_us: u64,
    ) -> Result<Response> {
        if !self.cfg.hedge {
            return self.replicas[target].serve_tracked(req);
        }
        let Some((alt, _)) = self.cheapest_alternative(target) else {
            return self.replicas[target].serve_tracked(req);
        };
        let est = Admission::estimate_us(&self.replicas[target]);
        // wait 2x the estimate (min 1 ms floor for cold estimators) but
        // never more than half the remaining budget before hedging
        let hedge_after_us = est.saturating_mul(2).max(1_000).min(remaining_us / 2).max(100);
        let (tx, rx) = std::sync::mpsc::channel();
        let primary = Arc::clone(&self.replicas[target]);
        let req_owned = req.clone();
        let loser = CancelToken::new();
        let loser_primary = loser.clone();
        std::thread::spawn(move || {
            let _ = tx
                .send(primary.serve_tracked_cancellable(&req_owned, Some(&loser_primary)));
        });
        let cancel_loser = || {
            if loser.cancel(CancelCause::HedgeLoser) {
                self.metrics.record_cancelled(
                    CancelCause::HedgeLoser,
                    CancelStage::Hedge,
                    req.m() as u64,
                );
            }
        };
        match rx.recv_timeout(Duration::from_micros(hedge_after_us)) {
            Ok(first) => first,
            Err(_) => {
                self.metrics.record_hedge();
                match self.replicas[alt].serve_tracked(req) {
                    Ok(resp) => {
                        self.metrics.record_hedge_win();
                        // the winner landed: the still-running primary is
                        // now a pure loser — cancel it so its completion
                        // cannot double-count this request
                        cancel_loser();
                        Ok(resp)
                    }
                    Err(hedge_err) => {
                        // hedge failed too: give the primary the rest of
                        // the budget (plus slack) to come through
                        let grace = Duration::from_micros(remaining_us.max(1_000));
                        match rx.recv_timeout(grace) {
                            Ok(primary_result) => primary_result,
                            Err(_) => {
                                // abandoned past the grace window: mark
                                // the primary a loser so its eventual
                                // completion stays out of the feeds
                                cancel_loser();
                                Err(hedge_err)
                            }
                        }
                    }
                }
            }
        }
    }

    /// Exact aggregate feature-cache hit rate across all replicas.
    pub fn aggregate_cache_hit_rate(&self) -> f64 {
        let (mut hits, mut misses) = (0u64, 0u64);
        for r in &self.replicas {
            let (h, m) = r.cache_counts();
            hits += h;
            misses += m;
        }
        if hits + misses == 0 {
            0.0
        } else {
            hits as f64 / (hits + misses) as f64
        }
    }

    pub fn snapshot(&self) -> ClusterSnapshot {
        let (result_hits, result_misses, result_coalesced) =
            self.result_cache.as_ref().map_or((0, 0, 0), |rc| rc.counts());
        ClusterSnapshot {
            policy: self.cfg.policy.name(),
            replicas: self.replicas.iter().map(|r| r.snapshot()).collect(),
            shed: self.admission.shed(),
            sla_misses: self.admission.sla_misses(),
            rerouted: self.admission.rerouted(),
            aggregate_cache_hit_rate: self.aggregate_cache_hit_rate(),
            result_hits,
            result_misses,
            result_coalesced,
            retries: self.metrics.retries(),
            hedges: self.metrics.hedges(),
            hedge_wins: self.metrics.hedge_wins(),
            probes_ok: self.replicas.iter().map(|r| r.probes_ok_total()).sum(),
            probes_failed: self.replicas.iter().map(|r| r.probes_failed_total()).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_router(n: usize, policy: RoutePolicy) -> ClusterRouter {
        let backends: Vec<Arc<dyn ReplicaBackend>> = (0..n)
            .map(|_| {
                Arc::new(SimReplica::new(SimConfig {
                    base_us: 0,
                    per_pair_ns: 0,
                    miss_penalty_us: 0,
                    ..SimConfig::default()
                })) as Arc<dyn ReplicaBackend>
            })
            .collect();
        ClusterRouter::new(backends, ClusterConfig { policy, ..ClusterConfig::default() })
            .unwrap()
    }

    fn req(id: u64, user: u64) -> Request {
        Request {
            request_id: id,
            user_id: user,
            history: vec![],
            candidates: vec![1, 2],
            ..Default::default()
        }
    }

    #[test]
    fn empty_cluster_rejected() {
        assert!(ClusterRouter::new(Vec::new(), ClusterConfig::default()).is_err());
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let router = sim_router(3, RoutePolicy::RoundRobin);
        for i in 0..300 {
            router.submit(&req(i, i)).unwrap();
        }
        for r in router.replicas() {
            assert_eq!(r.metrics.requests(), 100, "replica {}", r.id);
        }
    }

    #[test]
    fn affinity_pins_users_to_one_replica() {
        let router = sim_router(4, RoutePolicy::CacheAffinity);
        for round in 0..5 {
            for user in 0..40u64 {
                router.submit(&req(round * 40 + user, user)).unwrap();
            }
        }
        // every user's 5 requests hit exactly one replica: 40 misses
        // total, 160 hits, aggregate hit rate 0.8
        let (mut hits, mut misses) = (0u64, 0u64);
        for r in router.replicas() {
            let (h, m) = r.cache_counts();
            hits += h;
            misses += m;
        }
        assert_eq!(misses, 40);
        assert_eq!(hits, 160);
        assert!((router.aggregate_cache_hit_rate() - 0.8).abs() < 1e-9);
    }

    #[test]
    fn p2c_roughly_balances() {
        let router = sim_router(4, RoutePolicy::LeastLoaded);
        for i in 0..4_000 {
            router.submit(&req(i, i)).unwrap();
        }
        for r in router.replicas() {
            let n = r.metrics.requests();
            assert!((500..2_000).contains(&n), "replica {} got {n}", r.id);
        }
    }

    #[test]
    fn snapshot_reports_totals() {
        let router = sim_router(2, RoutePolicy::RoundRobin);
        for i in 0..10 {
            router.submit(&req(i, i)).unwrap();
        }
        let snap = router.snapshot();
        assert_eq!(snap.policy, "round-robin");
        assert_eq!(snap.replicas.len(), 2);
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.replicas.iter().map(|r| r.requests).sum::<u64>(), 10);
        // tier disabled by default: counters stay zero even for dupes
        assert_eq!((snap.result_hits, snap.result_misses, snap.result_coalesced), (0, 0, 0));
    }

    #[test]
    fn result_cache_short_circuits_duplicate_submissions() {
        let backends: Vec<Arc<dyn ReplicaBackend>> = (0..2)
            .map(|_| {
                Arc::new(SimReplica::new(SimConfig {
                    base_us: 0,
                    per_pair_ns: 0,
                    miss_penalty_us: 0,
                    ..SimConfig::default()
                })) as Arc<dyn ReplicaBackend>
            })
            .collect();
        let cfg = ClusterConfig {
            result_cache: ResultCacheConfig {
                capacity: 256,
                ttl_ms: 60_000,
                ..ResultCacheConfig::default()
            },
            ..ClusterConfig::default()
        };
        let router = ClusterRouter::new(backends, cfg).unwrap();
        // 5 identical (user, candidates) submissions: 1 backend serve
        for i in 0..5 {
            router.submit(&req(i, 42)).unwrap();
        }
        let snap = router.snapshot();
        assert_eq!(snap.result_hits, 4, "duplicates must hit the result tier");
        assert_eq!(snap.result_misses, 1);
        assert_eq!(
            snap.replicas.iter().map(|r| r.requests).sum::<u64>(),
            1,
            "only the first submission may reach a replica"
        );
        // router-level throughput still counts all five completions
        assert_eq!(router.metrics.requests(), 5);
        let m = router.metrics.snapshot();
        assert_eq!((m.result_hits, m.result_misses, m.result_coalesced), (4, 1, 0));
    }

    fn tenant_req(id: u64, tenant: u8, candidates: Vec<u64>) -> Request {
        Request {
            request_id: id,
            user_id: id,
            history: vec![],
            candidates,
            tenant: crate::workload::TenantId(tenant),
        }
    }

    #[test]
    fn tenant_sla_override_and_per_tenant_accounting() {
        let backends: Vec<Arc<dyn ReplicaBackend>> = (0..2)
            .map(|_| {
                Arc::new(SimReplica::new(SimConfig {
                    base_us: 5_000,
                    per_pair_ns: 0,
                    miss_penalty_us: 0,
                    ..SimConfig::default()
                })) as Arc<dyn ReplicaBackend>
            })
            .collect();
        let cfg = ClusterConfig {
            policy: RoutePolicy::RoundRobin,
            tenants: TenantSet::parse("t1:sla_ms=1").unwrap(),
            ..ClusterConfig::default()
        };
        let router = ClusterRouter::new(backends, cfg).unwrap();
        // tenant 1's 1 ms override makes a 5 ms serve an SLA miss — it
        // goes first, while the cold sojourn estimator still admits it
        router.submit(&tenant_req(100, 1, vec![1, 2])).unwrap();
        // tenant 0 rides the 50 ms cluster default: a 5 ms serve is fine
        for i in 0..4 {
            router.submit(&tenant_req(i, 0, vec![1, 2])).unwrap();
        }
        let counts = router.metrics.tenant_counts();
        assert_eq!(counts[0].requests, 4);
        assert_eq!(counts[0].sla_miss, 0, "tenant 0 within its default budget");
        assert_eq!(counts[1].requests, 1);
        assert_eq!(counts[1].sla_miss, 1, "tenant 1's tighter SLA judged the same latency");
        assert_eq!(counts[2].requests, 0, "unused tenants stay silent");
    }

    #[test]
    fn controller_gate_truncates_an_over_share_tenant() {
        let mut cfg = ClusterConfig { policy: RoutePolicy::RoundRobin, ..Default::default() };
        cfg.controller = true;
        let backends: Vec<Arc<dyn ReplicaBackend>> = (0..2)
            .map(|_| {
                Arc::new(SimReplica::new(SimConfig {
                    base_us: 0,
                    per_pair_ns: 0,
                    miss_penalty_us: 0,
                    ..SimConfig::default()
                })) as Arc<dyn ReplicaBackend>
            })
            .collect();
        let router = ClusterRouter::new(backends, cfg).unwrap();
        let ctrl = router.controller().expect("controller configured on");
        // one overloading window: tenant 0 floods and misses under
        // pressure, tenant 1 stays in-share → shed level lands in the
        // truncate regime (SHED_STEP ≤ TRUNCATE_CEILING)
        for _ in 0..900 {
            ctrl.note_submit(crate::workload::TenantId(0));
        }
        for _ in 0..100 {
            ctrl.note_submit(crate::workload::TenantId(1));
            ctrl.note_outcome(crate::workload::TenantId(1), false);
        }
        for i in 0..900 {
            ctrl.note_outcome(crate::workload::TenantId(0), i < 500);
        }
        ctrl.tick(1_000);
        assert!(ctrl.shed_permille(crate::workload::TenantId(0)) > 0);
        let (mut full, mut truncated) = (0u64, 0u64);
        for i in 0..300 {
            let resp = router.submit(&tenant_req(i, 0, vec![1, 2, 3, 4])).unwrap();
            match resp.m {
                4 => full += 1,
                2 => {
                    truncated += 1;
                    assert_eq!(resp.quality, crate::chaos::ServeQuality::TruncatedCandidates);
                }
                m => panic!("unexpected candidate count {m}"),
            }
        }
        assert!(truncated > 0, "some of the flash stream must be truncated");
        assert!(full > 0, "truncation is partial, not a blackout");
        let counts = router.metrics.tenant_counts();
        assert_eq!(
            counts[0].quality[crate::chaos::ServeQuality::TruncatedCandidates.index()],
            truncated,
            "tenant quality ladder records every truncation"
        );
    }

    #[test]
    fn controller_shed_surfaces_in_tenant_views_and_recovers() {
        let mut cfg = ClusterConfig { policy: RoutePolicy::RoundRobin, ..Default::default() };
        cfg.controller = true;
        let backends: Vec<Arc<dyn ReplicaBackend>> = (0..2)
            .map(|_| {
                Arc::new(SimReplica::new(SimConfig {
                    base_us: 0,
                    per_pair_ns: 0,
                    miss_penalty_us: 0,
                    ..SimConfig::default()
                })) as Arc<dyn ReplicaBackend>
            })
            .collect();
        let router = ClusterRouter::new(backends, cfg).unwrap();
        let ctrl = router.controller().unwrap();
        let t0 = crate::workload::TenantId(0);
        let t1 = crate::workload::TenantId(1);
        // sustained overload escalates past the truncate ceiling
        for _ in 0..6 {
            for _ in 0..900 {
                ctrl.note_submit(t0);
                ctrl.note_outcome(t0, true);
            }
            for _ in 0..100 {
                ctrl.note_submit(t1);
                ctrl.note_outcome(t1, false);
            }
            ctrl.tick(1_000);
        }
        assert!(ctrl.shed_permille(t0) > controller::TRUNCATE_CEILING);
        let mut shed_errs = 0u64;
        for i in 0..200 {
            if router.submit(&tenant_req(i, 0, vec![1, 2])).is_err() {
                shed_errs += 1;
            }
        }
        assert!(shed_errs > 50, "a 900‰ level sheds most of the stream: {shed_errs}");
        let counts = router.metrics.tenant_counts();
        assert_eq!(counts[0].shed, shed_errs, "tenant view counts every controller shed");
        assert_eq!(
            counts[0].quality[crate::chaos::ServeQuality::Shed.index()],
            shed_errs
        );
        assert_eq!(counts[1].shed, 0, "quiet tenant untouched");
        assert!(router.snapshot().shed >= shed_errs, "cluster shed totals include the gate");
        // storm passes: clean windows decay the level to zero
        for _ in 0..20 {
            for _ in 0..50 {
                ctrl.note_submit(t0);
                ctrl.note_outcome(t0, false);
            }
            ctrl.tick(0);
        }
        assert_eq!(ctrl.shed_permille(t0), 0);
        for i in 200..250 {
            router.submit(&tenant_req(i, 0, vec![1, 2])).unwrap();
        }
    }
}
