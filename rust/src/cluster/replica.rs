//! One routable replica: a serving backend wrapped with the telemetry
//! the router reads on every placement decision — in-flight load, queue
//! depth, a rolling latency histogram (p99 service estimate), and the
//! consecutive-error health state machine with timed re-admission.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::metrics::{Histogram, Recorder};
use crate::pda::StagingArena;
use crate::server::pipeline::{Response, ServingStack};
use crate::workload::Request;

/// Rolling-window epoch for the admission estimator (see
/// [`RollingWindow`]): estimates reflect roughly the last 1–2 s.
const ROLLING_EPOCH_US: u64 = 1_000_000;

/// Anything the cluster router can place a request on: a real
/// [`ServingStack`] ([`StackReplica`]) or the artifact-free simulated
/// backend (`cluster::sim::SimReplica`) used by benches and tests.
pub trait ReplicaBackend: Send + Sync {
    /// Serve one request synchronously.
    fn serve(&self, req: &Request) -> Result<Response>;

    /// (hits, misses) of this backend's feature cache. The router sums
    /// exact counts across replicas — an aggregate hit rate, not an
    /// average of per-replica rates.
    fn cache_counts(&self) -> (u64, u64) {
        (0, 0)
    }

    fn cache_hit_rate(&self) -> f64 {
        let (h, m) = self.cache_counts();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }
}

/// A real serving stack as a cluster backend. `ServingStack::serve`
/// needs a caller-owned staging arena; this wrapper keeps a small pool
/// so concurrent router submissions each get one without re-allocating.
pub struct StackReplica {
    stack: Arc<ServingStack>,
    arenas: Mutex<Vec<StagingArena>>,
}

impl StackReplica {
    pub fn new(stack: Arc<ServingStack>) -> Self {
        StackReplica { stack, arenas: Mutex::new(Vec::new()) }
    }

    pub fn stack(&self) -> &Arc<ServingStack> {
        &self.stack
    }
}

impl ReplicaBackend for StackReplica {
    fn serve(&self, req: &Request) -> Result<Response> {
        let mut arena = self
            .arenas
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_else(|| StagingArena::new(self.stack.arena_capacity()));
        let result = self.stack.serve(req, &mut arena);
        self.arenas.lock().unwrap_or_else(|e| e.into_inner()).push(arena);
        result
    }

    fn cache_counts(&self) -> (u64, u64) {
        let (hits, stale, misses, _, _) = self.stack.query.cache().stats.snapshot();
        (hits + stale, misses)
    }
}

/// Rolling-window latency view: two histogram epochs rotated on a wall
/// clock. Estimates read the recent window only, so one saturation
/// episode stops poisoning admission decisions once traffic (or idle
/// time) moves two epochs past it — a cumulative histogram would keep a
/// replica shedding forever after a single bad spell. Rotation may race
/// with concurrent records and drop a few samples; the estimator
/// tolerates that (exact accounting lives in `Replica::metrics`).
struct RollingWindow {
    cur: Histogram,
    prev: Histogram,
    epoch_start_us: AtomicU64,
    epoch_us: u64,
}

impl RollingWindow {
    fn new(epoch_us: u64) -> Self {
        RollingWindow {
            cur: Histogram::new(),
            prev: Histogram::new(),
            epoch_start_us: AtomicU64::new(0),
            epoch_us,
        }
    }

    fn maybe_rotate(&self, now_us: u64) {
        let start = self.epoch_start_us.load(Ordering::Relaxed);
        let elapsed = now_us.saturating_sub(start);
        if elapsed < self.epoch_us {
            return;
        }
        if self
            .epoch_start_us
            .compare_exchange(start, now_us, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            if elapsed >= self.epoch_us.saturating_mul(2) {
                // ≥2 epochs passed: everything in both windows predates
                // the window we report. A single rotation here would
                // carry an ancient tail (say, one saturation episode
                // minutes ago) into `prev` and keep admission starving
                // an idle replica — clear both epochs instead.
                self.prev.reset();
                self.cur.reset();
            } else {
                self.prev.reset();
                self.prev.merge(&self.cur);
                self.cur.reset();
            }
        }
    }

    fn record(&self, now_us: u64, v: u64) {
        self.maybe_rotate(now_us);
        self.cur.record(v);
    }

    /// Conservative tail estimate over the two live epochs.
    fn p99(&self, now_us: u64) -> u64 {
        self.maybe_rotate(now_us);
        self.cur.p99().max(self.prev.p99())
    }

    /// Count-weighted mean over the two live epochs.
    fn mean(&self, now_us: u64) -> u64 {
        self.maybe_rotate(now_us);
        let (nc, np) = (self.cur.count(), self.prev.count());
        if nc + np == 0 {
            return 0;
        }
        ((self.cur.mean() * nc as f64 + self.prev.mean() * np as f64) / (nc + np) as f64) as u64
    }
}

/// Point-in-time view of one replica (cluster reports).
#[derive(Clone, Debug)]
pub struct ReplicaSnapshot {
    pub id: usize,
    pub requests: u64,
    pub in_flight: usize,
    pub queue_depth: usize,
    pub mean_ms: f64,
    pub p99_ms: f64,
    pub cache_hit_rate: f64,
    pub errors: u64,
    pub ejections: u64,
    pub healthy: bool,
}

/// A backend wrapped with router-side accounting and health state.
pub struct Replica {
    pub id: usize,
    backend: Arc<dyn ReplicaBackend>,
    /// Cumulative router-side latency/throughput accounting.
    pub metrics: Recorder,
    /// Rolling latency window — what `p99_us`/`mean_us` (and therefore
    /// the admission estimator) read.
    window: RollingWindow,
    in_flight: AtomicUsize,
    /// Service-parallelism hint for the sojourn estimator: in-flight
    /// work beyond this many requests is treated as queued.
    slots: usize,
    consecutive_errors: AtomicU32,
    eject_after: u32,
    cooldown_us: u64,
    /// Ejection deadline in µs since `epoch`. Once the clock passes it
    /// the replica is *half-open*, not healthy: one canary request must
    /// succeed (`try_acquire_probe` / `probe_serve`) before full traffic
    /// returns.
    ejected_until_us: AtomicU64,
    /// Set on ejection, cleared by a successful canary. While set, the
    /// replica never reports healthy even after the cooldown.
    probe_pending: AtomicBool,
    /// At most one canary in flight at a time (CAS-guarded).
    probe_inflight: AtomicBool,
    epoch: Instant,
    errors_total: AtomicU64,
    ejections_total: AtomicU64,
    probes_ok_total: AtomicU64,
    probes_failed_total: AtomicU64,
}

impl Replica {
    pub fn new(
        id: usize,
        backend: Arc<dyn ReplicaBackend>,
        slots: usize,
        eject_after: u32,
        cooldown_us: u64,
    ) -> Self {
        Replica {
            id,
            backend,
            metrics: Recorder::new(),
            window: RollingWindow::new(ROLLING_EPOCH_US),
            in_flight: AtomicUsize::new(0),
            slots: slots.max(1),
            consecutive_errors: AtomicU32::new(0),
            eject_after: eject_after.max(1),
            cooldown_us,
            ejected_until_us: AtomicU64::new(0),
            probe_pending: AtomicBool::new(false),
            probe_inflight: AtomicBool::new(false),
            epoch: Instant::now(),
            errors_total: AtomicU64::new(0),
            ejections_total: AtomicU64::new(0),
            probes_ok_total: AtomicU64::new(0),
            probes_failed_total: AtomicU64::new(0),
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    pub fn backend(&self) -> &Arc<dyn ReplicaBackend> {
        &self.backend
    }

    /// Requests currently executing or queued on this replica.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// In-flight work beyond the replica's parallel service slots.
    pub fn queue_depth(&self) -> usize {
        self.in_flight().saturating_sub(self.slots)
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Rolling p99 service latency (µs) observed by the router.
    pub fn p99_us(&self) -> u64 {
        self.window.p99(self.now_us())
    }

    /// Rolling mean service latency (µs).
    pub fn mean_us(&self) -> u64 {
        self.window.mean(self.now_us())
    }

    pub fn errors_total(&self) -> u64 {
        self.errors_total.load(Ordering::Relaxed)
    }

    pub fn ejections_total(&self) -> u64 {
        self.ejections_total.load(Ordering::Relaxed)
    }

    /// Healthy = out of the ejection cooldown *and* re-proven: ejection
    /// sets `probe_pending`, and only a successful canary
    /// ([`Replica::probe_serve`]) clears it — half-open re-admission,
    /// not the blind timed readmit this used to be. An ejected replica's
    /// path back to traffic is: cooldown passes → `probing()` →
    /// the router wins `try_acquire_probe` for one request →
    /// `probe_serve` succeeds → healthy.
    pub fn healthy(&self) -> bool {
        self.now_us() >= self.ejected_until_us.load(Ordering::Relaxed)
            && !self.probe_pending.load(Ordering::Relaxed)
    }

    /// Half-open: the cooldown has passed but the replica still owes a
    /// successful canary.
    pub fn probing(&self) -> bool {
        self.probe_pending.load(Ordering::Relaxed)
            && self.now_us() >= self.ejected_until_us.load(Ordering::Relaxed)
    }

    /// Claim the single canary slot of a half-open replica. The winner
    /// must route exactly one request via [`Replica::probe_serve`].
    pub fn try_acquire_probe(&self) -> bool {
        self.probing()
            && self
                .probe_inflight
                .compare_exchange(false, true, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
    }

    /// Serve the canary request claimed by `try_acquire_probe`: success
    /// fully re-admits the replica, a hard failure re-ejects it for
    /// another cooldown, and backend pushback (`Overloaded`) is no
    /// verdict either way — the slot frees for another canary.
    pub fn probe_serve(&self, req: &Request) -> Result<Response> {
        let result = self.serve_tracked(req);
        match &result {
            Ok(_) => {
                self.probe_pending.store(false, Ordering::Relaxed);
                self.probes_ok_total.fetch_add(1, Ordering::Relaxed);
            }
            Err(Error::Overloaded(_)) => {}
            Err(_) => {
                self.probes_failed_total.fetch_add(1, Ordering::Relaxed);
                // a failed canary is decisive: back to cooldown (unless
                // serve_tracked's note_error already re-ejected)
                if self.now_us() >= self.ejected_until_us.load(Ordering::Relaxed) {
                    self.eject();
                }
            }
        }
        self.probe_inflight.store(false, Ordering::Release);
        result
    }

    pub fn probes_ok_total(&self) -> u64 {
        self.probes_ok_total.load(Ordering::Relaxed)
    }

    pub fn probes_failed_total(&self) -> u64 {
        self.probes_failed_total.load(Ordering::Relaxed)
    }

    /// Force this replica out of rotation for its cooldown period.
    pub fn eject(&self) {
        self.ejected_until_us.store(self.now_us() + self.cooldown_us, Ordering::Relaxed);
        self.probe_pending.store(true, Ordering::Relaxed);
        self.ejections_total.fetch_add(1, Ordering::Relaxed);
        self.consecutive_errors.store(0, Ordering::Relaxed);
    }

    /// Record an error against the health state machine (public so the
    /// router's failover path and tests can drive it directly).
    pub fn note_error(&self) {
        self.errors_total.fetch_add(1, Ordering::Relaxed);
        let n = self.consecutive_errors.fetch_add(1, Ordering::Relaxed) + 1;
        if n >= self.eject_after {
            self.eject();
        }
    }

    /// Serve with load/latency/health accounting — the only path the
    /// router uses to reach the backend.
    pub fn serve_tracked(&self, req: &Request) -> Result<Response> {
        self.serve_tracked_cancellable(req, None)
    }

    /// Like [`Replica::serve_tracked`], carrying the dispatch's cancel
    /// token. A completion whose token fired (a lost hedge race, an
    /// abandoned primary) keeps its load and health accounting — the
    /// work really ran — but stays OUT of the latency feeds: the winner
    /// already recorded this request once, and double-feeding the
    /// loser's elapsed time (which spans the whole race) would inflate
    /// request counts and poison the rolling sojourn estimator the
    /// admission gate reads.
    pub fn serve_tracked_cancellable(
        &self,
        req: &Request,
        cancel: Option<&crate::cancel::CancelToken>,
    ) -> Result<Response> {
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let result = self.backend.serve(req);
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
        match &result {
            Ok(_) => {
                self.consecutive_errors.store(0, Ordering::Relaxed);
                let lost_race = cancel.is_some_and(|t| t.is_cancelled());
                if !lost_race {
                    self.record_latency(t0.elapsed().as_micros() as u64, req.m());
                }
            }
            // backend admission pushback is load, not ill health: feeding
            // it into the ejection state machine would let a traffic burst
            // eject a busy-but-alive replica (and cascade fleet-wide as
            // its load shifts). The router still counts/reroutes it.
            Err(Error::Overloaded(_)) => {}
            Err(_) => self.note_error(),
        }
        result
    }

    /// Feed an observed completion into both the cumulative accounting
    /// and the rolling estimator window (`serve_tracked` calls this; an
    /// external front observing its own latencies may too).
    pub fn record_latency(&self, elapsed_us: u64, pairs: usize) {
        self.metrics.record_request(elapsed_us, pairs);
        self.window.record(self.now_us(), elapsed_us);
    }

    pub fn cache_counts(&self) -> (u64, u64) {
        self.backend.cache_counts()
    }

    pub fn snapshot(&self) -> ReplicaSnapshot {
        let s = self.metrics.snapshot();
        ReplicaSnapshot {
            id: self.id,
            requests: s.requests,
            in_flight: self.in_flight(),
            queue_depth: self.queue_depth(),
            mean_ms: s.overall_mean_ms,
            p99_ms: s.overall_p99_ms,
            cache_hit_rate: self.backend.cache_hit_rate(),
            errors: self.errors_total(),
            ejections: self.ejections_total(),
            healthy: self.healthy(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;

    /// Minimal backend: fails while `fail` is set, else returns instantly.
    struct FlakyBackend {
        fail: std::sync::atomic::AtomicBool,
    }

    impl ReplicaBackend for FlakyBackend {
        fn serve(&self, req: &Request) -> Result<Response> {
            if self.fail.load(Ordering::Relaxed) {
                return Err(Error::Internal("down".into()));
            }
            Ok(Response {
                request_id: req.request_id,
                scores: Vec::new(),
                m: req.m(),
                overall_us: 10,
                compute_us: 5,
                feature_us: 2,
                queue_us: 0,
                handoff_us: 0,
                quality: crate::chaos::ServeQuality::Full,
            })
        }
    }

    fn req() -> Request {
        Request {
            request_id: 1,
            user_id: 9,
            history: vec![],
            candidates: vec![1, 2, 3],
            ..Default::default()
        }
    }

    fn flaky(fail: bool) -> Arc<FlakyBackend> {
        Arc::new(FlakyBackend { fail: std::sync::atomic::AtomicBool::new(fail) })
    }

    #[test]
    fn consecutive_errors_eject_and_canary_readmits() {
        let b = flaky(true);
        // eject after 2 consecutive errors, 20 ms cooldown
        let r = Replica::new(0, b.clone(), 1, 2, 20_000);
        assert!(r.healthy());
        assert!(r.serve_tracked(&req()).is_err());
        assert!(r.healthy(), "one error must not eject yet");
        assert!(r.serve_tracked(&req()).is_err());
        assert!(!r.healthy(), "second consecutive error ejects");
        assert_eq!(r.ejections_total(), 1);
        assert!(!r.try_acquire_probe(), "no canary inside the cooldown");
        std::thread::sleep(std::time::Duration::from_millis(25));
        assert!(!r.healthy(), "cooldown alone no longer re-admits: half-open");
        assert!(r.probing());
        // the backend recovers; the canary succeeds and re-admits fully
        b.fail.store(false, Ordering::Relaxed);
        assert!(r.try_acquire_probe());
        assert!(!r.try_acquire_probe(), "one canary at a time");
        assert!(r.probe_serve(&req()).is_ok());
        assert!(r.healthy(), "successful canary restores full traffic");
        assert_eq!(r.probes_ok_total(), 1);
    }

    #[test]
    fn failed_canary_re_ejects_for_another_cooldown() {
        let b = flaky(true);
        let r = Replica::new(0, b.clone(), 1, 2, 15_000);
        r.eject();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(r.try_acquire_probe());
        assert!(r.probe_serve(&req()).is_err());
        assert_eq!(r.probes_failed_total(), 1);
        assert!(!r.healthy());
        assert!(!r.probing(), "failed canary restarted the cooldown");
        assert!(!r.try_acquire_probe());
        // second cooldown passes, backend is healthy now: canary wins
        std::thread::sleep(std::time::Duration::from_millis(20));
        b.fail.store(false, Ordering::Relaxed);
        assert!(r.try_acquire_probe());
        assert!(r.probe_serve(&req()).is_ok());
        assert!(r.healthy());
    }

    #[test]
    fn success_resets_consecutive_errors() {
        let b = flaky(true);
        let r = Replica::new(0, b.clone(), 1, 3, 50_000);
        assert!(r.serve_tracked(&req()).is_err());
        assert!(r.serve_tracked(&req()).is_err());
        b.fail.store(false, Ordering::Relaxed);
        assert!(r.serve_tracked(&req()).is_ok());
        b.fail.store(true, Ordering::Relaxed);
        assert!(r.serve_tracked(&req()).is_err());
        assert!(r.healthy(), "error streak was broken by the success");
        assert_eq!(r.errors_total(), 3);
    }

    #[test]
    fn rolling_window_forgets_old_tail() {
        // explicit now_us values — no wall-clock sleeping needed
        let w = RollingWindow::new(10_000); // 10 ms epochs
        w.record(0, 50_000);
        assert!(w.p99(1_000) >= 45_000, "fresh sample visible");
        // first rotation (one epoch elapsed): survives in the previous epoch
        assert!(w.p99(15_000) >= 45_000);
        // next rotation with no new samples: the estimate decays away
        assert_eq!(w.p99(32_000), 0);
        assert_eq!(w.mean(32_000), 0);
    }

    #[test]
    fn idle_gap_clears_both_epochs() {
        let w = RollingWindow::new(10_000); // 10 ms epochs
        w.record(0, 50_000);
        // 4 epochs of idle: the old single-rotation carried the ancient
        // 50 ms tail into `prev` and kept reporting it — the gap must
        // clear both epochs so the estimate decays to cold
        assert_eq!(w.p99(40_000), 0, "stale saturation tail survived an idle gap");
        assert_eq!(w.mean(40_000), 0);
    }

    #[test]
    fn record_latency_feeds_estimator() {
        let r = Replica::new(0, flaky(false), 4, 3, 1_000);
        assert_eq!(r.p99_us(), 0, "cold replica estimates 0");
        for _ in 0..50 {
            r.record_latency(3_000, 1);
        }
        assert!(r.p99_us() >= 2_800, "estimator sees the 3 ms tail");
        assert!(r.mean_us() >= 2_800);
    }

    /// A completion whose cancel token fired (a lost hedge race, an
    /// abandoned primary) keeps load/health accounting but stays OUT of
    /// the latency feeds — the winner already recorded this request,
    /// and double-feeding the loser's race-spanning elapsed time would
    /// inflate `requests` and poison the rolling sojourn estimator.
    #[test]
    fn lost_hedge_completion_stays_out_of_latency_feeds() {
        use crate::cancel::{CancelCause, CancelToken};
        let r = Replica::new(0, flaky(false), 2, 3, 1_000);
        let live = CancelToken::new();
        assert!(r.serve_tracked_cancellable(&req(), Some(&live)).is_ok());
        assert_eq!(r.metrics.requests(), 1, "live completion feeds the estimators");

        let fired = CancelToken::new();
        fired.cancel(CancelCause::HedgeLoser);
        assert!(r.serve_tracked_cancellable(&req(), Some(&fired)).is_ok());
        assert_eq!(r.metrics.requests(), 1, "lost race must not double-count");
        assert_eq!(r.in_flight(), 0, "load accounting stays balanced");
        assert_eq!(r.errors_total(), 0, "a lost race is not ill health");
        assert!(r.healthy());
    }

    #[test]
    fn latency_and_load_accounting() {
        let r = Replica::new(3, flaky(false), 2, 3, 1_000);
        assert_eq!(r.in_flight(), 0);
        for _ in 0..10 {
            r.serve_tracked(&req()).unwrap();
        }
        assert_eq!(r.in_flight(), 0);
        assert_eq!(r.queue_depth(), 0);
        assert_eq!(r.metrics.requests(), 10);
        assert_eq!(r.metrics.pairs(), 30); // 3 candidates each
        let snap = r.snapshot();
        assert_eq!(snap.requests, 10);
        assert!(snap.healthy);
    }
}
