//! Per-tenant feedback overload controller (AIMD / brownout).
//!
//! Closes the long-open loop between observed outcomes and admission:
//! each tenant's SLA-miss rate and the cluster's queue depth feed two
//! actuators, re-evaluated once per [`TICK_US`] tick:
//!
//! * **estimator blend** — the p99-vs-mean blend handed to
//!   `Admission::check_with`. Misses escalate it additively (up to
//!   [`BLEND_MAX`], extrapolating past a lagging rolling-window p99);
//!   clean windows decay it multiplicatively back toward
//!   [`BLEND_BASE`]. Classic AIMD.
//! * **weighted-fair shed level** — a pre-dispatch degradation
//!   probability (per-mille) that only ever rises for a tenant whose
//!   observed load share exceeds 1.25x its configured weight share
//!   while the cluster is under queue pressure *and* missing SLAs. A
//!   flash crowd therefore degrades the tenant that caused it — first
//!   onto the `TruncatedCandidates` rung of the `ServeQuality` ladder,
//!   then to full sheds — while within-share tenants are never
//!   controller-shed. Clean (or pressure-free) windows decay the level
//!   multiplicatively to zero: brownout-style recovery.
//!
//! The whole controller is atomics over fixed arrays — the tick and the
//! per-request `decision`/`note_*` paths take no locks (nothing to
//! poison; a panicking worker cannot wedge admission) and allocate
//! nothing. Tick election is a CAS on the tick deadline, so exactly one
//! in-flight request pays the (cheap) re-evaluation per window.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Instant;

use crate::util::rng::splitmix64;
use crate::workload::{TenantId, MAX_TENANTS};

use super::tenant::TenantSet;

/// Controller re-evaluation period (µs).
pub const TICK_US: u64 = 50_000;
/// Neutral estimator blend (≡ the plain p99 estimate).
pub const BLEND_BASE: u64 = 1_000;
/// Blend ceiling: never extrapolate past 4x the p99-mean spread.
pub const BLEND_MAX: u64 = 4_000;
/// Additive blend step per missing window.
pub const BLEND_STEP: u64 = 250;
/// Shed-level ceiling (per-mille): never starve a tenant completely —
/// the surviving trickle is also what keeps the sensor window sampled.
pub const SHED_MAX: u64 = 900;
/// Additive shed step per overloading window.
pub const SHED_STEP: u64 = 150;
/// Shed levels at or below this degrade to candidate truncation; above
/// it the controller escalates to full front-door sheds.
pub const TRUNCATE_CEILING: u64 = 400;
/// Queue depth (per-mille of total slots) that counts as pressure.
pub const PRESSURE_PERMILLE: u64 = 700;
/// Window miss rate (per-mille) that triggers escalation.
pub const MISS_HIGH_PERMILLE: u64 = 50;
/// Window miss rate (per-mille) under which a window counts as clean.
pub const MISS_LOW_PERMILLE: u64 = 10;

/// Pre-dispatch verdict for one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    Admit,
    /// Serve degraded: truncate the candidate set (the
    /// `TruncatedCandidates` quality rung).
    Truncate,
    /// Refuse at the front door (the `Shed` quality rung).
    Shed,
}

/// The controller: per-tenant AIMD state plus one tick window of
/// outcome counters. See module docs for the control law.
pub struct OverloadController {
    start: Instant,
    seed: u64,
    weights: [u64; MAX_TENANTS],
    blend: [AtomicU64; MAX_TENANTS],
    shed_level: [AtomicU64; MAX_TENANTS],
    // current-window sensors, swapped to zero at each tick
    w_ok: [AtomicU64; MAX_TENANTS],
    w_miss: [AtomicU64; MAX_TENANTS],
    w_submit: [AtomicU64; MAX_TENANTS],
    seq: [AtomicU64; MAX_TENANTS],
    next_tick_us: AtomicU64,
    ticks: AtomicU64,
}

impl OverloadController {
    pub fn new(tenants: &TenantSet, seed: u64) -> Self {
        let mut weights = [1u64; MAX_TENANTS];
        for (i, w) in weights.iter_mut().enumerate() {
            *w = tenants.weight(i).max(1);
        }
        OverloadController {
            start: Instant::now(),
            seed: seed ^ 0xC0_17_20_11,
            weights,
            blend: std::array::from_fn(|_| AtomicU64::new(BLEND_BASE)),
            shed_level: std::array::from_fn(|_| AtomicU64::new(0)),
            w_ok: std::array::from_fn(|_| AtomicU64::new(0)),
            w_miss: std::array::from_fn(|_| AtomicU64::new(0)),
            w_submit: std::array::from_fn(|_| AtomicU64::new(0)),
            seq: std::array::from_fn(|_| AtomicU64::new(0)),
            next_tick_us: AtomicU64::new(TICK_US),
            ticks: AtomicU64::new(0),
        }
    }

    /// Sensor: a request entered the router for `tenant`.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn note_submit(&self, tenant: TenantId) {
        self.w_submit[tenant.index()].fetch_add(1, Relaxed);
    }

    /// Sensor: a completion for `tenant`, and whether it blew its budget.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn note_outcome(&self, tenant: TenantId, sla_missed: bool) {
        let i = tenant.index();
        if sla_missed {
            self.w_miss[i].fetch_add(1, Relaxed);
        } else {
            self.w_ok[i].fetch_add(1, Relaxed);
        }
    }

    /// Current estimator blend (per-mille) for `tenant` — feed to
    /// `Admission::check_with`.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn blend_permille(&self, tenant: TenantId) -> u64 {
        self.blend[tenant.index()].load(Relaxed)
    }

    /// Current shed level (per-mille) for `tenant`.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn shed_permille(&self, tenant: TenantId) -> u64 {
        self.shed_level[tenant.index()].load(Relaxed)
    }

    pub fn ticks(&self) -> u64 {
        self.ticks.load(Relaxed)
    }

    /// Pre-dispatch verdict for one `tenant` request. Deterministic in
    /// `(seed, tenant, per-tenant call ordinal)`: a shed level of L
    /// per-mille degrades L/1000 of the tenant's stream, truncating
    /// while L ≤ [`TRUNCATE_CEILING`] and shedding beyond it.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn decision(&self, tenant: TenantId) -> Decision {
        let i = tenant.index();
        let level = self.shed_level[i].load(Relaxed);
        if level == 0 {
            return Decision::Admit;
        }
        let seq = self.seq[i].fetch_add(1, Relaxed);
        let mut s = self.seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ seq;
        if splitmix64(&mut s) % 1_000 >= level {
            return Decision::Admit;
        }
        if level <= TRUNCATE_CEILING {
            Decision::Truncate
        } else {
            Decision::Shed
        }
    }

    /// Run the control law if a tick is due. CAS-elected: exactly one
    /// caller per window pays; everyone else returns immediately.
    /// `queue_permille` is cluster queue depth as per-mille of total
    /// service slots (the router computes it from replica in-flights).
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn maybe_tick(&self, queue_permille: u64) {
        let now = self.start.elapsed().as_micros() as u64;
        let due = self.next_tick_us.load(Relaxed);
        if now < due {
            return;
        }
        if self
            .next_tick_us
            .compare_exchange(due, now + TICK_US, Relaxed, Relaxed)
            .is_err()
        {
            return;
        }
        self.tick(queue_permille);
    }

    /// The control law, applied to one window of sensor readings.
    /// Public so tests (and the bench) can step the controller
    /// deterministically without waiting out real tick periods.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn tick(&self, queue_permille: u64) {
        self.ticks.fetch_add(1, Relaxed);
        let pressure = queue_permille >= PRESSURE_PERMILLE;
        // harvest the window first so share math sees one coherent view
        let mut ok = [0u64; MAX_TENANTS];
        let mut miss = [0u64; MAX_TENANTS];
        let mut submit = [0u64; MAX_TENANTS];
        let mut total_submit = 0u64;
        let mut active_weight = 0u64;
        for i in 0..MAX_TENANTS {
            ok[i] = self.w_ok[i].swap(0, Relaxed);
            miss[i] = self.w_miss[i].swap(0, Relaxed);
            submit[i] = self.w_submit[i].swap(0, Relaxed);
            total_submit += submit[i];
            if submit[i] > 0 {
                active_weight += self.weights[i];
            }
        }
        for i in 0..MAX_TENANTS {
            let completed = ok[i] + miss[i];
            let miss_pm = if completed == 0 { 0 } else { miss[i] * 1_000 / completed };
            // load share vs weighted-fair share, over *active* tenants:
            // submit_i / total > 1.25 * weight_i / active_weight
            let over_fair = total_submit > 0
                && submit[i] * active_weight * 4 > self.weights[i] * total_submit * 5;
            let blend = self.blend[i].load(Relaxed);
            let shed = self.shed_level[i].load(Relaxed);
            // additive increase: a missing window escalates the blend;
            // only an over-share tenant under real pressure is shed
            if completed >= 10 && miss_pm > MISS_HIGH_PERMILLE {
                self.blend[i].store((blend + BLEND_STEP).min(BLEND_MAX), Relaxed);
                if pressure && over_fair {
                    self.shed_level[i].store((shed + SHED_STEP).min(SHED_MAX), Relaxed);
                    continue;
                }
            }
            // multiplicative decrease: clean or pressure-free windows
            // decay both actuators (brownout recovery; zero-snap so the
            // shed level actually reaches 0, not an asymptote)
            let clean = miss_pm < MISS_LOW_PERMILLE || completed < 10;
            if clean {
                self.blend[i].store(BLEND_BASE + (blend - BLEND_BASE) * 3 / 4, Relaxed);
            }
            if clean || !pressure {
                self.shed_level[i].store(if shed < 50 { 0 } else { shed * 3 / 4 }, Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctrl() -> OverloadController {
        OverloadController::new(&TenantSet::default(), 42)
    }

    fn feed(c: &OverloadController, t: TenantId, submits: u64, ok: u64, miss: u64) {
        for _ in 0..submits {
            c.note_submit(t);
        }
        for _ in 0..ok {
            c.note_outcome(t, false);
        }
        for _ in 0..miss {
            c.note_outcome(t, true);
        }
    }

    #[test]
    fn quiet_controller_admits_everything() {
        let c = ctrl();
        for _ in 0..1_000 {
            assert_eq!(c.decision(TenantId(0)), Decision::Admit);
        }
        assert_eq!(c.blend_permille(TenantId(0)), BLEND_BASE);
        assert_eq!(c.shed_permille(TenantId(0)), 0);
    }

    #[test]
    fn misses_escalate_blend_even_without_pressure() {
        let c = ctrl();
        feed(&c, TenantId(0), 100, 50, 50);
        c.tick(100); // no queue pressure: regime shift, not overload
        assert_eq!(c.blend_permille(TenantId(0)), BLEND_BASE + BLEND_STEP);
        assert_eq!(c.shed_permille(TenantId(0)), 0, "no shed without pressure");
    }

    #[test]
    fn flash_tenant_sheds_quiet_tenant_does_not() {
        let c = ctrl();
        let (a, b) = (TenantId(0), TenantId(1));
        for _ in 0..6 {
            // A floods (90% of load, equal weights) and both miss —
            // collateral damage is exactly what a storm looks like
            feed(&c, a, 900, 400, 500);
            feed(&c, b, 100, 60, 40);
            c.tick(1_000);
        }
        assert!(
            c.shed_permille(a) >= 3 * SHED_STEP,
            "flash tenant escalates: {}",
            c.shed_permille(a)
        );
        assert_eq!(c.shed_permille(b), 0, "within-share tenant never controller-shed");
        assert!(c.blend_permille(b) > BLEND_BASE, "but B admits more conservatively");
        // the decision stream degrades A at roughly its shed level
        let level = c.shed_permille(a);
        let degraded = (0..2_000)
            .filter(|_| c.decision(a) != Decision::Admit)
            .count();
        let expect = 2_000 * level as usize / 1_000;
        assert!(
            (degraded as i64 - expect as i64).unsigned_abs() < 300,
            "level {level} → expected ~{expect}, saw {degraded}"
        );
    }

    #[test]
    fn escalation_walks_the_quality_ladder() {
        let c = ctrl();
        let a = TenantId(0);
        feed(&c, a, 900, 400, 500);
        feed(&c, a.next_other(), 100, 100, 0); // second tenant so A is over-share
        c.tick(1_000);
        assert_eq!(c.shed_permille(a), SHED_STEP);
        assert!(SHED_STEP <= TRUNCATE_CEILING);
        // low levels truncate...
        let any_shed = (0..500).any(|_| c.decision(a) == Decision::Shed);
        let any_trunc = (0..500).any(|_| c.decision(a) == Decision::Truncate);
        assert!(any_trunc && !any_shed, "low level degrades by truncation only");
        // ...sustained overload escalates past the ceiling to full sheds
        for _ in 0..5 {
            feed(&c, a, 900, 400, 500);
            feed(&c, a.next_other(), 100, 100, 0);
            c.tick(1_000);
        }
        assert!(c.shed_permille(a) > TRUNCATE_CEILING);
        assert!((0..500).any(|_| c.decision(a) == Decision::Shed));
    }

    #[test]
    fn brownout_recovery_decays_to_zero() {
        let c = ctrl();
        let (a, b) = (TenantId(0), TenantId(1));
        for _ in 0..8 {
            feed(&c, a, 900, 400, 500);
            feed(&c, b, 100, 60, 40);
            c.tick(1_000);
        }
        assert!(c.shed_permille(a) > 0 && c.blend_permille(a) > BLEND_BASE);
        // storm passes: clean windows, no pressure
        for _ in 0..20 {
            feed(&c, a, 50, 50, 0);
            feed(&c, b, 50, 50, 0);
            c.tick(100);
        }
        assert_eq!(c.shed_permille(a), 0, "shed recovers to exactly 0");
        assert_eq!(c.shed_permille(b), 0);
        assert!(
            c.blend_permille(a) <= BLEND_BASE + 50,
            "blend relaxes to ~base: {}",
            c.blend_permille(a)
        );
        for _ in 0..100 {
            assert_eq!(c.decision(a), Decision::Admit);
        }
    }

    #[test]
    fn shed_level_is_capped_below_total_starvation() {
        let c = ctrl();
        let a = TenantId(0);
        for _ in 0..50 {
            feed(&c, a, 900, 100, 800);
            feed(&c, TenantId(1), 100, 100, 0);
            c.tick(1_000);
        }
        assert_eq!(c.shed_permille(a), SHED_MAX);
        assert_eq!(c.blend_permille(a), BLEND_MAX);
        let admitted = (0..2_000).filter(|_| c.decision(a) == Decision::Admit).count();
        assert!(admitted > 50, "a trickle always survives: {admitted}");
    }

    #[test]
    fn maybe_tick_is_elected_once_per_window() {
        let c = ctrl();
        // the first window's deadline has not elapsed yet
        c.maybe_tick(0);
        assert_eq!(c.ticks(), 0);
        std::thread::sleep(std::time::Duration::from_micros(TICK_US + 20_000));
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| c.maybe_tick(0));
            }
        });
        assert_eq!(c.ticks(), 1, "exactly one caller wins the CAS election");
    }

    impl TenantId {
        /// Test helper: some other tenant id.
        fn next_other(self) -> TenantId {
            TenantId(self.0 + 1)
        }
    }
}
