//! Router-level result cache with single-flight coalescing.
//!
//! The PDA tier (§3.1) never pays twice for the same feature bytes; at
//! the cluster tier the analogous waste is re-*scoring* an identical
//! (user, candidate-set) request that a replica just answered — the
//! paper's non-uniform upstream frequently re-issues near-identical
//! candidate sets within seconds. This module puts a request-level
//! result tier in front of placement/admission:
//!
//! * **result cache** — key = hash of `(scenario salt, user_id, history,
//!   canonicalized candidate ids)`; value = the scored outcome, stored
//!   in the existing [`ShardedCache`] under a short TTL. Candidate ids
//!   are canonicalized by sorting, so a permutation of the same set
//!   hits; on a hit the cached `[m][n_tasks]` score rows are remapped
//!   into the requester's candidate order.
//! * **single-flight coalescing** — concurrent identical misses block on
//!   one in-flight computation (a per-key waiter table) instead of
//!   fanning out to N replicas. The first miss becomes the *leader* and
//!   computes; duplicates wait (bounded by their deadline budget) and
//!   share the leader's result. A failed or timed-out leader wakes the
//!   waiters empty-handed and each falls back to its own computation —
//!   errors are never amplified across coalesced requests.
//!
//! Stored results carry the user id, sorted candidates, and a history
//! hash, which are re-verified on every hit: a 64-bit key collision
//! degrades to a miss, never to wrong scores.
//!
//! Two robustness properties are load-bearing for the chaos plane:
//!
//! * **leader failure promotes a waiter** — a leader that errors *or
//!   unwinds* deregisters its flight before waking the waiters, and a
//!   woken waiter loops back to the flight table: it either coalesces
//!   behind a newer leader or registers as the **new leader** itself.
//!   No waiter is ever wedged behind a dead flight, and a storm of
//!   duplicates behind a panicking leader degrades to one retry at a
//!   time instead of a thundering herd.
//! * **feature-update invalidation** — [`ResultCache::invalidate_user`]
//!   evicts every cached row scored from a user's features ahead of the
//!   TTL, so the stale-serve degradation rungs can never return
//!   pre-update scores from this tier.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cache::{Lookup, ShardedCache};
use crate::error::Result;
use crate::server::pipeline::Response;
use crate::util::rng::splitmix64;
use crate::workload::Request;

/// Result-cache shard count (keys are pre-mixed hashes, so a modest
/// power of two spreads them well).
const SHARDS: usize = 16;

/// Single-flight waiter-table shard count (power of two; keys are
/// pre-mixed hashes, so the low bits select uniformly). One global
/// mutex here used to be the last router-wide lock on the miss path —
/// sharding it means two concurrent misses on different keys almost
/// never contend, while the per-key leader/waiter semantics are
/// untouched (a key maps to exactly one shard).
const FLIGHT_SHARDS: usize = 16;

/// Result-tier knobs (part of `ClusterConfig`).
#[derive(Clone, Debug)]
pub struct ResultCacheConfig {
    /// Total cached responses across shards; 0 disables the tier.
    pub capacity: usize,
    /// Freshness TTL for cached responses (ms). Short by design: a
    /// result is only as fresh as the features it was scored from.
    pub ttl_ms: u64,
    /// Coalesce concurrent identical misses onto one backend call.
    pub coalesce: bool,
    /// Key salt for fronts that serve several scenarios/models — the
    /// same (user, candidates) pair must not collide across scenarios.
    pub scenario_salt: u64,
}

impl Default for ResultCacheConfig {
    fn default() -> Self {
        ResultCacheConfig { capacity: 0, ttl_ms: 2_000, coalesce: true, scenario_salt: 0 }
    }
}

/// The cached scoring outcome for one (user, candidate multiset).
struct CachedScores {
    user_id: u64,
    /// Candidate ids in the order `scores` rows are laid out.
    candidates: Vec<u64>,
    /// Sorted copy — the collision check against the canonical key.
    sorted: Vec<u64>,
    history_hash: u64,
    /// `[m][n_tasks]` task probabilities, `candidates` order.
    scores: Vec<f32>,
}

impl CachedScores {
    fn matches(&self, user_id: u64, sorted: &[u64], history_hash: u64) -> bool {
        self.user_id == user_id && self.history_hash == history_hash && self.sorted == sorted
    }
}

/// One in-flight computation that coalesced duplicates wait on. A
/// successful outcome carries the leader's shared trace-span id (0 =
/// tracing off) so each waiter can link its own trace to the leader's
/// computation.
struct Flight {
    outcome: Mutex<Option<std::result::Result<(Arc<CachedScores>, u64), ()>>>,
    done: Condvar,
}

impl Flight {
    fn new() -> Self {
        Flight { outcome: Mutex::new(None), done: Condvar::new() }
    }

    fn fill(&self, outcome: std::result::Result<(Arc<CachedScores>, u64), ()>) {
        *self.outcome.lock().unwrap_or_else(|e| e.into_inner()) = Some(outcome);
        self.done.notify_all();
    }

    /// Wait up to `timeout` for the leader; `None` = timed out.
    fn wait(
        &self,
        timeout: Duration,
    ) -> Option<std::result::Result<(Arc<CachedScores>, u64), ()>> {
        // cap so an effectively-infinite deadline budget cannot overflow
        // Instant arithmetic (and cannot hang a waiter for hours)
        let deadline = Instant::now() + timeout.min(Duration::from_secs(60));
        let mut slot = self.outcome.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(out) = slot.as_ref() {
                return Some(out.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self
                .done
                .wait_timeout(slot, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            slot = guard;
        }
    }
}

/// Outcome of [`ResultCache::begin`] — what the router does next.
pub enum Begin<'a> {
    /// Fresh cached response; serve it without touching a replica.
    Hit(Response),
    /// A coalesced duplicate: an identical in-flight computation
    /// finished while we waited — serve its result. The second field is
    /// the leader's shared trace-span id (0 = tracing off), the causal
    /// edge a waiter's trace links to.
    Coalesced(Response, u64),
    /// This request leads the computation: dispatch to a replica, then
    /// [`FlightGuard::complete`] with the outcome.
    Leader(FlightGuard<'a>),
    /// The wait budget ran out against a leader that never resolved:
    /// dispatch without registering (no re-coalescing — avoids convoys
    /// behind a request that keeps failing). A leader *failure* is not
    /// this case: failed leaders deregister, and the woken waiter loops
    /// back to become the new leader.
    Fallback,
}

/// Held by the leader of an in-flight computation. Completing publishes
/// the result to the cache and every waiter; dropping without
/// completing (error/unwind paths) wakes the waiters empty-handed so
/// none of them blocks past its deadline.
pub struct FlightGuard<'a> {
    cache: &'a ResultCache,
    key: u64,
    sorted: Vec<u64>,
    history_hash: u64,
    flight: Option<Arc<Flight>>,
    /// Shared trace-span id of the leader's computation, published to
    /// waiters with the outcome (0 = tracing off).
    span_id: u64,
    /// The user's invalidation epoch as of `begin()` — re-checked at
    /// publication so an `invalidate_user` racing this flight cannot be
    /// undone by the leader's late insert (see `complete`).
    user_epoch: u64,
}

impl FlightGuard<'_> {
    /// Name the shared trace span covering this leader's computation,
    /// so coalesced waiters can link their traces to it.
    pub fn set_span_id(&mut self, span_id: u64) {
        self.span_id = span_id;
    }

    /// Publish the leader's outcome: a success is inserted into the
    /// cache and handed to every coalesced waiter; an error wakes the
    /// waiters so they fall back to their own dispatch.
    pub fn complete(mut self, req: &Request, outcome: &Result<Response>) {
        match outcome {
            Ok(resp) => {
                let cached = Arc::new(CachedScores {
                    user_id: req.user_id,
                    candidates: req.candidates.clone(),
                    sorted: std::mem::take(&mut self.sorted),
                    history_hash: self.history_hash,
                    scores: resp.scores.clone(),
                });
                self.cache.cache.insert(self.key, Arc::clone(&cached));
                self.cache.note_user_key(req.user_id, self.key);
                // Invalidation race check, AFTER publishing: if the
                // user's features were invalidated while this flight was
                // computing, the row we just inserted was scored from
                // pre-update features — take it straight back out. The
                // epoch bumps before the evictor reads the user index, so
                // every interleaving is covered: an insert the evictor
                // cannot see implies we see the bumped epoch here.
                // In-flight waiters still get the computed response (they
                // were already committed to this computation); only the
                // *cache* must forget it.
                if self.cache.user_epoch(req.user_id).load(Ordering::SeqCst) != self.user_epoch {
                    self.cache.cache.remove(self.key);
                }
                let span_id = self.span_id;
                self.finish(Ok((cached, span_id)));
            }
            Err(_) => self.finish(Err(())),
        }
    }

    fn finish(&mut self, outcome: std::result::Result<(Arc<CachedScores>, u64), ()>) {
        if let Some(flight) = self.flight.take() {
            // deregister first so a new arrival starts a fresh flight
            // instead of waiting on a completed one
            self.cache
                .flight_shard(self.key)
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .remove(&self.key);
            flight.fill(outcome);
        }
    }
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        // leader unwound without completing: wake waiters empty-handed
        self.finish(Err(()));
    }
}

/// Cross-replica result cache + single-flight table (one per router).
pub struct ResultCache {
    cache: ShardedCache<Arc<CachedScores>>,
    /// key → in-flight computation (present only while a leader runs),
    /// sharded by key hash so misses on different keys don't contend.
    inflight: Vec<Mutex<HashMap<u64, Arc<Flight>>>>,
    /// user_id → cache keys holding results scored from that user's
    /// features — the invalidation index behind [`Self::invalidate_user`].
    users: Mutex<HashMap<u64, Vec<u64>>>,
    /// Per-user-slot invalidation epochs (slot = user_id low bits).
    /// `invalidate_user` bumps the slot before evicting; a single-flight
    /// leader captures it at `begin` and re-checks at publication, so a
    /// racing invalidation can never be resurrected by a late insert.
    /// Slots are shared across users — a false epoch mismatch only
    /// drops a fresh row (a future miss), never serves a stale one.
    epochs: [AtomicU64; SHARDS],
    coalesce: bool,
    salt: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
}

fn mix(h: u64, v: u64) -> u64 {
    let mut s = h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

impl ResultCache {
    /// Build from config; `None` when the tier is disabled
    /// (`capacity == 0`).
    pub fn new(cfg: &ResultCacheConfig) -> Option<ResultCache> {
        if cfg.capacity == 0 {
            return None;
        }
        let ttl = Duration::from_millis(cfg.ttl_ms.max(1));
        Some(ResultCache {
            cache: ShardedCache::new(cfg.capacity, SHARDS, ttl),
            inflight: (0..FLIGHT_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            users: Mutex::new(HashMap::new()),
            epochs: std::array::from_fn(|_| AtomicU64::new(0)),
            coalesce: cfg.coalesce,
            salt: cfg.scenario_salt,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
        })
    }

    /// (hits, misses, coalesced) counters. A coalesced request is one
    /// that shared an in-flight leader's computation; it is counted
    /// neither as a hit nor as a miss.
    pub fn counts(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.coalesced.load(Ordering::Relaxed),
        )
    }

    /// The single-flight shard owning `key` (keys are pre-mixed, so the
    /// low bits index uniformly).
    fn flight_shard(&self, key: u64) -> &Mutex<HashMap<u64, Arc<Flight>>> {
        &self.inflight[(key as usize) & (FLIGHT_SHARDS - 1)]
    }

    /// The invalidation-epoch slot for `user_id`.
    fn user_epoch(&self, user_id: u64) -> &AtomicU64 {
        &self.epochs[(user_id as usize) & (SHARDS - 1)]
    }

    /// Record that `key` holds a result scored from `user_id`'s features
    /// (called by the leader on publication).
    fn note_user_key(&self, user_id: u64, key: u64) {
        let mut map = self.users.lock().unwrap_or_else(|e| e.into_inner());
        let keys = map.entry(user_id).or_default();
        if !keys.contains(&key) {
            keys.push(key);
        }
    }

    /// Upstream feature-update hook: a user's features just changed, so
    /// every cached result scored from the old ones is now wrong in a
    /// way the TTL cannot see. Evicts them immediately and returns how
    /// many live entries were removed (already-expired or evicted rows
    /// don't count).
    pub fn invalidate_user(&self, user_id: u64) -> usize {
        // bump FIRST: any in-flight leader that publishes after this
        // point sees the new epoch at completion and evicts its own
        // insert; any insert we could miss below published (and indexed
        // itself) before the bump, so the index walk catches it
        self.user_epoch(user_id).fetch_add(1, Ordering::SeqCst);
        let keys = self
            .users
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(&user_id)
            .unwrap_or_default();
        keys.into_iter().filter(|&k| self.cache.remove(k)).count()
    }

    /// Canonical cache key: scenario salt + user + history hash + sorted
    /// candidate ids. Returns the sorted candidates and history hash for
    /// the collision re-check on hits.
    fn key_of(&self, req: &Request) -> (u64, Vec<u64>, u64) {
        let mut sorted = req.candidates.clone();
        sorted.sort_unstable();
        let mut hh = mix(0x9E37_79B9_7F4A_7C15, req.history.len() as u64);
        for &item in &req.history {
            hh = mix(hh, item);
        }
        let mut key = mix(self.salt ^ 0xF1A8_E00D_CAFE_F00D, req.user_id);
        key = mix(key, hh);
        key = mix(key, sorted.len() as u64);
        for &c in &sorted {
            key = mix(key, c);
        }
        (key, sorted, hh)
    }

    /// Classify one request against the cache and the in-flight table.
    /// `wait_budget` bounds how long a coalesced duplicate may block on
    /// the leader (the request's deadline budget).
    pub fn begin(&self, req: &Request, wait_budget: Duration) -> Begin<'_> {
        let (key, sorted, history_hash) = self.key_of(req);
        // captured BEFORE the computation this flight may lead: an
        // invalidation landing any time after this load is visible at
        // publication (see `FlightGuard::complete`)
        let user_epoch = self.user_epoch(req.user_id).load(Ordering::SeqCst);
        if let Lookup::Fresh(cached) = self.cache.get(key) {
            if cached.matches(req.user_id, &sorted, history_hash) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Begin::Hit(self.response_from(req, &cached));
            }
        }
        if !self.coalesce {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return Begin::Leader(FlightGuard {
                cache: self,
                key,
                sorted,
                history_hash,
                flight: None,
                span_id: 0,
                user_epoch,
            });
        }
        // Flight-table loop: each pass either registers this request as
        // the leader, or parks it behind the current one. A leader that
        // *fails or unwinds* deregisters its flight before waking the
        // waiters, so a woken waiter loops back here and — finding the
        // slot empty — becomes the NEW leader (or coalesces behind
        // whoever beat it to the slot). Only the deadline exhausting
        // produces `Fallback`; a dead leader never wedges its waiters.
        let deadline = Instant::now() + wait_budget.min(Duration::from_secs(60));
        loop {
            let flight = {
                let mut map =
                    self.flight_shard(key).lock().unwrap_or_else(|e| e.into_inner());
                if let Some(f) = map.get(&key) {
                    Arc::clone(f)
                } else {
                    // Double-check the cache while holding the key's shard
                    // lock: a leader we would have waited on may have just
                    // finished — it publishes to the cache *before*
                    // deregistering (from this same shard, since a key maps
                    // to exactly one shard), so a fresh entry here is
                    // authoritative and closes the check-then-act window
                    // that would otherwise let a descheduled thread become
                    // a second leader.
                    if let Lookup::Fresh(cached) = self.cache.get(key) {
                        if cached.matches(req.user_id, &sorted, history_hash) {
                            self.hits.fetch_add(1, Ordering::Relaxed);
                            return Begin::Hit(self.response_from(req, &cached));
                        }
                    }
                    let flight = Arc::new(Flight::new());
                    map.insert(key, Arc::clone(&flight));
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    return Begin::Leader(FlightGuard {
                        cache: self,
                        key,
                        sorted,
                        history_hash,
                        flight: Some(flight),
                        span_id: 0,
                        user_epoch,
                    });
                }
            };
            let now = Instant::now();
            if now >= deadline {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return Begin::Fallback;
            }
            match flight.wait(deadline - now) {
                Some(Ok((cached, leader_span)))
                    if cached.matches(req.user_id, &sorted, history_hash) =>
                {
                    self.coalesced.fetch_add(1, Ordering::Relaxed);
                    return Begin::Coalesced(self.response_from(req, &cached), leader_span);
                }
                // leader failed/unwound (or, vanishingly, published a
                // colliding key): its flight is gone — loop back and
                // take the lead ourselves if the slot is still empty
                Some(_) => continue,
                // budget exhausted against a live-but-slow leader
                None => {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    return Begin::Fallback;
                }
            }
        }
    }

    /// Materialize a response for `req` from a cached outcome, remapping
    /// the `[m][n_tasks]` score rows when the requester's candidate
    /// order differs from the cached one. `overall_us` is left 0 for the
    /// router to stamp with its own elapsed time; compute/feature cost
    /// is 0 — a hit does no backend work.
    fn response_from(&self, req: &Request, cached: &CachedScores) -> Response {
        let scores = if cached.candidates == req.candidates || cached.scores.is_empty() {
            cached.scores.clone()
        } else {
            let n_tasks = cached.scores.len() / cached.candidates.len();
            let index: HashMap<u64, usize> = cached
                .candidates
                .iter()
                .enumerate()
                .map(|(i, &id)| (id, i))
                .collect();
            let mut out = Vec::with_capacity(req.candidates.len() * n_tasks);
            for id in &req.candidates {
                let i = index[id];
                out.extend_from_slice(&cached.scores[i * n_tasks..(i + 1) * n_tasks]);
            }
            out
        };
        Response {
            request_id: req.request_id,
            scores,
            m: req.m(),
            overall_us: 0,
            compute_us: 0,
            feature_us: 0,
            queue_us: 0,
            handoff_us: 0,
            // served from the result tier, not a live computation: the
            // CachedResult rung of the degradation ladder
            quality: crate::chaos::ServeQuality::CachedResult,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, user: u64, candidates: Vec<u64>) -> Request {
        Request {
            request_id: id,
            user_id: user,
            history: vec![user, user + 1],
            candidates,
            ..Default::default()
        }
    }

    fn resp(req: &Request, per_task: usize) -> Response {
        // deterministic, candidate-dependent scores: row i = f(candidate)
        let mut scores = Vec::with_capacity(req.m() * per_task);
        for &c in &req.candidates {
            for t in 0..per_task {
                scores.push((c as f32) + (t as f32) / 10.0);
            }
        }
        Response {
            request_id: req.request_id,
            scores,
            m: req.m(),
            overall_us: 100,
            compute_us: 80,
            feature_us: 10,
            queue_us: 0,
            handoff_us: 0,
            quality: crate::chaos::ServeQuality::Full,
        }
    }

    fn cache(coalesce: bool) -> ResultCache {
        ResultCache::new(&ResultCacheConfig {
            capacity: 1024,
            ttl_ms: 60_000,
            coalesce,
            scenario_salt: 0,
        })
        .unwrap()
    }

    #[test]
    fn disabled_at_zero_capacity() {
        assert!(ResultCache::new(&ResultCacheConfig::default()).is_none());
    }

    #[test]
    fn canonical_key_ignores_candidate_order() {
        let rc = cache(false);
        let (ka, sa, _) = rc.key_of(&req(0, 7, vec![3, 1, 2]));
        let (kb, sb, _) = rc.key_of(&req(1, 7, vec![2, 3, 1]));
        assert_eq!(ka, kb);
        assert_eq!(sa, sb);
        let (kc, _, _) = rc.key_of(&req(2, 8, vec![3, 1, 2]));
        assert_ne!(ka, kc, "different user must not share a key");
        let (kd, _, _) = rc.key_of(&req(3, 7, vec![3, 1, 4]));
        assert_ne!(ka, kd, "different candidates must not share a key");
    }

    #[test]
    fn hit_remaps_scores_to_requested_order() {
        let rc = cache(false);
        let first = req(0, 7, vec![10, 20, 30]);
        let Begin::Leader(guard) = rc.begin(&first, Duration::from_secs(1)) else {
            panic!("first sight must lead");
        };
        guard.complete(&first, &Ok(resp(&first, 2)));

        // same multiset, permuted order: a hit whose rows are remapped
        let second = req(1, 7, vec![30, 10, 20]);
        match rc.begin(&second, Duration::from_secs(1)) {
            Begin::Hit(r) => {
                assert_eq!(r.request_id, 1);
                assert_eq!(r.m, 3);
                assert_eq!(r.scores, resp(&second, 2).scores, "rows not in requested order");
            }
            _ => panic!("permuted duplicate must hit"),
        }
        let (hits, misses, coalesced) = rc.counts();
        assert_eq!((hits, misses, coalesced), (1, 1, 0));
    }

    #[test]
    fn leader_error_leaves_no_entry() {
        let rc = cache(true);
        let r = req(0, 3, vec![1, 2]);
        let Begin::Leader(guard) = rc.begin(&r, Duration::from_secs(1)) else {
            panic!("must lead");
        };
        guard.complete(&r, &Err(crate::error::Error::Internal("boom".into())));
        // the failure is not cached; the next arrival leads again
        assert!(matches!(rc.begin(&r, Duration::from_secs(1)), Begin::Leader(_)));
    }

    /// Regression (single-flight leader panic): an unwinding leader used
    /// to strand its waiters into `Fallback`; now the woken waiter loops
    /// back to the (deregistered) flight slot and takes the lead itself.
    /// If either half of the fix is lost — the drop-time wake or the
    /// waiter's re-registration loop — this test hangs or fails.
    #[test]
    fn dropped_guard_promotes_waiter_to_new_leader() {
        let rc = Arc::new(cache(true));
        let r = req(0, 3, vec![1, 2]);
        let guard = match rc.begin(&r, Duration::from_secs(1)) {
            Begin::Leader(g) => g,
            _ => panic!("must lead"),
        };
        // probe: map holds 1 ref, the guard 1, this clone 1 — a waiter
        // enqueuing behind the flight raises the count to 4
        let probe = Arc::clone(guard.flight.as_ref().unwrap());
        std::thread::scope(|s| {
            let rc2 = Arc::clone(&rc);
            let waiter = s.spawn(move || {
                let w = req(1, 3, vec![1, 2]);
                match rc2.begin(&w, Duration::from_secs(30)) {
                    Begin::Leader(g) => {
                        // the promoted waiter can complete and publish
                        g.complete(&w, &Ok(resp(&w, 2)));
                        true
                    }
                    _ => false,
                }
            });
            // wait until the waiter is actually parked behind the flight,
            // then unwind the leader without completing
            for _ in 0..5_000 {
                if Arc::strong_count(&probe) >= 4 {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            assert!(Arc::strong_count(&probe) >= 4, "waiter never enqueued");
            drop(guard);
            assert!(waiter.join().unwrap(), "waiter must become the new leader, not hang");
        });
        // the promoted leader's publication is live: next arrival hits
        let again = req(2, 3, vec![1, 2]);
        assert!(matches!(rc.begin(&again, Duration::from_secs(1)), Begin::Hit(_)));
    }

    /// Chaos-flavored variant: the leader dies by *panic* (caught by a
    /// supervisor, as in the pipeline/executor loops) rather than a tidy
    /// drop. All waiters must wake; one becomes the new leader, the rest
    /// coalesce behind it once it publishes.
    #[test]
    fn leader_panic_wakes_all_waiters_one_becomes_leader() {
        const WAITERS: usize = 4;
        let rc = Arc::new(cache(true));
        let r = req(0, 4, vec![7, 8]);
        let guard = match rc.begin(&r, Duration::from_secs(1)) {
            Begin::Leader(g) => g,
            _ => panic!("must lead"),
        };
        let probe = Arc::clone(guard.flight.as_ref().unwrap());
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..WAITERS)
                .map(|i| {
                    let rc2 = Arc::clone(&rc);
                    s.spawn(move || {
                        let w = req(1 + i as u64, 4, vec![7, 8]);
                        match rc2.begin(&w, Duration::from_secs(30)) {
                            Begin::Leader(g) => {
                                g.complete(&w, &Ok(resp(&w, 2)));
                                "led"
                            }
                            Begin::Coalesced(..) | Begin::Hit(_) => "shared",
                            Begin::Fallback => "fallback",
                        }
                    })
                })
                .collect();
            // all waiters parked: map + guard + probe + N waiters
            for _ in 0..5_000 {
                if Arc::strong_count(&probe) >= 3 + WAITERS {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            assert!(Arc::strong_count(&probe) >= 3 + WAITERS, "waiters never enqueued");
            // lint: supervisor — test-local stand-in for the pipeline
            // supervisor: the panic must unwind the guard, not the test
            let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                let _doomed = guard;
                // lint: allow(panic) simulated leader crash, caught above
                panic!("chaos: leader panic mid-computation");
            }));
            assert!(unwound.is_err());
            let outcomes: Vec<&str> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            assert_eq!(
                outcomes.iter().filter(|&&o| o == "led").count(),
                1,
                "exactly one waiter takes the lead: {outcomes:?}"
            );
            assert_eq!(
                outcomes.iter().filter(|&&o| o == "shared").count(),
                WAITERS - 1,
                "the rest share the new leader's result: {outcomes:?}"
            );
        });
    }

    /// Satellite: an upstream user-feature update evicts that user's
    /// cached results ahead of the TTL — a post-update duplicate misses
    /// and recomputes instead of serving pre-update scores.
    #[test]
    fn user_feature_update_evicts_cached_results() {
        let rc = cache(true);
        let r = req(0, 7, vec![10, 20]);
        let Begin::Leader(guard) = rc.begin(&r, Duration::from_secs(1)) else {
            panic!("must lead");
        };
        guard.complete(&r, &Ok(resp(&r, 2)));
        assert!(matches!(rc.begin(&req(1, 7, vec![10, 20]), Duration::from_secs(1)), Begin::Hit(_)));

        // invalidating an unrelated user leaves the entry live
        assert_eq!(rc.invalidate_user(8), 0);
        assert!(matches!(rc.begin(&req(2, 7, vec![10, 20]), Duration::from_secs(1)), Begin::Hit(_)));

        // the user's own update evicts: next duplicate must recompute
        assert_eq!(rc.invalidate_user(7), 1);
        assert!(
            matches!(rc.begin(&req(3, 7, vec![10, 20]), Duration::from_secs(1)), Begin::Leader(_)),
            "post-update duplicate must miss and lead a fresh computation"
        );
        // idempotent: the index entry was consumed
        assert_eq!(rc.invalidate_user(7), 0);
    }

    /// Regression (invalidate vs in-flight leader): `invalidate_user`
    /// landing while a single-flight leader is mid-computation used to
    /// be undone by the leader's subsequent insert — the next duplicate
    /// served scores from pre-update features. The epoch captured at
    /// `begin` and re-checked at publication closes the window.
    #[test]
    fn invalidation_racing_a_leader_is_not_resurrected_by_its_insert() {
        let rc = cache(true);
        let r = req(0, 7, vec![10, 20]);
        let Begin::Leader(guard) = rc.begin(&r, Duration::from_secs(1)) else {
            panic!("must lead");
        };
        // the feature update lands while the leader is still computing
        assert_eq!(rc.invalidate_user(7), 0, "nothing published yet");
        // ...and the leader publishes afterwards
        guard.complete(&r, &Ok(resp(&r, 2)));
        assert!(
            matches!(rc.begin(&req(1, 7, vec![10, 20]), Duration::from_secs(1)), Begin::Leader(_)),
            "stale flight must not resurrect the entry: duplicate must recompute"
        );
        // a flight that begins after the invalidation publishes normally
        let r2 = req(2, 7, vec![10, 20]);
        let Begin::Leader(g2) = rc.begin(&r2, Duration::from_secs(1)) else {
            panic!("must lead");
        };
        g2.complete(&r2, &Ok(resp(&r2, 2)));
        let b3 = rc.begin(&req(3, 7, vec![10, 20]), Duration::from_secs(1));
        assert!(matches!(b3, Begin::Hit(_)));
    }

    #[test]
    fn misses_on_different_shards_never_contend() {
        // Regression for the sharded waiter table: holding one shard's
        // mutex (as a long miss registration would) must not block a
        // miss whose key hashes to a different shard. With the old
        // single global mutex this test deadlocks until the timeout.
        let rc = Arc::new(cache(true));
        let a = req(0, 1, vec![11, 12]);
        let (ka, _, _) = rc.key_of(&a);
        let shard_of = |k: u64| (k as usize) & (FLIGHT_SHARDS - 1);
        let b = (2..200)
            .map(|u| req(1, u, vec![13, 14]))
            .find(|r| shard_of(rc.key_of(r).0) != shard_of(ka))
            .expect("some user must hash to a different shard");

        let _hold = rc.flight_shard(ka).lock().unwrap();
        let rc2 = Arc::clone(&rc);
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let led = matches!(rc2.begin(&b, Duration::from_secs(1)), Begin::Leader(_));
            let _ = tx.send(led);
        });
        let led = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("different-shard miss blocked behind a foreign shard lock");
        assert!(led, "first sight of the other key must lead");
    }

    #[test]
    fn same_key_still_single_flights_across_shard_split() {
        // sharding must not weaken the per-key invariant: a second miss
        // on the SAME key while a leader is in flight coalesces (waits),
        // it does not become a second leader
        let rc = Arc::new(cache(true));
        let r = req(0, 9, vec![5, 6]);
        let guard = match rc.begin(&r, Duration::from_secs(1)) {
            Begin::Leader(g) => g,
            _ => panic!("must lead"),
        };
        let rc2 = Arc::clone(&rc);
        let dup = req(1, 9, vec![5, 6]);
        let waiter = std::thread::spawn(move || {
            // Coalesced if it parks behind the flight, Hit if it arrives
            // after publication — either way it must NOT lead again
            matches!(
                rc2.begin(&dup, Duration::from_secs(10)),
                Begin::Coalesced(..) | Begin::Hit(_)
            )
        });
        // give the waiter time to park, then publish
        std::thread::sleep(Duration::from_millis(30));
        guard.complete(&r, &Ok(resp(&r, 2)));
        assert!(waiter.join().unwrap(), "duplicate must share the leader's result");
        let (hits, misses, coalesced) = rc.counts();
        assert_eq!(misses, 1, "exactly one leader");
        assert_eq!(hits + coalesced, 1, "the duplicate was served without leading");
    }

    #[test]
    fn waiter_times_out_against_stuck_leader() {
        let rc = cache(true);
        let r = req(0, 5, vec![9]);
        let _guard = match rc.begin(&r, Duration::from_secs(1)) {
            Begin::Leader(g) => g,
            _ => panic!("must lead"),
        };
        // same key, tiny budget: the leader never completes in time
        let t0 = Instant::now();
        let w = req(1, 5, vec![9]);
        assert!(matches!(rc.begin(&w, Duration::from_millis(20)), Begin::Fallback));
        assert!(t0.elapsed() < Duration::from_secs(1), "timed wait overshot");
    }
}
