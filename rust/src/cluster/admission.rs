//! Deadline-aware admission control.
//!
//! Every request enters the router with a deadline budget (default: the
//! paper's < 50 ms envelope from `ServerConfig::deadline_ms`). Before
//! dispatch, the router estimates the request's sojourn time on the
//! chosen replica from that replica's rolling latency histogram and its
//! current congestion; a request that cannot make its SLA is re-routed
//! to the cheapest healthy alternative or shed at the front door —
//! paying nothing for work that would arrive dead (`shed_total`).
//! Completions that still blew the budget are counted in
//! `sla_miss_total` (the estimator's miss rate is its calibration
//! signal).

use std::sync::atomic::{AtomicU64, Ordering};

use super::replica::Replica;

/// Outcome of the pre-dispatch deadline check.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    Admit,
    /// The replica's estimated sojourn exceeds the request's budget.
    Overbudget { estimate_us: u64 },
}

/// Shared admission counters + the sojourn estimator.
#[derive(Default)]
pub struct Admission {
    /// Requests refused at the front door (could not make the SLA
    /// anywhere).
    pub shed_total: AtomicU64,
    /// Completed requests whose end-to-end latency still exceeded their
    /// budget.
    pub sla_miss_total: AtomicU64,
    /// Requests moved off their policy-chosen replica (deadline or
    /// failover re-routes).
    pub rerouted_total: AtomicU64,
}

impl Admission {
    pub fn new() -> Self {
        Admission::default()
    }

    /// Expected sojourn time (µs) for a new request on `replica`: the
    /// rolling p99 service time (tail-conservative), plus one mean
    /// service time per "wave" of in-flight work ahead of it relative to
    /// the replica's parallel slots. Partial waves round *up*: 3 of 4
    /// slots busy is still a wave the arrival may wait behind — flooring
    /// it estimated zero queueing right up to the saturation point. The
    /// divisor is guarded so a zero-slot replica cannot panic. A cold
    /// replica (empty histogram) estimates 0 — optimistic admission
    /// until the histogram warms, which is what lets a freshly
    /// re-admitted replica be probed at all.
    pub fn estimate_us(replica: &Replica) -> u64 {
        Self::estimate_us_with(replica, 1_000)
    }

    /// [`Admission::estimate_us`] with an explicit p99-vs-mean blend
    /// (per-mille). The effective tail is
    /// `mean + (p99 - mean) * blend / 1000`: 0 trusts the mean, 1000
    /// reproduces the plain p99 estimate, and values above 1000
    /// *extrapolate past* the rolling-window p99 — the overload
    /// controller's lever when observed SLA misses say the histogram is
    /// lagging a latency regime shift (the window only knows what the
    /// replica measured; a shift that lands outside it — upstream
    /// stalls, store faults — never shows up in p99 at all).
    pub fn estimate_us_with(replica: &Replica, blend_permille: u64) -> u64 {
        let mean = replica.mean_us();
        let p99 = replica.p99_us();
        let tail = if p99 > mean {
            mean + (p99 - mean).saturating_mul(blend_permille) / 1_000
        } else if p99 > 0 {
            p99
        } else {
            mean
        };
        let waves = replica.in_flight().div_ceil(replica.slots().max(1)) as u64;
        tail + mean.saturating_mul(waves)
    }

    /// Pre-dispatch deadline check for `replica` against `budget_us`.
    pub fn check(&self, replica: &Replica, budget_us: u64) -> Verdict {
        self.check_with(replica, budget_us, 1_000)
    }

    /// [`Admission::check`] under a feedback-adjusted tail blend (see
    /// [`Admission::estimate_us_with`]).
    pub fn check_with(&self, replica: &Replica, budget_us: u64, blend_permille: u64) -> Verdict {
        let estimate_us = Self::estimate_us_with(replica, blend_permille);
        if estimate_us <= budget_us {
            Verdict::Admit
        } else {
            Verdict::Overbudget { estimate_us }
        }
    }

    pub fn note_shed(&self) {
        self.shed_total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_reroute(&self) {
        self.rerouted_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a completion; counts an SLA miss if the budget was blown.
    pub fn note_completion(&self, elapsed_us: u64, budget_us: u64) {
        if elapsed_us > budget_us {
            self.sla_miss_total.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn shed(&self) -> u64 {
        self.shed_total.load(Ordering::Relaxed)
    }

    pub fn sla_misses(&self) -> u64 {
        self.sla_miss_total.load(Ordering::Relaxed)
    }

    pub fn rerouted(&self) -> u64 {
        self.rerouted_total.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::replica::ReplicaBackend;
    use crate::error::Result;
    use crate::server::pipeline::Response;
    use crate::workload::Request;
    use std::sync::Arc;

    struct NullBackend;

    impl ReplicaBackend for NullBackend {
        fn serve(&self, req: &Request) -> Result<Response> {
            Ok(Response {
                request_id: req.request_id,
                scores: Vec::new(),
                m: req.m(),
                overall_us: 0,
                compute_us: 0,
                feature_us: 0,
                queue_us: 0,
                handoff_us: 0,
                quality: crate::chaos::ServeQuality::Full,
            })
        }
    }

    fn replica(slots: usize) -> Replica {
        Replica::new(0, Arc::new(NullBackend), slots, 3, 1_000)
    }

    #[test]
    fn cold_replica_admits_optimistically() {
        let r = replica(4);
        let a = Admission::new();
        assert_eq!(a.check(&r, 1), Verdict::Admit);
    }

    #[test]
    fn warm_replica_estimate_uses_tail() {
        let r = replica(4);
        // seed the rolling window: ~2 ms service times
        for _ in 0..100 {
            r.record_latency(2_000, 1);
        }
        let est = Admission::estimate_us(&r);
        assert!(est >= 1_900, "estimate {est} should reflect the 2 ms tail");
        let a = Admission::new();
        assert_eq!(a.check(&r, 50_000), Verdict::Admit);
        match a.check(&r, 1_000) {
            Verdict::Overbudget { estimate_us } => assert!(estimate_us >= 1_900),
            v => panic!("expected Overbudget, got {v:?}"),
        }
    }

    /// Backend that blocks every serve call until the gate opens —
    /// pins `in_flight` at a known value while the test reads estimates.
    struct GateBackend {
        gate: std::sync::Arc<(std::sync::Mutex<bool>, std::sync::Condvar)>,
    }

    impl ReplicaBackend for GateBackend {
        fn serve(&self, req: &Request) -> Result<Response> {
            let (lock, cv) = &*self.gate;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            Ok(Response {
                request_id: req.request_id,
                scores: Vec::new(),
                m: req.m(),
                overall_us: 0,
                compute_us: 0,
                feature_us: 0,
                queue_us: 0,
                handoff_us: 0,
                quality: crate::chaos::ServeQuality::Full,
            })
        }
    }

    /// Regression: 3 of 4 slots busy used to floor to zero queueing
    /// waves, estimating a saturating replica as idle.
    #[test]
    fn partial_wave_rounds_up() {
        let gate = std::sync::Arc::new((std::sync::Mutex::new(false), std::sync::Condvar::new()));
        let backend = Arc::new(GateBackend { gate: std::sync::Arc::clone(&gate) });
        let r = Replica::new(0, backend, 4, 3, 1_000);
        for _ in 0..100 {
            r.record_latency(2_000, 1);
        }
        let (busy, est, floor) = std::thread::scope(|s| {
            for i in 0..3u64 {
                let r = &r;
                s.spawn(move || {
                    let req = Request {
                        request_id: i,
                        user_id: i,
                        history: vec![],
                        candidates: vec![1],
                        ..Default::default()
                    };
                    r.serve_tracked(&req).unwrap();
                });
            }
            // wait for all three to be in flight
            for _ in 0..2_000 {
                if r.in_flight() == 3 {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            let busy = r.in_flight();
            let est = Admission::estimate_us(&r);
            let floor = r.p99_us() + r.mean_us();
            // always release the gate before asserting, or a failure
            // would hang the blocked serve threads instead of failing
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
            (busy, est, floor)
        });
        assert_eq!(busy, 3, "servers never blocked on the gate");
        assert!(
            est >= floor,
            "3/4 busy is one partial wave: estimate {est} µs < tail+mean {floor} µs"
        );
    }

    /// Invariant (two-layer guard): `Replica::new` clamps `slots` to
    /// ≥ 1, and `estimate_us` guards its divisor independently — so a
    /// slots-0 configuration can never reach a division by zero even if
    /// one of the two layers is refactored away.
    #[test]
    fn zero_slots_guarded() {
        let r = Replica::new(0, Arc::new(NullBackend), 0, 3, 1_000);
        r.record_latency(1_000, 1);
        let _ = Admission::estimate_us(&r); // must not divide by zero
        assert!(r.slots() >= 1);
    }

    /// The long-open over-admission gap, demonstrated: a latency regime
    /// shift the replica histogram cannot see (misses accrue end-to-end
    /// while on-replica service times look unchanged) leaves the plain
    /// estimator admitting into a blown SLA. The feedback blend is the
    /// fix — extrapolating the tail past the stale p99 flips the verdict
    /// without waiting for the window to (never) catch up.
    #[test]
    fn regime_shift_over_admits_without_feedback() {
        let r = replica(4);
        // warm window: mostly 2 ms with a thin 6 ms tail
        for i in 0..1_000 {
            r.record_latency(if i % 100 == 0 { 6_000 } else { 2_000 }, 1);
        }
        let a = Admission::new();
        // regime shift: end-to-end completions now blow the 10 ms budget
        // (upstream stall — invisible to the replica's own histogram)
        for _ in 0..20 {
            a.note_completion(30_000, 10_000);
        }
        assert_eq!(a.sla_misses(), 20, "the sensor sees the shift immediately");
        // pre-fix behavior (blend pinned at 1000 ≡ plain p99): still admits
        assert_eq!(
            a.check(&r, 10_000),
            Verdict::Admit,
            "without feedback the estimator keeps over-admitting"
        );
        // with feedback escalating the blend, the same replica is refused
        match a.check_with(&r, 10_000, 3_000) {
            Verdict::Overbudget { estimate_us } => {
                assert!(estimate_us > 10_000, "extrapolated tail {estimate_us}")
            }
            v => panic!("expected Overbudget under escalated blend, got {v:?}"),
        }
    }

    #[test]
    fn blend_scales_the_tail_both_ways() {
        let r = replica(4);
        for i in 0..1_000 {
            r.record_latency(if i % 100 == 0 { 6_000 } else { 2_000 }, 1);
        }
        let plain = Admission::estimate_us(&r);
        assert_eq!(plain, Admission::estimate_us_with(&r, 1_000), "1000 ≡ plain p99");
        let trusting = Admission::estimate_us_with(&r, 0);
        assert_eq!(trusting, r.mean_us(), "0 collapses to the mean");
        let paranoid = Admission::estimate_us_with(&r, 3_000);
        assert!(paranoid > plain, "{paranoid} vs {plain}");
        // a cold replica stays optimistic at every blend
        let cold = replica(4);
        assert_eq!(Admission::estimate_us_with(&cold, 4_000), 0);
    }

    #[test]
    fn completion_counts_sla_misses() {
        let a = Admission::new();
        a.note_completion(10_000, 50_000);
        a.note_completion(60_000, 50_000);
        assert_eq!(a.sla_misses(), 1);
        a.note_shed();
        a.note_reroute();
        assert_eq!(a.shed(), 1);
        assert_eq!(a.rerouted(), 1);
    }
}
