//! Routing policies for the cluster tier.
//!
//! Three placement strategies, one flag apart (the bench compares them
//! under the paper's non-uniform candidate mix):
//!
//! * **round-robin** — the uniform baseline; spreads every user over
//!   every replica, so per-replica feature caches stay cold.
//! * **least-loaded** — power-of-two-choices over in-flight counts;
//!   near-optimal load balance at O(1) per decision (Mitzenmacher).
//! * **cache-affinity** — consistent hashing on `user_id` over a
//!   virtual-node ring, so a returning user lands on the replica whose
//!   PDA feature cache already holds their features. Replica ejection
//!   moves only the keys that mapped to the ejected replica (minimal
//!   disruption), which is the property that keeps the other replicas'
//!   caches warm through a failure.

use crate::error::{Error, Result};
use crate::util::rng::splitmix64;

/// Cluster request-placement policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    /// Power-of-two-choices on in-flight load.
    LeastLoaded,
    /// Consistent hashing on `user_id` (feature-cache affinity).
    CacheAffinity,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "rr" | "round-robin" => Ok(RoutePolicy::RoundRobin),
            "p2c" | "least-loaded" => Ok(RoutePolicy::LeastLoaded),
            "affinity" | "cache-affinity" => Ok(RoutePolicy::CacheAffinity),
            o => Err(Error::Config(format!(
                "unknown routing policy '{o}' (rr | p2c | affinity)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::CacheAffinity => "cache-affinity",
        }
    }

    pub fn all() -> [RoutePolicy; 3] {
        [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded, RoutePolicy::CacheAffinity]
    }
}

/// Mix two values into one well-distributed hash point.
fn hash2(a: u64, b: u64) -> u64 {
    let mut s = a.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ b;
    splitmix64(&mut s)
}

/// Consistent-hash ring with virtual nodes.
///
/// Each replica contributes `vnodes` points; a key routes to the owner
/// of the first point clockwise from its hash. Determinism: the ring is
/// a pure function of (replica count, vnodes), so every router instance
/// with the same topology places a user identically.
pub struct HashRing {
    /// (point hash, replica id), sorted by hash.
    points: Vec<(u64, usize)>,
    n_replicas: usize,
}

impl HashRing {
    pub fn new(n_replicas: usize, vnodes: usize) -> Self {
        let n_replicas = n_replicas.max(1);
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(n_replicas * vnodes);
        for r in 0..n_replicas {
            for v in 0..vnodes {
                points.push((hash2(r as u64 + 1, v as u64), r));
            }
        }
        points.sort_unstable();
        HashRing { points, n_replicas }
    }

    /// Index of the first ring point clockwise from the key's hash.
    fn start_index(&self, key: u64) -> usize {
        let h = {
            let mut s = key ^ 0xC0FF_EE00_D15E_A5E5;
            splitmix64(&mut s)
        };
        match self.points.binary_search_by(|&(p, _)| p.cmp(&h)) {
            Ok(i) => i,
            Err(i) if i == self.points.len() => 0,
            Err(i) => i,
        }
    }

    /// The key's primary replica.
    pub fn route(&self, key: u64) -> usize {
        self.points[self.start_index(key)].1
    }

    /// Walk clockwise from the key's position to the first replica that
    /// passes `healthy`. Keys whose primary is healthy are unaffected by
    /// other replicas' health (minimal disruption).
    pub fn route_filtered<F: Fn(usize) -> bool>(&self, key: u64, healthy: F) -> Option<usize> {
        let start = self.start_index(key);
        let mut ruled_out = 0usize;
        // allocated only once a replica fails the health check — the
        // healthy-primary common case returns on the first point
        let mut seen: Option<Vec<bool>> = None;
        for off in 0..self.points.len() {
            let (_, r) = self.points[(start + off) % self.points.len()];
            if healthy(r) {
                return Some(r);
            }
            let seen = seen.get_or_insert_with(|| vec![false; self.n_replicas]);
            if !seen[r] {
                seen[r] = true;
                ruled_out += 1;
                if ruled_out == self.n_replicas {
                    return None;
                }
            }
        }
        None
    }

    pub fn n_replicas(&self) -> usize {
        self.n_replicas
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrip() {
        for p in RoutePolicy::all() {
            assert_eq!(RoutePolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(RoutePolicy::parse("p2c").unwrap(), RoutePolicy::LeastLoaded);
        assert_eq!(RoutePolicy::parse("affinity").unwrap(), RoutePolicy::CacheAffinity);
        assert!(RoutePolicy::parse("bogus").is_err());
    }

    #[test]
    fn ring_is_deterministic() {
        let a = HashRing::new(5, 64);
        let b = HashRing::new(5, 64);
        for key in 0..2_000u64 {
            assert_eq!(a.route(key), b.route(key));
        }
    }

    #[test]
    fn ring_covers_all_replicas_roughly_evenly() {
        let ring = HashRing::new(4, 64);
        let mut counts = [0usize; 4];
        for key in 0..40_000u64 {
            counts[ring.route(key)] += 1;
        }
        for (r, &c) in counts.iter().enumerate() {
            // each replica owns ~25%; virtual nodes keep the spread tight
            assert!((6_000..14_000).contains(&c), "replica {r} got {c}");
        }
    }

    #[test]
    fn filtered_route_moves_only_dead_replicas_keys() {
        let ring = HashRing::new(4, 64);
        let dead = 2usize;
        for key in 0..10_000u64 {
            let primary = ring.route(key);
            let routed = ring.route_filtered(key, |r| r != dead).unwrap();
            if primary != dead {
                assert_eq!(routed, primary, "healthy-primary key {key} moved");
            } else {
                assert_ne!(routed, dead);
            }
        }
    }

    #[test]
    fn filtered_route_none_when_all_dead() {
        let ring = HashRing::new(3, 16);
        assert_eq!(ring.route_filtered(7, |_| false), None);
    }

    #[test]
    fn single_replica_ring() {
        let ring = HashRing::new(1, 8);
        for key in 0..100u64 {
            assert_eq!(ring.route(key), 0);
        }
    }
}
