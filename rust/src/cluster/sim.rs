//! Simulated replica backend — the artifact-free stand-in for a full
//! `ServingStack` that lets the cluster tier be benched and tested on a
//! bare checkout (no HLO artifacts, no PJRT).
//!
//! The model is deliberately simple but keeps the two properties the
//! router's policies are sensitive to:
//!
//! * a **per-replica user-feature cache** (the PDA cache analogue keyed
//!   on `user_id`): a miss costs a simulated remote feature fetch, so
//!   cache-affinity routing shows up as both a hit-rate and a latency
//!   win;
//! * **limited service parallelism** (`slots`): requests beyond the slot
//!   count queue on a condvar, so load creates real queueing latency and
//!   the deadline admission controller has a real signal to act on.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cache::{Lookup, ShardedCache};
use crate::chaos::{ChaosSlot, FaultPlan};
use crate::error::{Error, Result};
use crate::server::pipeline::Response;
use crate::util::timeutil::precise_wait;
use crate::workload::Request;

use super::replica::ReplicaBackend;

/// Cost model for one simulated replica.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Fixed per-request overhead (µs).
    pub base_us: u64,
    /// Scoring cost per user-item pair (ns) — ties service time to M,
    /// so the non-uniform candidate mix shapes the latency distribution.
    pub per_pair_ns: u64,
    /// Remote feature fetch penalty on a user-cache miss (µs).
    pub miss_penalty_us: u64,
    /// User-feature cache capacity (entries).
    pub cache_capacity: usize,
    /// Parallel service slots; in-flight work beyond this queues.
    pub slots: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            base_us: 80,
            per_pair_ns: 400,
            miss_penalty_us: 250,
            cache_capacity: 8_192,
            slots: 4,
        }
    }
}

/// Counting semaphore (mutex + condvar; no external deps).
struct Slots {
    free: Mutex<usize>,
    available: Condvar,
    waiting: AtomicUsize,
}

impl Slots {
    fn new(n: usize) -> Self {
        Slots {
            free: Mutex::new(n.max(1)),
            available: Condvar::new(),
            waiting: AtomicUsize::new(0),
        }
    }

    fn acquire(&self) {
        self.waiting.fetch_add(1, Ordering::Relaxed);
        let mut free = self.free.lock().unwrap_or_else(|e| e.into_inner());
        while *free == 0 {
            free = self.available.wait(free).unwrap_or_else(|e| e.into_inner());
        }
        *free -= 1;
        self.waiting.fetch_sub(1, Ordering::Relaxed);
    }

    fn release(&self) {
        *self.free.lock().unwrap_or_else(|e| e.into_inner()) += 1;
        self.available.notify_one();
    }
}

/// The simulated replica.
pub struct SimReplica {
    cfg: SimConfig,
    /// Per-replica PDA-style feature cache keyed on `user_id` — what
    /// cache-affinity routing is designed to keep warm.
    cache: ShardedCache<u64>,
    slots: Slots,
    fail_next: AtomicU32,
    served_total: AtomicU64,
    /// Fault-injection point: brownout (service-time multiplier) and
    /// hard-crash windows keyed by this replica's cluster index.
    chaos: ChaosSlot,
    chaos_id: AtomicUsize,
}

impl SimReplica {
    pub fn new(cfg: SimConfig) -> Self {
        let cache = ShardedCache::new(cfg.cache_capacity, 8, Duration::from_secs(3_600));
        let slots = Slots::new(cfg.slots);
        SimReplica {
            cfg,
            cache,
            slots,
            fail_next: AtomicU32::new(0),
            served_total: AtomicU64::new(0),
            chaos: ChaosSlot::new(),
            chaos_id: AtomicUsize::new(0),
        }
    }

    /// Arm the replica's fault-injection point. `id` is the replica's
    /// cluster index — what `brownout:replica=N` / `crash:replica=N`
    /// clauses key on.
    pub fn arm_chaos(&self, id: usize, plan: Arc<FaultPlan>) {
        self.chaos_id.store(id, Ordering::Relaxed);
        self.chaos.arm(plan);
    }

    /// Make the next `n` serve calls fail (health/ejection tests).
    pub fn fail_next(&self, n: u32) {
        self.fail_next.store(n, Ordering::Relaxed);
    }

    pub fn served_total(&self) -> u64 {
        self.served_total.load(Ordering::Relaxed)
    }

    /// Requests currently blocked waiting for a service slot.
    pub fn queue_depth(&self) -> usize {
        self.slots.waiting.load(Ordering::Relaxed)
    }
}

impl ReplicaBackend for SimReplica {
    fn serve(&self, req: &Request) -> Result<Response> {
        if self.fail_next.load(Ordering::Relaxed) > 0
            && self
                .fail_next
                .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
                .map(|prev| prev > 0)
                .unwrap_or(false)
        {
            return Err(Error::Internal("sim: injected replica failure".into()));
        }
        if let Some(plan) = self.chaos.get() {
            if plan.crashed(self.chaos_id.load(Ordering::Relaxed)) {
                return Err(Error::Internal("chaos: replica crash".into()));
            }
        }

        let t0 = Instant::now();
        self.slots.acquire();
        let queue_us = t0.elapsed().as_micros() as u64;

        let miss = matches!(self.cache.get(req.user_id), Lookup::Miss);
        if miss {
            self.cache.insert(req.user_id, req.user_id);
        }
        let compute_us = self.cfg.base_us + self.cfg.per_pair_ns * req.m() as u64 / 1_000;
        let feature_us = if miss { self.cfg.miss_penalty_us } else { 0 };
        // a browned-out replica still answers, just `x` times slower —
        // the router's hedging exists to route around exactly this
        let brownout_x = self
            .chaos
            .get()
            .and_then(|p| p.brownout_x(self.chaos_id.load(Ordering::Relaxed)))
            .unwrap_or(1) as u64;
        precise_wait(Duration::from_micros((compute_us + feature_us) * brownout_x));
        self.slots.release();

        self.served_total.fetch_add(1, Ordering::Relaxed);
        Ok(Response {
            request_id: req.request_id,
            scores: Vec::new(),
            m: req.m(),
            overall_us: t0.elapsed().as_micros() as u64,
            compute_us,
            feature_us,
            queue_us,
            handoff_us: 0,
            quality: crate::chaos::ServeQuality::Full,
        })
    }

    fn cache_counts(&self) -> (u64, u64) {
        let (hits, stale, misses, _, _) = self.cache.stats.snapshot();
        (hits + stale, misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64, user: u64, m: usize) -> Request {
        Request {
            request_id: id,
            user_id: user,
            history: vec![],
            candidates: (0..m as u64).collect(),
            ..Default::default()
        }
    }

    fn fast_cfg() -> SimConfig {
        SimConfig { base_us: 0, per_pair_ns: 0, miss_penalty_us: 0, ..SimConfig::default() }
    }

    #[test]
    fn repeat_user_hits_cache() {
        let r = SimReplica::new(fast_cfg());
        r.serve(&req(0, 42, 4)).unwrap();
        r.serve(&req(1, 42, 4)).unwrap();
        r.serve(&req(2, 43, 4)).unwrap();
        let (hits, misses) = r.cache_counts();
        assert_eq!(hits, 1, "second visit of user 42");
        assert_eq!(misses, 2, "first visits of users 42 and 43");
        assert_eq!(r.served_total(), 3);
    }

    #[test]
    fn service_time_scales_with_m_and_misses() {
        let cfg = SimConfig {
            base_us: 10,
            per_pair_ns: 1_000, // 1 µs per pair
            miss_penalty_us: 100,
            ..SimConfig::default()
        };
        let r = SimReplica::new(cfg);
        let cold = r.serve(&req(0, 7, 32)).unwrap();
        assert_eq!(cold.compute_us, 10 + 32);
        assert_eq!(cold.feature_us, 100);
        let warm = r.serve(&req(1, 7, 32)).unwrap();
        assert_eq!(warm.feature_us, 0, "warm user pays no fetch penalty");
    }

    #[test]
    fn injected_failures_then_recovery() {
        let r = SimReplica::new(fast_cfg());
        r.fail_next(2);
        assert!(r.serve(&req(0, 1, 1)).is_err());
        assert!(r.serve(&req(1, 1, 1)).is_err());
        assert!(r.serve(&req(2, 1, 1)).is_ok());
    }

    #[test]
    fn chaos_brownout_multiplies_service_time() {
        let cfg = SimConfig { base_us: 500, per_pair_ns: 0, miss_penalty_us: 0, ..SimConfig::default() };
        let healthy = SimReplica::new(cfg.clone());
        let t0 = Instant::now();
        healthy.serve(&req(0, 1, 1)).unwrap();
        let base = t0.elapsed();

        let browned = SimReplica::new(cfg);
        browned.arm_chaos(2, Arc::new(crate::chaos::FaultPlan::parse("brownout:replica=2,x=8", 0).unwrap()));
        let t1 = Instant::now();
        browned.serve(&req(1, 1, 1)).unwrap();
        let slow = t1.elapsed();
        assert!(slow >= base * 3, "brownout x=8: healthy {base:?} vs browned {slow:?}");
    }

    #[test]
    fn chaos_crash_window_fails_then_recovers() {
        let r = SimReplica::new(fast_cfg());
        r.arm_chaos(0, Arc::new(crate::chaos::FaultPlan::parse("crash:replica=0,after=1,down=2", 0).unwrap()));
        assert!(r.serve(&req(0, 1, 1)).is_ok(), "before the window");
        assert!(r.serve(&req(1, 1, 1)).is_err());
        assert!(r.serve(&req(2, 1, 1)).is_err());
        assert!(r.serve(&req(3, 1, 1)).is_ok(), "window closed");
    }

    #[test]
    fn slots_serialize_service() {
        // 1 slot, 2 ms service: two concurrent requests cannot overlap,
        // so the second observes ≥ ~2 ms of queueing.
        let cfg = SimConfig { base_us: 2_000, per_pair_ns: 0, miss_penalty_us: 0, slots: 1, ..SimConfig::default() };
        let r = Arc::new(SimReplica::new(cfg));
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for i in 0..2 {
                let r = Arc::clone(&r);
                s.spawn(move || r.serve(&req(i, i, 1)).unwrap());
            }
        });
        assert!(
            t0.elapsed() >= Duration::from_micros(3_500),
            "two 2 ms requests through 1 slot took {:?}",
            t0.elapsed()
        );
    }
}
