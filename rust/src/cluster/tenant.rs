//! Tenant registry: per-tenant traffic weights and SLA budgets.
//!
//! A *tenant* is a scenario / product surface sharing the cluster — the
//! paper's deployments serve many recommendation surfaces with distinct
//! latency envelopes off one fleet. The registry is parsed from the
//! `--tenants` clause grammar (same shape as `--chaos` / `--storm`):
//!
//! ```text
//! t0:w=3,sla_ms=50,t1:w=1,sla_ms=30
//! ```
//!
//! `w` is the tenant's relative traffic weight (the weighted-fair share
//! the overload controller defends); `sla_ms` overrides the cluster's
//! default deadline for that tenant's requests. Unlisted tenants keep
//! weight 1 and the default deadline, so a bare cluster behaves exactly
//! as before tenancy existed.

use crate::error::{Error, Result};
use crate::workload::{TenantId, MAX_TENANTS};

/// Per-tenant configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TenantSpec {
    /// Relative traffic weight (weighted-fair share).
    pub weight: u64,
    /// Per-tenant deadline override (ms); None = cluster default.
    pub sla_ms: Option<u64>,
}

impl Default for TenantSpec {
    fn default() -> Self {
        TenantSpec { weight: 1, sla_ms: None }
    }
}

/// The full registry, one slot per possible tenant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantSet {
    pub specs: [TenantSpec; MAX_TENANTS],
}

impl Default for TenantSet {
    fn default() -> Self {
        TenantSet { specs: [TenantSpec::default(); MAX_TENANTS] }
    }
}

impl TenantSet {
    /// Parse the clause grammar (see module docs). Clause names are
    /// `t0`..`t7`; params are `w` (weight ≥ 1) and `sla_ms`.
    pub fn parse(spec: &str) -> Result<TenantSet> {
        let mut out = TenantSet::default();
        let mut current: Option<usize> = None;
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let (clause, param) = match tok.split_once(':') {
                Some((name, first)) => (Some(name), first),
                None => (None, tok),
            };
            if let Some(name) = clause {
                let idx: usize = name
                    .strip_prefix('t')
                    .and_then(|d| d.parse().ok())
                    .filter(|&i| i < MAX_TENANTS)
                    .ok_or_else(|| {
                        Error::Config(format!(
                            "tenant clause '{name}' is not t0..t{}",
                            MAX_TENANTS - 1
                        ))
                    })?;
                current = Some(idx);
            }
            let Some(idx) = current else {
                return Err(Error::Config(format!(
                    "tenant param '{tok}' precedes any t<N> clause"
                )));
            };
            let (k, v) = match param.split_once('=') {
                Some((k, v)) if !k.is_empty() && !v.is_empty() => (k.trim(), v.trim()),
                _ => return Err(Error::Config(format!("tenant token '{param}' is not key=value"))),
            };
            let n: u64 = v
                .parse()
                .map_err(|_| Error::Config(format!("tenant param {k}='{v}' is not an integer")))?;
            match k {
                "w" => {
                    if n == 0 {
                        return Err(Error::Config("tenant weight must be >= 1".into()));
                    }
                    out.specs[idx].weight = n;
                }
                "sla_ms" => out.specs[idx].sla_ms = Some(n),
                o => return Err(Error::Config(format!("unknown tenant param '{o}'"))),
            }
        }
        Ok(out)
    }

    /// Deadline budget (µs) for `tenant`, falling back to `default_us`.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn budget_us(&self, tenant: TenantId, default_us: u64) -> u64 {
        match self.specs[tenant.index()].sla_ms {
            Some(ms) => ms.saturating_mul(1_000),
            None => default_us,
        }
    }

    /// Relative weight for tenant slot `idx`.
    // lint: no_alloc — per-request hot path, must stay allocation-free
    pub fn weight(&self, idx: usize) -> u64 {
        self.specs[idx.min(MAX_TENANTS - 1)].weight
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_neutral() {
        let set = TenantSet::default();
        for i in 0..MAX_TENANTS {
            assert_eq!(set.weight(i), 1);
        }
        assert_eq!(set.budget_us(TenantId(3), 50_000), 50_000);
    }

    #[test]
    fn parse_weights_and_slas() {
        let set = TenantSet::parse("t0:w=3,sla_ms=50,t1:w=1,sla_ms=30").unwrap();
        assert_eq!(set.weight(0), 3);
        assert_eq!(set.weight(1), 1);
        assert_eq!(set.budget_us(TenantId(0), 10_000), 50_000);
        assert_eq!(set.budget_us(TenantId(1), 10_000), 30_000);
        // unlisted tenants keep the defaults
        assert_eq!(set.weight(2), 1);
        assert_eq!(set.budget_us(TenantId(2), 10_000), 10_000);
    }

    #[test]
    fn parse_rejects_junk() {
        assert!(TenantSet::parse("t9:w=1").is_err(), "tenant out of range");
        assert!(TenantSet::parse("w=1").is_err(), "param before clause");
        assert!(TenantSet::parse("t0:w=0").is_err(), "zero weight");
        assert!(TenantSet::parse("t0:budget=5").is_err(), "unknown param");
        assert!(TenantSet::parse("t0:w").is_err(), "not key=value");
    }

    #[test]
    fn out_of_range_lookup_folds() {
        let set = TenantSet::parse("t7:w=5").unwrap();
        assert_eq!(set.weight(200), 5, "folds into the last slot");
    }
}
