//! Request queue + admission control feeding the pipeline workers.
//!
//! A bounded MPMC queue (mutex + condvar; crossbeam channels aren't in
//! the vendor set) with load-shedding: when the queue is full the request
//! is rejected immediately rather than growing the tail — the paper's
//! envelope is a hard < 50 ms deadline, so queued-forever requests are
//! worthless.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};

struct Inner<T> {
    queue: VecDeque<(T, Instant)>,
    closed: bool,
}

/// Priority key extractor: pop takes the queued item with the smallest
/// key (FIFO among ties). Boxed so the queue type stays unparameterized.
type PriorityFn<T> = Box<dyn Fn(&T) -> u64 + Send + Sync>;

/// Bounded MPMC request queue with shed-on-full admission. FIFO by
/// default; [`RequestQueue::with_priority`] pops the minimum-key item
/// instead (deadline-closest-first intake).
pub struct RequestQueue<T> {
    inner: Mutex<Inner<T>>,
    /// Items available (poppers park here).
    notify: Condvar,
    /// Space available (blocking pushers park here — kept separate from
    /// `notify` so a wakeup can never be stolen by the wrong side).
    space: Condvar,
    capacity: usize,
    priority: Option<PriorityFn<T>>,
}

impl<T> RequestQueue<T> {
    pub fn new(capacity: usize) -> Arc<Self> {
        Self::build(capacity, None)
    }

    /// A queue whose `pop` returns the item with the smallest `key`
    /// value (ties resolve FIFO). The linear min-scan (plus `remove`)
    /// runs under the queue lock — O(capacity) per pop, so a full
    /// default intake (1024) costs ~1k key calls per pop. Acceptable
    /// for the opt-in deadline-first intake; if it ever runs hot at
    /// extreme depth, a `BinaryHeap` keyed on `(key, admission_seq)`
    /// keeps the FIFO tie-break at O(log n) (ROADMAP follow-up).
    pub fn with_priority<F>(capacity: usize, key: F) -> Arc<Self>
    where
        F: Fn(&T) -> u64 + Send + Sync + 'static,
    {
        Self::build(capacity, Some(Box::new(key)))
    }

    fn build(capacity: usize, priority: Option<PriorityFn<T>>) -> Arc<Self> {
        Arc::new(RequestQueue {
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            notify: Condvar::new(),
            space: Condvar::new(),
            capacity: capacity.max(1),
            priority,
        })
    }

    /// Admit a request or shed it (Err(Overloaded)).
    pub fn push(&self, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Error::Shutdown("request queue closed".into()));
        }
        if g.queue.len() >= self.capacity {
            return Err(Error::Overloaded(format!("request queue full ({})", self.capacity)));
        }
        g.queue.push_back((item, Instant::now()));
        drop(g);
        self.notify.notify_one();
        Ok(())
    }

    /// Blocking admit: waits for space instead of shedding — the
    /// stage-to-stage handoff primitive. A full downstream queue stalls
    /// the producer, which is exactly how handoff backpressure reaches
    /// the front door (the stalled producer stops draining the bounded
    /// intake queue, whose `push` then sheds). Returns the item back on
    /// a closed queue so the caller can fail it explicitly.
    pub fn push_blocking(&self, item: T) -> std::result::Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(item);
            }
            if g.queue.len() < self.capacity {
                g.queue.push_back((item, Instant::now()));
                drop(g);
                self.notify.notify_one();
                return Ok(());
            }
            g = self.space.wait(g).unwrap();
        }
    }

    /// Blocking pop; returns the item + its queueing delay, or None when
    /// the queue is closed and drained. FIFO, unless the queue was built
    /// with a priority key — then the minimum-key item pops first.
    pub fn pop(&self) -> Option<(T, std::time::Duration)> {
        let mut g = self.inner.lock().unwrap();
        loop {
            let next = match &self.priority {
                None => g.queue.pop_front(),
                Some(key) => {
                    // first minimal key → FIFO among ties
                    let best = g
                        .queue
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, (item, _))| key(item))
                        .map(|(i, _)| i);
                    best.and_then(|i| g.queue.remove(i))
                }
            };
            if let Some((item, t)) = next {
                drop(g);
                self.space.notify_one();
                return Some((item, t.elapsed()));
            }
            if g.closed {
                return None;
            }
            g = self.notify.wait(g).unwrap();
        }
    }

    /// Close the queue; waiting poppers drain then observe None and
    /// blocked pushers get their item back.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
        self.space.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(8);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop().unwrap().0, 1);
        assert_eq!(q.pop().unwrap().0, 2);
    }

    #[test]
    fn sheds_when_full() {
        let q = RequestQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        match q.push(3) {
            Err(Error::Overloaded(_)) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_unblocks_poppers() {
        let q: Arc<RequestQueue<u32>> = RequestQueue::new(4);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn close_drains_remaining() {
        let q = RequestQueue::new(4);
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.pop().unwrap().0, 7);
        assert!(q.pop().is_none());
        assert!(q.push(8).is_err());
    }

    #[test]
    fn queueing_delay_measured() {
        let q = RequestQueue::new(4);
        q.push(1).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let (_, delay) = q.pop().unwrap();
        assert!(delay >= std::time::Duration::from_millis(4));
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let q = RequestQueue::new(0);
        assert_eq!(q.capacity(), 1);
        q.push(1).unwrap();
        match q.push(2) {
            Err(Error::Overloaded(_)) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }

    #[test]
    fn shed_preserves_fifo_of_admitted_items() {
        let q = RequestQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert!(q.push(3).is_err()); // shed
        assert_eq!(q.pop().unwrap().0, 1);
        q.push(4).unwrap(); // capacity freed: admitted again
        assert_eq!(q.pop().unwrap().0, 2);
        assert_eq!(q.pop().unwrap().0, 4);
        assert!(q.is_empty());
    }

    #[test]
    fn queueing_delay_near_zero_for_immediate_pop() {
        let q = RequestQueue::new(4);
        q.push(1).unwrap();
        let (_, delay) = q.pop().unwrap();
        assert!(delay < std::time::Duration::from_millis(50), "delay {delay:?}");
    }

    #[test]
    fn push_blocking_waits_for_space_then_admits() {
        let q: Arc<RequestQueue<u32>> = RequestQueue::new(1);
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push_blocking(2));
        std::thread::sleep(std::time::Duration::from_millis(20));
        // deterministic either way the scheduler lands: while the queue
        // is full the blocked push must not have enqueued anything
        assert_eq!(q.len(), 1, "push_blocking enqueued into a full queue");
        assert_eq!(q.pop().unwrap().0, 1); // frees a slot, wakes the pusher
        assert!(pusher.join().unwrap().is_ok());
        assert_eq!(q.pop().unwrap().0, 2);
    }

    #[test]
    fn push_blocking_returns_item_on_close() {
        let q: Arc<RequestQueue<u32>> = RequestQueue::new(1);
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push_blocking(7));
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.close();
        assert_eq!(pusher.join().unwrap(), Err(7), "closed queue hands the item back");
    }

    #[test]
    fn close_wakes_every_blocked_pusher() {
        // shutdown-while-blocked: several producers parked on a full
        // queue must ALL wake with their item back (so each caller can
        // fail its request with a typed shutdown error), not hang on a
        // condvar nobody will ever signal again
        let q: Arc<RequestQueue<u32>> = RequestQueue::new(1);
        q.push(0).unwrap(); // fill
        let pushers: Vec<_> = (1..=3)
            .map(|i| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || q.push_blocking(i))
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        let mut returned: Vec<u32> = pushers
            .into_iter()
            .map(|h| h.join().unwrap().expect_err("blocked pusher must get its item back"))
            .collect();
        returned.sort_unstable();
        assert_eq!(returned, vec![1, 2, 3], "every blocked producer woke with its item");
        // the admitted item still drains; new pushes fail typed
        assert_eq!(q.pop().unwrap().0, 0);
        assert!(q.pop().is_none());
        match q.push(9) {
            Err(Error::Shutdown(_)) => {}
            other => panic!("expected typed Shutdown, got {other:?}"),
        }
    }

    #[test]
    fn closed_queue_sheds_with_typed_shutdown_error() {
        let q: Arc<RequestQueue<u32>> = RequestQueue::new(4);
        q.close();
        match q.push(1) {
            Err(Error::Shutdown(_)) => {}
            other => panic!("expected Shutdown, got {other:?}"),
        }
    }

    #[test]
    fn priority_pop_takes_minimum_key() {
        // items are (priority, label); lower key pops first regardless
        // of push order
        let q: Arc<RequestQueue<(u64, &str)>> = RequestQueue::with_priority(8, |it| it.0);
        q.push((50, "slack")).unwrap();
        q.push((5, "tight")).unwrap();
        q.push((20, "mid")).unwrap();
        assert_eq!(q.pop().unwrap().0 .1, "tight");
        assert_eq!(q.pop().unwrap().0 .1, "mid");
        assert_eq!(q.pop().unwrap().0 .1, "slack");
    }

    #[test]
    fn priority_ties_stay_fifo() {
        let q: Arc<RequestQueue<(u64, u32)>> = RequestQueue::with_priority(8, |it| it.0);
        q.push((7, 1)).unwrap();
        q.push((7, 2)).unwrap();
        q.push((7, 3)).unwrap();
        assert_eq!(q.pop().unwrap().0 .1, 1);
        assert_eq!(q.pop().unwrap().0 .1, 2);
        assert_eq!(q.pop().unwrap().0 .1, 3);
    }

    #[test]
    fn priority_queue_still_sheds_and_drains_on_close() {
        let q: Arc<RequestQueue<(u64, u32)>> = RequestQueue::with_priority(2, |it| it.0);
        q.push((9, 1)).unwrap();
        q.push((1, 2)).unwrap();
        assert!(q.push((0, 3)).is_err(), "full queue must shed");
        q.close();
        assert_eq!(q.pop().unwrap().0 .1, 2, "min key first even after close");
        assert_eq!(q.pop().unwrap().0 .1, 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let q: Arc<RequestQueue<u64>> = RequestQueue::new(10_000);
        for i in 0..1000 {
            q.push(i).unwrap();
        }
        q.close();
        let sum = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                let sum = Arc::clone(&sum);
                std::thread::spawn(move || {
                    while let Some((v, _)) = q.pop() {
                        sum.fetch_add(v, std::sync::atomic::Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(sum.load(std::sync::atomic::Ordering::Relaxed), 499_500);
    }
}
